"""One benchmark per paper table/figure (§VIII). Each function returns CSV
rows (name, us_per_call, derived). Every method is built and searched
through the unified `repro.api` facade (`common.METHOD_SPECS` names the
registry backends) — no per-backend build/search glue lives here."""
from __future__ import annotations

import time

import numpy as np

from .common import (BENCH_SETS, METHOD_SPECS, build_backend, build_method,
                     evaluate, load)

_built = {}


def _get(name, label):
    key = (name, label)
    if key not in _built:
        _built[key] = build_method(name, label)
    return _built[key]


def fig4a_index_size():
    """Fig. 4(a): index size per method per dataset (MB)."""
    rows = []
    for name in BENCH_SETS:
        for label in METHOD_SPECS:
            s = _get(name, label)
            rows.append((f"fig4a/{name}/{label}", 0.0,
                         f"index_mb={s.index_bytes/1e6:.2f}"))
    return rows


def fig4b_preprocessing_time():
    """Fig. 4(b): pre-processing (build) time per method (s)."""
    rows = []
    for name in BENCH_SETS:
        for label in METHOD_SPECS:
            secs = _get(name, label).build_seconds
            rows.append((f"fig4b/{name}/{label}", secs * 1e6,
                         f"build_s={secs:.2f}"))
    return rows


def _accuracy_fig(metric):
    rows = []
    for name in BENCH_SETS:
        for label in METHOD_SPECS:
            for k in (10, 50, 100):
                m = evaluate(_get(name, label), name, k)
                rows.append((f"{metric}/{name}/{label}/k{k}", m["cpu_us"],
                             f"ratio={m['ratio']:.4f};recall={m['recall']:.3f};"
                             f"pages={m['pages']:.0f};total_us={m['total_us']:.0f}"))
    return rows


def fig5_6_overall_ratio_recall():
    """Figs. 5-6: overall ratio + recall vs k (plus pages/time, reused by 7-9)."""
    return _accuracy_fig("fig5-9")


def fig10_impact_of_c():
    """Fig. 10: ProMIPS accuracy/efficiency vs approximation ratio c."""
    rows = []
    for name in ("netflix", "sift"):
        for c in (0.7, 0.8, 0.9):
            s = build_backend(name, "promips", c=c, search_path="host")
            m = evaluate(s, name, 10)
            rows.append((f"fig10/{name}/c{c}", m["cpu_us"],
                         f"ratio={m['ratio']:.4f};pages={m['pages']:.0f};"
                         f"guarantee_frac={m['guarantee_frac']:.2f}"))
    return rows


def fig11_impact_of_p():
    """Fig. 11: ProMIPS accuracy/efficiency vs guarantee probability p0."""
    rows = []
    for name in ("netflix", "sift"):
        for p0 in (0.3, 0.5, 0.7, 0.9):
            s = build_backend(name, "promips", p0=p0, search_path="host")
            m = evaluate(s, name, 10)
            rows.append((f"fig11/{name}/p{p0}", m["cpu_us"],
                         f"ratio={m['ratio']:.4f};pages={m['pages']:.0f};"
                         f"guarantee_frac={m['guarantee_frac']:.2f}"))
    return rows


def table2_complexity_scaling():
    """Table II: search cost scaling in n (ProMIPS O(d + n log n))."""
    from repro import api
    from repro.data.synthetic import mf_factors
    rows = []
    prev = None
    for n in (2000, 8000, 32000):
        x = mf_factors(n, 128, 24, decay=0.2, seed=0, norm_tail=0.3)
        q = mf_factors(8, 128, 24, decay=0.2, seed=1)
        t0 = time.time()
        s = api.build(x, backend="promips", m=8, mode="progressive",
                      norm_strata=4)
        build_s = time.time() - t0
        s.search(q, k=10)  # compile
        t0 = time.perf_counter()
        s.search(q, k=10)
        us = (time.perf_counter() - t0) / 8 * 1e6
        growth = "" if prev is None else f";time_growth={us/prev:.2f}x_for_4x_n"
        prev = us
        rows.append((f"table2/n{n}", us, f"build_s={build_s:.2f}{growth}"))
    return rows


def ablation_beyond_paper():
    """Beyond-paper ladder: paper-faithful -> +norm-adaptive -> +CS-prune ->
    +progressive (+norm-strata layout). One backend, four option sets —
    the §Perf algorithmic story, expressed as facade build options."""
    variants = [
        ("paper", {}),
        ("+norm-adaptive", dict(norm_adaptive=True)),
        ("+cs-prune", dict(norm_adaptive=True, cs_prune=True)),
        ("+progressive+strata", dict(mode="progressive", norm_strata=4)),
    ]
    rows = []
    for name in ("netflix", "sift"):
        for label, opts in variants:
            s = build_backend(name, "promips", search_path="host", **opts)
            m = evaluate(s, name, 10)
            rows.append((f"ablation/{name}/{label}", m["cpu_us"],
                         f"ratio={m['ratio']:.4f};pages={m['pages']:.0f};"
                         f"guarantee_frac={m['guarantee_frac']:.2f}"))
    return rows


def bench_api(quick: bool = True):
    """Registry sweep (`benchmarks/run.py --api`): for EVERY registered
    backend — build time, index bytes on disk (real npz+json footprint after
    `save`), µs/query on a 64-query batch, and recall@10 vs exact. Writes
    BENCH_api.json at the repo root."""
    import json
    import os
    import shutil
    import tempfile

    from repro import api
    from repro.baselines.exact import exact_topk
    from repro.core import recall_at_k
    from repro.data.synthetic import mf_factors

    n, d, n_q = (8000, 64, 64) if quick else (20000, 96, 64)
    x = mf_factors(n, d, 16, decay=0.5, seed=0, norm_tail=0.3)
    q = mf_factors(n_q, d, 16, decay=0.5, seed=1)
    eids, _ = exact_topk(x, q, 10)
    guarantee = api.GuaranteeConfig(c=0.9, p0=0.6, k=10)

    rec = {"n": n, "d": d, "batch": n_q, "k": 10,
           "guarantee": guarantee.to_dict(), "backends": {}}
    rows = []
    tmp = tempfile.mkdtemp(prefix="bench_api_")
    try:
        for backend in api.backends():
            prune = (dict(norm_adaptive=True, cs_prune=True)
                     if api.get_backend(backend).capabilities.guaranteed
                     and backend != "exact" else {})
            t0 = time.perf_counter()
            s = api.build(x, backend=backend, guarantee=guarantee, seed=0,
                          **prune)
            build_s = time.perf_counter() - t0

            path = os.path.join(tmp, backend)
            s.save(path)
            disk = api.saved_bytes(path)

            s.search(q, k=10)  # warm-up / compile
            reps = 3
            t0 = time.perf_counter()
            for _ in range(reps):
                res = s.search(q, k=10)
            us = (time.perf_counter() - t0) / (reps * n_q) * 1e6
            recall = float(np.mean([recall_at_k(res.ids[i], eids[i])
                                    for i in range(n_q)]))
            cell = dict(build_s=build_s, disk_bytes=disk, us_per_query=us,
                        recall_vs_exact=recall,
                        pages_per_query=res.pages / n_q,
                        capabilities=vars(s.capabilities).copy())
            rec["backends"][backend] = cell
            rows.append((f"api/{backend}", us,
                         f"recall={recall:.3f};disk_mb={disk/1e6:.2f};"
                         f"build_s={build_s:.2f}"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # Large-n point (>= 100k): the regime where pruning pays off — the
    # `promips` facade backend must beat the `exact` dense scan at
    # recall >= 0.95 (PR 4 acceptance). Restricted to those two backends:
    # the numpy LSH/PQ baselines take minutes per 100k-corpus sweep and add
    # nothing to the pruned-vs-dense comparison this point exists for.
    cfg = LARGE_N
    xl, ql = _large_corpus()
    eids_l, _ = exact_topk(xl, ql, cfg["k"])
    large_guarantee = api.GuaranteeConfig(c=cfg["c"], p0=cfg["p0"], k=cfg["k"])
    rec["large_n"] = {"n": cfg["n"], "d": cfg["d"], "batch": cfg["n_q"],
                      "k": cfg["k"], "guarantee": large_guarantee.to_dict(),
                      "backends": {}}
    promips_opts = dict(m=cfg["m"], k_p=cfg["k_p"], k_sp=cfg["k_sp"],
                        norm_strata=cfg["norm_strata"], norm_adaptive=True,
                        cs_prune=True)
    searchers, times = {}, {}
    for backend, opts in (("exact", {}), ("promips", promips_opts)):
        t0 = time.perf_counter()
        s = api.build(xl, backend=backend, guarantee=large_guarantee, seed=0,
                      **opts)
        build_s = time.perf_counter() - t0
        s.search(ql, k=cfg["k"])  # warm-up / compile
        searchers[backend] = (s, build_s)
        times[backend] = []
    # prefilter-on variant reuses the SAME built index (the sketch is built
    # unconditionally) with only the runtime knob flipped — no second
    # 100k-corpus build.
    import dataclasses as _dc
    s_pm, build_pm = searchers["promips"]
    s_pf = type(s_pm)(s_pm.pm,
                      _dc.replace(s_pm.runtime, prefilter=True,
                                  prefilter_eps=PREFILTER_EPS),
                      s_pm.search_path)
    s_pf.search(ql, k=cfg["k"])  # warm-up / compile
    searchers["promips-prefilter"] = (s_pf, build_pm)
    times["promips-prefilter"] = []
    # interleaved reps + medians: both backends see the same host
    # conditions (this box's wall clock jitters +-20% across seconds)
    results = {}
    for _ in range(5):
        for backend, (s, _) in searchers.items():
            t0 = time.perf_counter()
            results[backend] = s.search(ql, k=cfg["k"])
            times[backend].append(time.perf_counter() - t0)
    for backend, (s, build_s) in searchers.items():
        res = results[backend]
        us = float(np.median(times[backend])) / cfg["n_q"] * 1e6
        recall = float(np.mean([recall_at_k(res.ids[i], eids_l[i])
                                for i in range(cfg["n_q"])]))
        rec["large_n"]["backends"][backend] = dict(
            build_s=build_s, us_per_query=us, recall_vs_exact=recall,
            pages_per_query=res.pages / cfg["n_q"])
        rows.append((f"api/large_n{cfg['n']}/{backend}", us,
                     f"recall={recall:.3f};build_s={build_s:.1f}"))
    ratios = [te / tp for te, tp in zip(times["exact"], times["promips"])]
    rec["large_n"]["promips_vs_exact_speedup"] = float(np.median(ratios))
    rec["large_n"]["promips_beats_exact"] = (
        rec["large_n"]["promips_vs_exact_speedup"] > 1.0)
    rows.append(("api/large_n/promips_vs_exact", 0.0,
                 f"x{rec['large_n']['promips_vs_exact_speedup']:.2f}"))
    # prefilter on/off page fractions through the facade (history.jsonl
    # carries these per commit; ci.sh guards the smoke-scale counterpart)
    nb = s_pm.pm.meta.n_blocks
    cells = rec["large_n"]["backends"]
    rec["large_n"]["prefilter_eps"] = PREFILTER_EPS
    rec["large_n"]["prefilter_on_pages_frac"] = (
        cells["promips-prefilter"]["pages_per_query"] / nb)
    rec["large_n"]["prefilter_off_pages_frac"] = (
        cells["promips"]["pages_per_query"] / nb)
    rows.append(("api/large_n/prefilter_pages_frac", 0.0,
                 f"{rec['large_n']['prefilter_on_pages_frac']:.3f} vs "
                 f"{rec['large_n']['prefilter_off_pages_frac']:.3f} off"))

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_api.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rows


# Large-n benchmark point (n >= 100k, SIFT-like d=128) where pruning
# actually pays off: strong norm decay + long-tail scales, norm-stratified
# layout, m=16 projections. Shared by `bench_search_runtime` and `bench_api`
# so both --quick and --api record the same regime (recall vs exact is
# 1.000 at these settings; pages ~0.8 of blocks). d=128 matters: the
# per-query dense scan is bandwidth-bound in n*d while the fused batch
# path's non-matmul work is d-independent, so this is the regime the
# index's batched amortization genuinely wins on CPU too.
LARGE_N = dict(n=100_000, d=128, rank=16, decay=0.5, norm_tail=0.6,
               m=16, k_p=8, k_sp=8, norm_strata=8, c=0.9, p0=0.6,
               n_q=64, k=10)

# Sketch-prefilter calibration knob (DESIGN.md §13): eps=0.1 holds recall
# 1.000 at the LARGE_N point while cutting pages_frac 0.84 -> ~0.11; the
# cliff is below ~0.07. The guarantee suite pins this same eps on its grid.
PREFILTER_EPS = 0.1


def _large_corpus():
    from repro.data.synthetic import mf_factors
    cfg = LARGE_N
    x = mf_factors(cfg["n"], cfg["d"], cfg["rank"], decay=cfg["decay"],
                   seed=0, norm_tail=cfg["norm_tail"])
    q = mf_factors(cfg["n_q"], cfg["d"], cfg["rank"], decay=cfg["decay"],
                   seed=1)
    return x, q


def bench_search_runtime(quick: bool = False):
    """Host vs device scan/batched/fused verification — the two-phase
    runtime speedup cells (ISSUE 1: batched >= 2x scan; ISSUE 4: fused >=
    batched, guarded by scripts/ci.sh). Writes BENCH_search.json at the
    repo root with per-query latency + logical pages so the perf trajectory
    is recorded (benchmarks/run.py also appends it to
    results/bench/history.jsonl), including a large-n point (`LARGE_N`)
    where pruning pays off and `promips` must beat the exact full scan.

    Settings are tuned so pruning actually ENGAGES (ISSUE 2): decay-0.5 MF
    norms, an 8-stratum layout and the norm-adaptive + CS-prune radii leave
    pages_mean well under n_blocks (~398/500 at quick sizes, recall 0.997
    vs exact) — the page-access axis measures real work, not a full sweep.
    Both pages_mean and n_blocks are recorded so the engagement is auditable.

    (This bench deliberately reaches below the facade: it compares the
    verification backends INSIDE the "promips" registry entry.)
    """
    import json
    import os

    import jax.numpy as jnp

    from repro.core import ProMIPS
    from repro.data.synthetic import mf_factors

    n, d, n_q = (8000, 64, 64) if quick else (20000, 96, 64)
    x = mf_factors(n, d, 16, decay=0.5, seed=0, norm_tail=0.3)
    q = mf_factors(n_q, d, 16, decay=0.5, seed=1)
    pm = ProMIPS.build(x, m=8, c=0.9, p=0.6, k_p=8, k_sp=12, norm_strata=8)
    qj = jnp.asarray(q, jnp.float32)

    import jax
    backend = ("tpu-pallas" if jax.default_backend() == "tpu"
               else f"{jax.default_backend()}-jnp-oracle")
    rec = {"n": n, "d": d, "batch": n_q, "k": 10,
           "n_blocks": pm.meta.n_blocks, "page_rows": pm.meta.page_rows,
           "backend": backend}
    rows = []

    pm.search_host(q[0], k=10)   # warm-up: lazy HostSearcher build + chi2,
    t0 = time.perf_counter()     # mirroring the device paths' compile call
    for i in range(8):
        _, _, st_h = pm.search_host(q[i], k=10)
    rec["host_us_per_query"] = (time.perf_counter() - t0) / 8 * 1e6
    rows.append(("runtime/host", rec["host_us_per_query"], "queries=8"))

    labels = ("scan", "batched", "fused")

    def one_rep(label):
        t0 = time.perf_counter()
        ids, _, st = pm.search(qj, k=10, verification=label,
                               norm_adaptive=True, cs_prune=True)
        ids.block_until_ready()
        return time.perf_counter() - t0, st

    times = {label: [] for label in labels}
    stats = {}
    for label in labels:
        one_rep(label)  # compile
    # interleaved reps + per-pair ratio medians: the CI guard hard-asserts
    # fused >= batched and this host's wall clock jitters +-20% across
    # seconds, so back-to-back timing blocks would make that ratio a lottery
    for _ in range(5):
        for label in labels:
            dt, stats[label] = one_rep(label)
            times[label].append(dt)
    for label in labels:
        us = float(np.median(times[label])) / n_q * 1e6
        pages = float(np.mean(np.asarray(stats[label].pages)))
        rec[f"device_{label}_us_per_query"] = us
        rec[f"device_{label}_pages_mean"] = pages
        rows.append((f"runtime/device_{label}", us,
                     f"pages={pages:.0f}/{pm.meta.n_blocks}"))

    rec["pages_frac_of_blocks"] = (
        rec["device_batched_pages_mean"] / pm.meta.n_blocks)
    rec["pruning_engaged"] = rec["pages_frac_of_blocks"] < 1.0
    rec["speedup_batched_vs_scan"] = float(np.median(
        [s / b for s, b in zip(times["scan"], times["batched"])]))
    rec["speedup_fused_vs_batched"] = float(np.median(
        [b / f for b, f in zip(times["batched"], times["fused"])]))
    rows.append(("runtime/speedup_batched_vs_scan", 0.0,
                 f"x{rec['speedup_batched_vs_scan']:.2f}"))
    rows.append(("runtime/speedup_fused_vs_batched", 0.0,
                 f"x{rec['speedup_fused_vs_batched']:.2f}"))

    # prefilter on/off page fractions at the smoke scale (ci.sh guards the
    # cut + the recall floor; exact ids from a jit scan, not the index)
    xj = jnp.asarray(x, jnp.float32)
    eids = np.asarray(jax.lax.top_k((xj @ qj.T).T, 10)[1])
    from repro.core import recall_at_k
    for tag, kw in (("off", {}), ("on", dict(prefilter=True,
                                             prefilter_eps=PREFILTER_EPS))):
        ids, _, st = pm.search(qj, k=10, norm_adaptive=True, cs_prune=True,
                               **kw)
        ids = np.asarray(ids)
        rec[f"prefilter_{tag}_pages_frac"] = float(
            np.mean(np.asarray(st.pages))) / pm.meta.n_blocks
        rec[f"prefilter_{tag}_recall"] = float(np.mean(
            [recall_at_k(ids[i], eids[i]) for i in range(n_q)]))
    rec["prefilter_eps"] = PREFILTER_EPS
    rows.append(("runtime/prefilter_pages_frac", 0.0,
                 f"{rec['prefilter_on_pages_frac']:.3f} vs "
                 f"{rec['prefilter_off_pages_frac']:.3f} off; "
                 f"recall={rec['prefilter_on_recall']:.3f}"))

    rec["large_n"] = large = _bench_runtime_large()
    rows.append((f"runtime/large_n{large['n']}/exact",
                 large["exact_us_per_query"], "numpy per-query scan"))
    rows.append((f"runtime/large_n{large['n']}/exact_jit",
                 large["exact_jit_us_per_query"], "jit batch matmul+topk"))
    for label in ("batched", "fused_noprefilter", "fused", "tuned"):
        rows.append((f"runtime/large_n{large['n']}/{label}",
                     large[f"{label}_us_per_query"],
                     f"pages={large[f'{label}_pages_mean']:.0f}"
                     f"/{large['n_blocks']};"
                     f"recall={large[f'{label}_recall']:.3f}"))
    rows.append(("runtime/large_n/speedup_fused_vs_exact", 0.0,
                 f"x{large['speedup_fused_vs_exact']:.2f}"))
    rows.append(("runtime/large_n/speedup_fused_vs_exact_jit", 0.0,
                 f"x{large['speedup_fused_vs_exact_jit']:.2f}"))
    rows.append(("runtime/large_n/speedup_tuned_vs_default", 0.0,
                 f"x{large['speedup_tuned_vs_default']:.2f};"
                 f"config_source={large['config_source']}"))

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_search.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def _bench_runtime_large():
    """The large-n cell: fused/batched two-phase vs the exact full scan.

    This is the regime the paper's pitch is about — at n >= 100k the fused
    pruned path must come in UNDER the `exact` backend (the numpy per-query
    scan every accuracy figure compares against; `promips` < `exact` with
    recall >= 0.95). A jit batch matmul+top_k is ALSO recorded
    (``exact_jit_us_per_query``) as the device-side dense upper bound — on
    this CPU container its one sgemm beats everything at ~80% page
    fractions; the fused kernel's page-skipping DMA walk is what closes
    that gap on a real TPU (DESIGN.md §10). Returns the record embedded in
    BENCH_search.json.
    """
    import jax
    import jax.numpy as jnp

    from repro.baselines.exact import ExactMIPS, exact_topk
    from repro.core import ProMIPS, recall_at_k

    cfg = LARGE_N
    x, q = _large_corpus()
    t0 = time.perf_counter()
    pm = ProMIPS.build(x, m=cfg["m"], c=cfg["c"], p=cfg["p0"], k_p=cfg["k_p"],
                       k_sp=cfg["k_sp"], norm_strata=cfg["norm_strata"])
    rec = {"n": cfg["n"], "d": cfg["d"], "batch": cfg["n_q"], "k": cfg["k"],
           "build_s": time.perf_counter() - t0, "n_blocks": pm.meta.n_blocks}
    qj = jnp.asarray(q, jnp.float32)
    eids, _ = exact_topk(x, q, cfg["k"])

    exact = ExactMIPS().build(x)
    exact.search(q[0], k=cfg["k"])

    def exact_rep():
        t0 = time.perf_counter()
        for i in range(cfg["n_q"]):
            exact.search(q[i], k=cfg["k"])
        return time.perf_counter() - t0

    xj = jnp.asarray(x, jnp.float32)

    @jax.jit
    def exact_scan(qj):
        return jax.lax.top_k((xj @ qj.T).T, cfg["k"])
    out = exact_scan(qj)
    out[0].block_until_ready()

    def exact_jit_rep():
        t0 = time.perf_counter()
        out = exact_scan(qj)
        out[0].block_until_ready()
        return time.perf_counter() - t0

    # headline fused = sketch prefilter ON at the DESIGN.md §13-calibrated
    # eps; the no-prefilter fused path is recorded alongside so the page
    # cut is auditable in one record. The hand-picked arms PIN dense_frac
    # and tile_cap explicitly so an installed tuning cache
    # (results/tune/tuning.json) cannot leak into the baseline; the "tuned"
    # arm takes whatever `repro.tune.cache` resolves for this shape — with
    # no entry it degenerates to the hand-picked config (config_source
    # records which happened).
    from repro.tune import cache as tune_cache
    tuned_entry = tune_cache.lookup(cfg["n"], cfg["d"])
    tuned_rt = tune_cache.resolved("runtime", cfg["n"], cfg["d"])
    rec["config_source"] = "tuned" if tuned_entry is not None else "default"
    rec["tuned_runtime"] = dict(tuned_rt)
    pin = dict(dense_frac=0.9, tile_cap=pm.meta.n_blocks)
    tuned_tc = tuned_rt["tile_cap"]
    variants = {
        "batched": dict(verification="batched"),
        "fused_noprefilter": dict(verification="fused", **pin),
        "fused": dict(verification="fused", prefilter=True,
                      prefilter_eps=PREFILTER_EPS, **pin),
        "tuned": dict(verification=tuned_rt["verification"], prefilter=True,
                      prefilter_eps=(float(tuned_rt["prefilter_eps"])
                                     if tuned_entry is not None
                                     else PREFILTER_EPS),
                      dense_frac=float(tuned_rt["dense_frac"]),
                      tile_cap=(int(tuned_tc) if tuned_tc is not None
                                else pm.meta.n_blocks)),
    }
    rec["prefilter_eps"] = PREFILTER_EPS

    def device_rep(label):
        t0 = time.perf_counter()
        ids, _, st = pm.search(qj, k=cfg["k"], norm_adaptive=True,
                               cs_prune=True, **variants[label])
        ids.block_until_ready()
        return time.perf_counter() - t0, ids, st

    for label in variants:
        device_rep(label)  # compile
    # INTERLEAVED exact/exact_jit/batched/fused reps: this host's wall clock
    # drifts +-20% over tens of seconds, so back-to-back blocks of reps make
    # the recorded ratios a lottery; pairing every rep and taking the median
    # per-pair ratio measures all contenders under the same conditions.
    # exact_jit is paired the same way (not timed once in its own block) so
    # speedup_fused_vs_exact_jit is an honest same-conditions ratio.
    t_ex, t_jit = [], []
    times = {label: [] for label in variants}
    outs = {}
    ratios, ratios_jit, ratios_tuned, ratios_tuned_exact = [], [], [], []
    for _ in range(5):
        t_ex.append(exact_rep())
        t_jit.append(exact_jit_rep())
        for label in variants:
            dt, ids, st = device_rep(label)
            times[label].append(dt)
            outs[label] = (ids, st)
        ratios.append(t_ex[-1] / times["fused"][-1])
        ratios_jit.append(t_jit[-1] / times["fused"][-1])
        ratios_tuned.append(times["fused"][-1] / times["tuned"][-1])
        ratios_tuned_exact.append(t_ex[-1] / times["tuned"][-1])
    rec["exact_us_per_query"] = float(np.median(t_ex)) / cfg["n_q"] * 1e6
    rec["exact_jit_us_per_query"] = float(np.median(t_jit)) / cfg["n_q"] * 1e6
    for label in variants:
        ids, st = outs[label]
        ids = np.asarray(ids)
        rec[f"{label}_us_per_query"] = (float(np.median(times[label]))
                                        / cfg["n_q"] * 1e6)
        rec[f"{label}_pages_mean"] = float(np.mean(np.asarray(st.pages)))
        rec[f"{label}_recall"] = float(np.mean(
            [recall_at_k(ids[i], eids[i]) for i in range(cfg["n_q"])]))
    rec["recall"] = rec["fused_recall"]
    rec["recall_noprefilter"] = rec["fused_noprefilter_recall"]
    rec["pages_frac_of_blocks"] = rec["fused_pages_mean"] / rec["n_blocks"]
    rec["pages_frac_noprefilter"] = (rec["fused_noprefilter_pages_mean"]
                                     / rec["n_blocks"])
    rec["pruning_engaged"] = rec["pages_frac_of_blocks"] < 1.0
    rec["speedup_fused_vs_exact"] = float(np.median(ratios))
    rec["speedup_fused_vs_exact_jit"] = float(np.median(ratios_jit))
    # same-session interleaved ratio of the hand-picked fused arm over the
    # cache-resolved arm — the --quick perf guard in scripts/ci.sh asserts
    # this stays above the noise floor when a tuned entry is installed
    rec["speedup_tuned_vs_default"] = float(np.median(ratios_tuned))
    # with a cache entry installed the tuned arm IS the shipped default
    # config, so the exact-scan headline is also recorded against it
    rec["speedup_tuned_vs_exact"] = float(np.median(ratios_tuned_exact))
    rec["roofline"] = _roofline_record(pm, qj, cfg["k"])
    return rec


def _roofline_record(pm, qj, k):
    """Achieved-vs-roofline cost terms of the in-graph fused search
    (prefilter on/off) and the exact jit scan, via XLA's cost_analysis on
    the compiled graphs (`launch/roofline.kernel_cost`). Caveat recorded
    honestly: the in-graph driver compiles EVERY lax.switch tile branch, and
    static cost_analysis sums them all, so these are compile-time upper
    bounds that cannot see the prefilter's runtime branch selection — the
    dynamic traffic cut is what `pages_frac_of_blocks` (vs
    `pages_frac_noprefilter`) audits; this record pins the roofline context
    (memory-bound, and how far the exact sgemm sits from the bound)."""
    import jax
    import jax.numpy as jnp

    from repro.core import RuntimeConfig, runtime_search
    from repro.launch.roofline import kernel_cost

    xj = jnp.asarray(pm.arrays.x)

    def graph(cfg):
        return jax.jit(lambda arrays, q: runtime_search(arrays, pm.meta,
                                                        q, cfg))

    out = {}
    try:
        out["exact_jit"] = kernel_cost(
            lambda q: jax.lax.top_k((xj @ q.T).T, k), qj)
        out["fused"] = kernel_cost(
            graph(RuntimeConfig(k=k, norm_adaptive=True, cs_prune=True,
                                prefilter=True,
                                prefilter_eps=PREFILTER_EPS)),
            pm.arrays, qj)
        out["fused_noprefilter"] = kernel_cost(
            graph(RuntimeConfig(k=k, norm_adaptive=True, cs_prune=True)),
            pm.arrays, qj)
    except Exception as e:  # cost_analysis is backend-dependent; never fatal
        out["error"] = f"{type(e).__name__}: {e}"
    return out


def bench_tune(smoke: bool = True):
    """Autotuner bench (ISSUE 8): runs the budgeted coordinate descent
    end-to-end on a cutout, writes the entry to a TEMP cache (never the
    committed results/tune/tuning.json), then audits the three properties
    scripts/ci.sh guards:

      1. searching with the tuned cache installed is not slower than the
         pinned hand-picked config beyond the noise floor (interleaved
         same-session ratio ``speedup_cached_vs_handpicked``);
      2. the tuned config returns bit-identical (ids, scores) — the parity
         gate's whole point (``tuned_parity``);
      3. an empty/disabled cache changes nothing: default-knob searches
         equal explicit hand-picked ones bitwise (``empty_cache_noop``).

    Writes BENCH_tune.json at the repo root.
    """
    import json
    import os
    import tempfile

    from repro.core import ProMIPS
    from repro.tune import cache as tune_cache
    from repro.tune import cutout as tune_cutout
    from repro.tune import search as tune_search

    n, d, n_q = (4000, 32, 16) if smoke else (20000, 64, 32)
    budget_s = 60.0 if smoke else 300.0
    x, q = tune_cutout.make_cutout(n, d, n_q, seed=0)
    build_opts = dict(m=12, c=0.9, p=0.6, k_p=4, k_sp=4, norm_strata=4,
                      seed=0)
    search_opts = dict(k=10, norm_adaptive=True, cs_prune=True,
                       prefilter=True, prefilter_eps=PREFILTER_EPS)

    tmp_cache = os.path.join(tempfile.mkdtemp(prefix="repro-tune-bench-"),
                             "tuning.json")
    entry = tune_search.tune_point(
        x, q, build_opts=build_opts, search_opts=search_opts,
        budget_s=budget_s, reps=3, include_build=False, write=True,
        path=tmp_cache)
    summary = entry["trace"]["summary"]
    rec = {"n": n, "d": d, "batch": n_q, "smoke": smoke,
           "cache_key": entry["key"], "tuned_runtime": entry["runtime"],
           "baseline_us_per_query": summary["baseline_us_per_query"],
           "best_us_per_query": summary["best_us_per_query"],
           "speedup_tuned_vs_default": summary["speedup_tuned_vs_default"],
           "n_candidates": summary["n_candidates"],
           "tune_elapsed_s": summary["elapsed_s"]}

    pm = ProMIPS.build(x, **build_opts)
    hand = dict(tune_cache.space.HAND_PICKED["runtime"])
    hand["prefilter_eps"] = PREFILTER_EPS
    fn_hand = tune_search._search_fn(pm, q, search_opts, hand)
    res_hand = fn_hand()
    import jax
    jax.block_until_ready(res_hand[1])

    prev = os.environ.get(tune_cache.ENV_VAR)
    try:
        # arm 2: the tuned cache INSTALLED — verification from the entry,
        # dense_frac/tile_cap left as None so runtime.search resolves them
        # from the cache, exactly like a user with the file in place
        os.environ[tune_cache.ENV_VAR] = tmp_cache
        tune_cache.clear_memo()
        tuned_rt = tune_cache.resolved("runtime", n, d)

        def fn_cached():
            return pm.search(q, k=10, norm_adaptive=True, cs_prune=True,
                             verification=tuned_rt["verification"],
                             prefilter=True, prefilter_eps=PREFILTER_EPS)

        res_cached = fn_cached()
        jax.block_until_ready(res_cached[1])
        rec["tuned_parity"] = tune_search._result_parity(res_hand,
                                                         res_cached)
        t_hand, t_cached, ratio = tune_cutout.interleaved_ratio(
            fn_hand, fn_cached, reps=3)
        rec["handpicked_us_per_query"] = t_hand * 1e6 / n_q
        rec["cached_us_per_query"] = t_cached * 1e6 / n_q
        rec["speedup_cached_vs_handpicked"] = ratio

        # arm 3: cache DISABLED — default knobs must change nothing
        os.environ[tune_cache.ENV_VAR] = ""
        tune_cache.clear_memo()
        res_none = pm.search(q, k=10, norm_adaptive=True, cs_prune=True,
                             prefilter=True, prefilter_eps=PREFILTER_EPS)
        jax.block_until_ready(res_none[1])
        rec["empty_cache_noop"] = tune_search._result_parity(res_hand,
                                                             res_none)
    finally:
        if prev is None:
            os.environ.pop(tune_cache.ENV_VAR, None)
        else:
            os.environ[tune_cache.ENV_VAR] = prev
        tune_cache.clear_memo()

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_tune.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return [
        ("tune/descent_s", summary["elapsed_s"] * 1e6,
         f"candidates={summary['n_candidates']};"
         f"speedup=x{summary['speedup_tuned_vs_default']:.3f}"),
        ("tune/cached_vs_handpicked", rec["cached_us_per_query"],
         f"x{rec['speedup_cached_vs_handpicked']:.3f};"
         f"parity={rec['tuned_parity']}"),
        ("tune/empty_cache_noop", 0.0, str(rec["empty_cache_noop"])),
    ]


def bench_sharded(quick: bool = True):
    """Sharded fan-out (ISSUE 5): the in-graph fused driver inside
    `sharded_search`'s shard_map vs the batched graph, at the LARGE_N point
    (n=100k) across device counts. Writes BENCH_sharded.json at the repo
    root (benchmarks/run.py appends it to results/bench/history.jsonl).

    Run under ``--xla_force_host_platform_device_count=8`` (benchmarks/run.py
    --sharded sets the flag itself before jax initializes); device counts
    that exceed the actual device count are skipped, so the bench degrades
    gracefully to a single-device point. Per count the corpus is re-sharded
    (shard count == mesh size — `build_sharded`'s contract) and the SAME
    fused-vs-batched interleaved-rep protocol as `bench_search_runtime`
    guards the ratio against this host's wall-clock drift. The CI perf
    guard asserts ``speedup_sharded_fused_vs_batched >= 1`` at the largest
    count (scripts/ci.sh).
    """
    import dataclasses
    import json
    import os

    import jax

    from repro.baselines.exact import exact_topk
    from repro.core import recall_at_k
    from repro.core.runtime import RuntimeConfig
    from repro.core.sharded import (build_sharded, device_put_sharded_index,
                                    sharded_search)
    from repro.launch.mesh import make_mesh_compat

    cfg = LARGE_N
    counts = [c for c in ((1, 2, 8) if quick else (1, 2, 4, 8))
              if c <= jax.device_count()]
    x, q = _large_corpus()
    eids, _ = exact_topk(x, q, cfg["k"])
    cfg_f = RuntimeConfig(mode="two_phase", verification="fused",
                          norm_adaptive=True, cs_prune=True)
    cfg_b = dataclasses.replace(cfg_f, verification="batched")

    rec = {"n": cfg["n"], "d": cfg["d"], "batch": cfg["n_q"], "k": cfg["k"],
           "jax_device_count": jax.device_count(), "device_counts": counts,
           "points": {}}
    rows = []
    for n_dev in counts:
        mesh = make_mesh_compat((n_dev,), ("model",))
        t0 = time.perf_counter()
        sh = build_sharded(x, n_dev, m=cfg["m"], c=cfg["c"], p=cfg["p0"],
                           k_p=cfg["k_p"], k_sp=cfg["k_sp"],
                           norm_strata=cfg["norm_strata"])
        shd = device_put_sharded_index(sh, mesh)
        build_s = time.perf_counter() - t0

        def one_rep(runtime):
            t0 = time.perf_counter()
            ids, scores, pages = sharded_search(shd, q, cfg["k"], mesh,
                                                runtime=runtime)
            ids.block_until_ready()
            return time.perf_counter() - t0, ids, pages

        for runtime in (cfg_f, cfg_b):
            one_rep(runtime)  # compile
        t_f, t_b, ratios = [], [], []
        for _ in range(3):  # interleaved: both contenders see the same drift
            tb, _, _ = one_rep(cfg_b)
            tf, ids, pages = one_rep(cfg_f)
            t_f.append(tf)
            t_b.append(tb)
            ratios.append(tb / tf)
        recall = float(np.mean([recall_at_k(np.asarray(ids)[i], eids[i])
                                for i in range(cfg["n_q"])]))
        point = {
            "build_s": build_s,
            "n_blocks_per_shard": sh.meta.n_blocks,
            "fused_us_per_query": float(np.median(t_f)) / cfg["n_q"] * 1e6,
            "batched_us_per_query": float(np.median(t_b)) / cfg["n_q"] * 1e6,
            "pages_total": int(pages),
            "recall": recall,
            "speedup_fused_vs_batched": float(np.median(ratios)),
        }
        rec["points"][str(n_dev)] = point
        rows.append((f"sharded/devices{n_dev}/fused",
                     point["fused_us_per_query"],
                     f"recall={recall:.3f};pages={int(pages)}"))
        rows.append((f"sharded/devices{n_dev}/batched",
                     point["batched_us_per_query"],
                     f"x{point['speedup_fused_vs_batched']:.2f} fused-vs-batched"))

    top = rec["points"][str(counts[-1])]
    rec["max_devices"] = counts[-1]
    rec["recall"] = top["recall"]
    rec["speedup_sharded_fused_vs_batched"] = top["speedup_fused_vs_batched"]
    rows.append(("sharded/speedup_fused_vs_batched", 0.0,
                 f"x{rec['speedup_sharded_fused_vs_batched']:.2f}"
                 f"@{counts[-1]}dev"))

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_sharded.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def bench_stream(quick: bool = True):
    """Streaming index (ISSUE 2): insert throughput, search latency at
    0%/10%/30% delta fraction, and latency right after compaction. Writes
    BENCH_stream.json at the repo root. Built through the facade; the
    mutation calls are the uniform capability-gated Searcher surface."""
    import json
    import os

    from repro import api
    from repro.core.runtime import RuntimeConfig
    from repro.data.synthetic import mf_factors

    n, d, n_q = (8000, 64, 64) if quick else (20000, 96, 64)
    x = mf_factors(n, d, 16, decay=0.5, seed=0, norm_tail=0.3)
    q = mf_factors(n_q, d, 16, decay=0.5, seed=1)
    rng = np.random.RandomState(2)

    s = api.build(x, backend="promips-stream",
                  guarantee=api.GuaranteeConfig(c=0.9, p0=0.6, k=10),
                  m=8, k_p=8, k_sp=12, norm_strata=8, seed=0)
    st = s.inner  # delta watermark introspection below is stream-specific
    cfg = RuntimeConfig(norm_adaptive=True, cs_prune=True)  # pruning engaged
    rec = {"n": n, "d": d, "batch": n_q, "k": 10,
           "delta_capacity": st.delta_capacity}
    rows = []

    def timed_search():
        s.search(q, k=10, runtime=cfg)
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            res = s.search(q, k=10, runtime=cfg)
        return ((time.perf_counter() - t0) / (reps * n_q) * 1e6,
                res.pages / n_q)

    # insert throughput: batched appends into the preallocated delta
    bursts, burst = 16, 64
    gid0 = 10 * n
    t0 = time.perf_counter()
    for i in range(bursts):
        s.insert(np.arange(gid0 + i * burst, gid0 + (i + 1) * burst),
                 rng.randn(burst, d).astype(np.float32))
    dt = time.perf_counter() - t0
    rec["insert_rows_per_s"] = bursts * burst / dt
    rows.append(("stream/insert_throughput", dt / (bursts * burst) * 1e6,
                 f"rows_per_s={rec['insert_rows_per_s']:.0f}"))
    s.delete(np.arange(gid0, gid0 + bursts * burst))  # reset to 0% live
    s.compact()

    for frac in (0.0, 0.1, 0.3):
        want = int(frac / (1 - frac) * n)  # live delta rows for this fraction
        have = st._delta.n_alive
        if want > have:
            s.insert(np.arange(20 * n + have, 20 * n + want),
                     rng.randn(want - have, d).astype(np.float32))
        us, pages = timed_search()
        assert abs(st.delta_fraction - frac) < 0.02, st.delta_fraction
        rec[f"search_us_delta_{int(frac*100)}pct"] = us
        rec[f"pages_delta_{int(frac*100)}pct"] = pages
        rows.append((f"stream/search_delta_{int(frac*100)}pct", us,
                     f"pages={pages:.0f};delta_frac={st.delta_fraction:.2f}"))

    t0 = time.perf_counter()
    s.compact()
    rec["compaction_s"] = time.perf_counter() - t0
    us, pages = timed_search()
    rec["search_us_post_compaction"] = us
    rec["pages_post_compaction"] = pages
    rows.append(("stream/search_post_compaction", us,
                 f"pages={pages:.0f};compaction_s={rec['compaction_s']:.2f}"))

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_stream.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def bench_device_throughput():
    """Batched device-mode (jit) search throughput + Pallas kernel check."""
    import jax.numpy as jnp
    from repro.kernels import ops
    rows = []
    name = "netflix"
    s = build_backend(name, "promips", mode="progressive", norm_strata=4)
    x, queries = load(name)
    s.search(queries, k=10)   # compile
    t0 = time.perf_counter()
    for _ in range(3):
        res = s.search(queries, k=10)
    us = (time.perf_counter() - t0) / (3 * len(queries)) * 1e6
    rows.append((f"device/{name}/progressive", us,
                 f"pages={res.pages / len(queries):.0f}"))
    # kernel-level verification scan (backend-aware default: Pallas on TPU,
    # jnp oracle here — mips_topk no longer silently pays interpret mode)
    import jax
    xr = jnp.asarray(x[:2048], jnp.float32)
    valid = jnp.ones(2048, bool)
    t0 = time.perf_counter()
    top, idx = ops.mips_topk(xr, jnp.asarray(queries[:4], jnp.float32), valid,
                             k=10)
    top.block_until_ready()
    us_k = (time.perf_counter() - t0) * 1e6 / 4
    mode = "pallas" if jax.default_backend() == "tpu" else "jnp-oracle"
    rows.append(("device/kernel/mips_topk", us_k, f"mode={mode}"))
    return rows


def bench_obs(quick: bool = True):
    """Observability tier (DESIGN.md §14): the tracer must be FREE when off
    and cheap when on, and the per-phase spans must account for the whole
    end-to-end latency.

    Three interleaved modes at the smoke scale, median-of-adjacent-pair
    ratios (same jitter defense as bench_search_runtime):

      baseline  span call sites monkeypatched to a null lambda — the code
                with no instrumentation at all
      disabled  real `repro.obs.trace.span` with tracing off (one bool
                check + a shared null context manager per site)
      enabled   tracing on, unfenced (the always-on production setting)

    scripts/ci.sh asserts overhead_disabled_frac < 1% and
    overhead_enabled_frac < 5%. Then the LARGE_N fused+prefilter point runs
    FENCED and the spans are grouped into the four pipeline phases
    (frontend / prefilter / verify / merge); their sum must land within 15%
    of the measured end-to-end batch latency (phase_sum_frac), or the spans
    are lying. One fenced batch is exported as a Chrome trace under
    results/obs/ — load it in Perfetto (the §14 worked example).
    """
    import json
    import os

    import jax.numpy as jnp

    from repro.core import ProMIPS
    from repro.core import runtime as rt
    from repro.core import search_fused as sf
    from repro.data.synthetic import mf_factors
    from repro.obs import metrics, trace

    rows = []
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

    # --- smoke-scale overhead: baseline vs disabled vs enabled -------------
    n, d, n_q = 8000, 64, 64
    x = mf_factors(n, d, 16, decay=0.5, seed=0, norm_tail=0.3)
    q = mf_factors(n_q, d, 16, decay=0.5, seed=1)
    pm = ProMIPS.build(x, m=8, c=0.9, p=0.6, k_p=8, k_sp=12, norm_strata=8)
    qj = jnp.asarray(q, jnp.float32)

    real_sf_span, real_rt_span = sf._span, rt._span

    def null_span(name, active=None, metric=None):
        return trace._NULL

    def set_mode(mode):
        sf._span = rt._span = (null_span if mode == "baseline"
                               else trace.span)
        if mode == "enabled":
            trace.enable(fence=False)
        else:
            trace.disable()

    def one_rep():
        t0 = time.perf_counter()
        ids, _, _ = pm.search(qj, k=10, verification="fused",
                              norm_adaptive=True, cs_prune=True)
        ids.block_until_ready()
        return time.perf_counter() - t0

    modes = ("baseline", "disabled", "enabled")
    times = {m: [] for m in modes}
    try:
        for m in modes:
            set_mode(m)
            one_rep()   # compile / warm
        rounds = 12 if quick else 30
        for _ in range(rounds):
            for m in modes:
                set_mode(m)
                times[m].append(one_rep())
    finally:
        sf._span, rt._span = real_sf_span, real_rt_span
        trace.disable()

    base_us = float(np.median(times["baseline"])) * 1e6
    smoke = {"n": n, "d": d, "batch": n_q, "rounds": rounds,
             "baseline_us_per_call": base_us}
    for m in ("disabled", "enabled"):
        # adjacent-pair ratios: mode m vs the baseline rep of the SAME round
        frac = float(np.median(
            [t / b for t, b in zip(times[m], times["baseline"])])) - 1.0
        smoke[f"overhead_{m}_frac"] = frac
        rows.append((f"obs/overhead_{m}", 0.0, f"{frac:+.4f}"))
    rec = {"smoke": smoke}

    # --- LARGE_N fenced per-phase breakdown --------------------------------
    cfg = LARGE_N
    x2, q2 = _large_corpus()
    pm2 = ProMIPS.build(x2, m=cfg["m"], c=cfg["c"], p=cfg["p0"],
                        k_p=cfg["k_p"], k_sp=cfg["k_sp"],
                        norm_strata=cfg["norm_strata"])
    qj2 = jnp.asarray(q2, jnp.float32)
    kw = dict(verification="fused", norm_adaptive=True, cs_prune=True,
              prefilter=True, prefilter_eps=PREFILTER_EPS)

    metrics.reset()
    metrics.enable()
    ids, _, st = pm2.search(qj2, k=cfg["k"], **kw)   # compile / warm
    ids.block_until_ready()
    st.to_dict()   # one pass through the stats_totals -> registry feed
    reps = 3 if quick else 8
    trace.enable(fence=True)
    trace.clear()
    try:
        for _ in range(reps):
            ids, _, _ = pm2.search(qj2, k=cfg["k"], **kw)
            ids.block_until_ready()
        spans = trace.spans()

        per_name: dict = {}
        for s in spans:
            per_name.setdefault(s["name"], []).append(s["dur_us"])
        span_means = {nm: float(np.sum(v)) / reps
                      for nm, v in sorted(per_name.items())}
        PHASES = {
            "frontend": ("select_frontend", "compensation"),
            "prefilter": ("prefilter_round1", "prefilter_round2"),
            "verify": ("plan_tile_round1", "plan_tile_round2",
                       "verify_round1", "verify_round2"),
            "merge": ("rescore",),
        }
        phases = {ph: float(sum(span_means.get(nm, 0.0) for nm in nms))
                  for ph, nms in PHASES.items()}
        e2e = span_means["search"]
        phase_sum_frac = sum(phases.values()) / e2e

        # a fresh single fenced batch as the committed Perfetto example
        trace.clear()
        ids, _, _ = pm2.search(qj2, k=cfg["k"], **kw)
        ids.block_until_ready()
        trace_path = os.path.join("results", "obs",
                                  "trace_large_n_fused.json")
        trace.export_chrome_trace(os.path.join(root, trace_path))
    finally:
        trace.disable()
        metrics.disable()

    snap = metrics.snapshot()
    undeclared = sorted(set(snap) - set(metrics.GLOSSARY))
    rec["large_n"] = {
        "n": cfg["n"], "d": cfg["d"], "batch": cfg["n_q"], "reps": reps,
        "fenced": True, "prefilter_eps": PREFILTER_EPS,
        "e2e_us": e2e, "phases_us": phases,
        "span_means_us": span_means, "phase_sum_frac": phase_sum_frac,
        "chrome_trace": trace_path,
    }
    rec["registered_metrics"] = sorted(snap)
    rec["undeclared"] = undeclared
    for ph, us in phases.items():
        rows.append((f"obs/large_n/{ph}", us / cfg["n_q"],
                     f"{100 * us / e2e:.1f}% of e2e"))
    rows.append(("obs/large_n/e2e", e2e / cfg["n_q"],
                 f"phase_sum_frac={phase_sum_frac:.3f}"))

    with open(os.path.join(root, "BENCH_obs.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def bench_robust(quick: bool = True):
    """Robustness tier (DESIGN.md §16): the three guarantees the robust
    subsystem sells, each with a number ci.sh can guard.

      wal overhead   interleaved mutate+search cycles on a plain vs WAL'd
                     (fsync="os") stream.  The guarded figure is
                     `wal_workload_overhead_frac` — durability cost on the
                     streaming workload (inserts + the queries they serve),
                     asserted <= 5%.  `wal_append_overhead_frac` is the
                     honest *bare* insert-path ratio, reported but NOT
                     guarded: a delta append is a memcpy + id-map update
                     (~0.1 ms/burst) while an acknowledged WAL record costs
                     an unavoidable crc32 + flush-to-OS (~0.3 ms at 1024
                     rows), so the bare ratio sits far above any useful
                     threshold and a guard there would only measure zlib
                     throughput.
      recovery       crash the WAL'd searcher (drop it), `recover()` from
                     snapshot + log; reports wall time, replayed rows/s,
                     and `recovery_bit_parity` — ids AND scores of the
                     recovered searcher exactly equal the live one's.
      degradation    open-loop overload burst into a DecodeEngine with the
                     ladder + deadlines enabled: shed rate, tier
                     transitions, and per-tier search p50/p99 + recall
                     against the full-budget tier, compared with the
                     policy's declared recall floors.

    Writes BENCH_robust.json at the repo root.
    """
    import json
    import os
    import shutil
    import tempfile

    from repro import api
    from repro.data.synthetic import mf_factors
    from repro.robust import recover

    n, d, n_q = (4000, 48, 32) if quick else (12000, 64, 64)
    x = mf_factors(n, d, 16, decay=0.5, seed=0, norm_tail=0.3)
    q = mf_factors(n_q, d, 16, decay=0.5, seed=1)
    rng = np.random.RandomState(2)
    rows_out = []
    rec = {"n": n, "d": d, "batch": n_q, "k": 10, "wal_fsync": "os"}

    tmp = tempfile.mkdtemp(prefix="bench_robust_")
    wal_dir = os.path.join(tmp, "wal")
    build_kw = dict(guarantee=api.GuaranteeConfig(c=0.9, p0=0.6, k=10),
                    m=8, k_p=8, k_sp=12, norm_strata=8, seed=0,
                    delta_capacity=8 * n)   # no auto-compaction mid-timing
    try:
        plain = api.build(x, backend="promips-stream", **build_kw)
        walled = api.build(x, backend="promips-stream", wal_dir=wal_dir,
                           **build_kw)

        # -- WAL overhead: interleaved cycles, median of adjacent ratios --
        cycles, burst = (10, 256) if quick else (16, 512)
        gid0 = 10 * n
        t_plain, t_wal, ta_plain, ta_wal = [], [], [], []
        def timed(fn, *a, **kw):
            t0 = time.perf_counter()
            fn(*a, **kw)
            return time.perf_counter() - t0

        for i in range(cycles):
            g = np.arange(gid0 + i * burst, gid0 + (i + 1) * burst)
            r = rng.randn(burst, d).astype(np.float32)
            # alternate which arm runs first each cycle: the second arm of
            # an adjacent pair sees warm caches/allocator state, so a fixed
            # order biases the ratio (measurably below 1.0 with plain
            # always first)
            if i % 2 == 0:
                ap = timed(plain.insert, g, r)
                aw = timed(walled.insert, g, r)
            else:
                aw = timed(walled.insert, g, r)
                ap = timed(plain.insert, g, r)
            # untimed warmups: a delta-size bucket crossing triggers an XLA
            # recompile (~100ms) on the FIRST search at the new shape;
            # absorbing it here keeps the timed pair at steady state
            plain.search(q, k=10)
            walled.search(q, k=10)
            # searches are pure: best-of-3 per arm discards scheduler
            # jitter (single-shot spread here is ~+-10%, which would drown
            # a 5% guard)
            if i % 2 == 0:
                sp = min(timed(plain.search, q, k=10) for _ in range(3))
                sw = min(timed(walled.search, q, k=10) for _ in range(3))
            else:
                sw = min(timed(walled.search, q, k=10) for _ in range(3))
                sp = min(timed(plain.search, q, k=10) for _ in range(3))
            ta_plain.append(ap)
            ta_wal.append(aw)
            t_plain.append(ap + sp)
            t_wal.append(aw + sw)
        drop = 2                                    # warmup cycles
        app = (np.asarray(ta_wal[drop:]) / np.asarray(ta_plain[drop:]))
        rec["wal_append_overhead_frac"] = float(np.median(app) - 1.0)
        # totals, not median-of-ratios: the search term dominates each
        # cycle and its jitter (~+-10% per pair) swamps the per-pair
        # ratio; summing over the alternating-order cycles averages the
        # order effect AND the jitter out
        rec["wal_workload_overhead_frac"] = float(
            np.sum(t_wal[drop:]) / np.sum(t_plain[drop:]) - 1.0)
        rec["wal_append_us_per_burst"] = float(
            np.mean(ta_wal[drop:]) - np.mean(ta_plain[drop:])) * 1e6
        rows_out.append((
            "robust/wal_workload", float(np.mean(t_wal[drop:])) * 1e6,
            f"overhead_frac={rec['wal_workload_overhead_frac']:.4f}"))
        rows_out.append((
            "robust/wal_append", float(np.mean(ta_wal[drop:])) * 1e6
            / burst,
            f"bare_insert_overhead_frac={rec['wal_append_overhead_frac']:.3f}"
            " (informational; see docstring)"))

        # a delete through the log, so replay covers both row opcodes
        dels = np.arange(gid0, gid0 + burst)
        plain.delete(dels)
        walled.delete(dels)

        # -- recovery: drop the live searcher, restore from snapshot+WAL --
        live_res = walled.search(q, k=10)
        replay_records = walled.wal_lag()
        replay_rows = cycles * burst + burst        # inserts + the delete
        t0 = time.perf_counter()
        recovered = recover(wal_dir, attach=False)
        rec["recovery_s"] = time.perf_counter() - t0
        rec["replay_records"] = int(replay_records)
        rec["replay_rows_per_s"] = replay_rows / rec["recovery_s"]
        got = recovered.search(q, k=10)
        rec["recovery_bit_parity"] = bool(
            np.array_equal(live_res.ids, got.ids)
            and np.array_equal(live_res.scores, got.scores))
        rows_out.append((
            "robust/recovery", rec["recovery_s"] * 1e6,
            f"rows_per_s={rec['replay_rows_per_s']:.0f};"
            f"bit_parity={rec['recovery_bit_parity']}"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # -- degradation ladder under open-loop overload ----------------------
    import jax
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import DecodeEngine, DegradationPolicy

    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    pol = DegradationPolicy(tiers=(1.0, 0.5, 0.25),
                            recall_floors=(0.95, 0.8, 0.5),
                            queue_high=3, queue_low=1, patience=2,
                            recovery=4)
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                       logits_mode="promips", degradation=pol, max_queue=6,
                       default_deadline_s=60.0)
    vrng = np.random.RandomState(3)
    n_req = 24 if quick else 64
    admitted = 0
    max_tier = 0
    t0 = time.perf_counter()
    for i in range(n_req):                          # open loop: 3 per step
        r = eng.submit(vrng.randint(1, cfg.vocab, size=5),
                       max_new_tokens=6)
        admitted += r is not None
        if i % 3 == 2:
            eng.step()
            max_tier = max(max_tier, eng.tier)
    while eng.queue or eng.active.any():
        eng.step()
        max_tier = max(max_tier, eng.tier)
    overload_s = time.perf_counter() - t0
    for _ in range(2 * (pol.recovery + 1)):
        eng.step()      # idle calm ticks: the ladder steps back up to full
    rec["overload"] = {
        "requests": n_req, "admitted": admitted, "shed": eng.shed,
        "shed_rate": eng.shed / n_req, "stepdowns": eng.stepdowns,
        "stepups": eng.stepups, "deadline_drops": eng.deadline_drops,
        "max_tier_reached": max_tier, "wall_s": overload_s,
        "final_state": eng.health()["state"],
    }
    rows_out.append((
        "robust/overload", overload_s / n_req * 1e6,
        f"shed_rate={rec['overload']['shed_rate']:.2f};"
        f"stepdowns={eng.stepdowns};max_tier={max_tier}"))

    # -- per-tier latency percentiles + recall vs the full-budget tier ----
    # Measured on the mf_factors stream index (the repo's benchmark MIPS
    # corpus), replicating the engine's tier->budget resolution exactly
    # (float tier = fraction of the index's block count, budget AND budget2
    # — `DecodeEngine._resolve_tier_budgets` / `_tier_runtime`). The floors
    # here are what a DegradationPolicy on this corpus can honestly
    # declare; ci.sh guards measured >= declared. Budget truncation is
    # best-first (`core.search_device.truncate_union`), which is what
    # makes these floors hold — layout-order truncation scores ~0 here.
    import dataclasses

    from repro.core.runtime import RuntimeConfig

    tier_fracs = (1.0, 0.5, 0.25)
    tier_floors = (0.95, 0.85, 0.65)
    nb = plain.inner.meta.n_blocks
    rt0 = RuntimeConfig(mode="two_phase", verification="batched",
                        norm_adaptive=True, cs_prune=True)
    full = plain.search(q, k=10, runtime=rt0)
    tiers = []
    reps = 20 if quick else 50
    for t_i, (frac, floor) in enumerate(zip(tier_fracs, tier_floors)):
        b = None if frac >= 1.0 else max(1, round(nb * frac))
        rt = (rt0 if b is None
              else dataclasses.replace(rt0, budget=b, budget2=b))
        plain.search(q, k=10, runtime=rt)           # warm
        lat = []
        for _ in range(reps):
            t1 = time.perf_counter()
            res = plain.search(q, k=10, runtime=rt)
            lat.append((time.perf_counter() - t1) / n_q * 1e6)
        recall = float(np.mean([
            len(set(a.tolist()) & set(b_.tolist())) / 10
            for a, b_ in zip(res.ids, full.ids)]))
        tiers.append({
            "tier": t_i, "frac": frac, "budget": b,
            "p50_us": float(np.percentile(lat, 50)),
            "p99_us": float(np.percentile(lat, 99)),
            "pages_per_query": float(res.stats["pages"]) / n_q,
            "recall_vs_full": recall, "declared_floor": floor,
            "meets_floor": bool(recall >= floor),
        })
        rows_out.append((
            f"robust/tier{t_i}_search", tiers[-1]["p50_us"],
            f"p99={tiers[-1]['p99_us']:.0f}us;recall={recall:.3f};"
            f"floor={floor}"))
    rec["tiers"] = tiers

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_robust.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rows_out


def bench_serve(quick: bool = True):
    """Serve-frontend tier (DESIGN.md §17): the numbers the continuous-
    batching engine + hot-query cache sell, each guarded by ci.sh.

      ramp       open-loop Zipfian load whose arrival rate ramps up until
                 it trips the degradation ladder and the admission cap:
                 p50/p99 request latency + queue wait, completed queries/s,
                 shed/expired fractions, per-tier step occupancy, cache hit
                 rate. Guarded: p99 <= declared bound, queries/s >= floor.
      cache      the same hot Zipfian pool replayed at saturation (arrivals
                 due immediately, so the engine is the bottleneck) through
                 a cache-on and a cache-off engine, alternating order per
                 rep; rep 0 absorbs compiles and is dropped. Guarded:
                 cache-on throughput >= cache-off.
      cold       distinct prompts decoded cache-on and cache-off — token
                 streams must be BIT-identical (all misses: the cache may
                 not change what is decoded). Guarded.
      inactive   one request on a 4-slot engine: the decode search may
                 touch only the active row (searched_rows == decode steps;
                 the pre-§17 engine searched all 4 and counted their
                 pages). Guarded structurally, pages vs a 1-slot engine
                 reported alongside.

    Writes BENCH_serve.json at the repo root.
    """
    import json
    import os

    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import (DecodeEngine, DegradationPolicy, LoadgenConfig,
                             generate, run_load)

    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    pkw = dict(m=8, c=0.95, p=0.95)

    def mk(**kw):
        return DecodeEngine(params, cfg, max_len=64, logits_mode="promips",
                            promips_kwargs=dict(pkw), **kw)

    rows_out = []
    rec = {"model": "tinyllama-1.1b(reduced)", "vocab": int(cfg.vocab),
           "d_model": int(cfg.d_model),
           # declared SLA bounds ci.sh guards the ramp arm against (wide
           # margins over the measured values on this CPU box: the guard
           # catches a serve-path collapse, not scheduler jitter)
           # hot_speedup_floor is 0.9, not 1.0: at vocab=512 the
           # transformer forward dominates the step, so the cache's saved
           # search time sits inside run-to-run scheduler noise (~±5%);
           # the guard pairs it with the STRUCTURAL check that cache-on
           # actually searched fewer rows, which is noise-free.
           # measured on this box: p99 4.5-8.3s, qps 1.1-1.8 across runs
           "declared": {"latency_p99_bound_s": 15.0,
                        "queries_per_s_floor": 0.5,
                        "hot_speedup_floor": 0.9}}

    # -- ramp: trip the ladder + the admission cap on purpose -------------
    n_req = 48 if quick else 160
    # recovery=3: the drain tail after the last arrival is the only calm
    # stretch the ladder gets to climb back in before the run ends, and it
    # is ~6-10 steps long at this request mix
    pol = DegradationPolicy(tiers=(1.0, 0.5, 0.25),
                            recall_floors=(0.95, 0.8, 0.5),
                            queue_high=4, queue_low=1, patience=2,
                            recovery=3)
    eng = mk(batch_slots=4, degradation=pol, max_queue=8, result_cache=256)
    # the reduced engine saturates around ~7 qps on the CPU oracle: start
    # under capacity and ramp to ~3x over it, so the run crosses from "ok"
    # into the ladder + shedding instead of collapsing from t=0
    lg_ramp = LoadgenConfig(
        rate_qps=4.0, n_requests=n_req, zipf_s=1.1, pool_size=12,
        prompt_lens=(4, 8), max_new_tokens_choices=(4, 8),
        deadline_mix=((None, 3.0), (1.0, 1.0)), ramp=5.0, seed=0)
    # replay the identical schedule once UNTIMED first: every (group size,
    # prompt length) prefill shape, every miss-row search width and every
    # ladder tier XLA-compiles on first sight, and those multi-second
    # stalls would otherwise be measured as queue wait / latency. The
    # timed replay below then runs compile-free on a warm engine; ladder
    # and cache counters are reported as deltas across it.
    run_load(eng, generate(lg_ramp, cfg.vocab), max_wall_s=120.0)
    sd0, su0 = eng.stepdowns, eng.stepups
    h0, m0 = eng.qcache.hits, eng.qcache.misses
    ramp = run_load(eng, generate(lg_ramp, cfg.vocab), max_wall_s=120.0)
    ramp["stepdowns"] -= sd0
    ramp["stepups"] -= su0
    ramp["cache"] = dict(eng.qcache.stats())
    dh, dm = eng.qcache.hits - h0, eng.qcache.misses - m0
    ramp["cache"].update(hits=dh, misses=dm,
                         hit_rate=dh / max(dh + dm, 1))
    rec["ramp"] = ramp
    rec["ramp"]["config"] = {"rate_qps": lg_ramp.rate_qps,
                             "ramp": lg_ramp.ramp, "zipf_s": lg_ramp.zipf_s,
                             "pool_size": lg_ramp.pool_size}
    rows_out.append((
        "serve/ramp_p99", ramp["latency_p99_s"] * 1e6,
        f"p50={ramp['latency_p50_s']*1e3:.1f}ms;"
        f"qps={ramp['queries_per_s']:.1f};shed={ramp['shed_frac']:.2f};"
        f"expired={ramp['expired_frac']:.2f};"
        f"hit_rate={ramp['cache']['hit_rate']:.2f};"
        f"max_tier={ramp['max_tier']}"))

    # -- cache on/off throughput at saturation ----------------------------
    reps = 3 if quick else 5
    lg_hot = LoadgenConfig(
        rate_qps=1e5, n_requests=(32 if quick else 96), zipf_s=1.2,
        pool_size=8, prompt_lens=(6, 6), max_new_tokens_choices=(6,),
        ramp=1.0, seed=1)
    eng_on = mk(batch_slots=4, result_cache=512)
    eng_off = mk(batch_slots=4, result_cache=0)
    walls = {"on": [], "off": []}
    for r in range(reps + 1):           # rep 0 = compile warmup, dropped
        order = (("on", eng_on), ("off", eng_off)) if r % 2 == 0 else \
                (("off", eng_off), ("on", eng_on))
        for label, e in order:
            s = run_load(e, generate(lg_hot, cfg.vocab), max_wall_s=120.0)
            if r > 0:
                walls[label].append(s["wall_s"])
            if label == "on":
                hot_on = s
            else:
                hot_off = s
    qps_on = lg_hot.n_requests / float(np.median(walls["on"]))
    qps_off = lg_hot.n_requests / float(np.median(walls["off"]))
    rec["hot"] = {
        "cache_on_qps": qps_on, "cache_off_qps": qps_off,
        "speedup_cache_on_vs_off": qps_on / qps_off,
        "cache_hit_rate": eng_on.qcache.hit_rate,
        "searched_rows_on": eng_on.searched_rows,
        "searched_rows_off": eng_off.searched_rows,
        "zipf_s": lg_hot.zipf_s, "pool_size": lg_hot.pool_size,
        "reps": reps,
    }
    rows_out.append((
        "serve/hot_zipf", 1e6 / qps_on,
        f"qps_on={qps_on:.1f};qps_off={qps_off:.1f};"
        f"speedup=x{qps_on/qps_off:.2f};"
        f"hit_rate={eng_on.qcache.hit_rate:.2f}"))

    # -- cold bit-parity --------------------------------------------------
    prng = np.random.RandomState(5)
    prompts = [prng.randint(1, cfg.vocab, size=6) for _ in range(6)]
    tokens = {}
    for cap in (0, 64):
        e = mk(batch_slots=2, result_cache=cap)
        reqs = [e.submit(p, max_new_tokens=5) for p in prompts]
        e.run()
        tokens[cap] = [r.out_tokens for r in reqs]
    rec["cache_cold_bit_parity"] = bool(tokens[0] == tokens[64])
    rows_out.append(("serve/cold_parity", 0.0,
                     f"bit_parity={rec['cache_cold_bit_parity']}"))

    # -- inactive-slot page accounting ------------------------------------
    prompt = prng.randint(1, cfg.vocab, size=6)
    pages = {}
    for b in (1, 4):
        e = mk(batch_slots=b, result_cache=0)
        r = e.submit(prompt, max_new_tokens=6)
        e.run()
        pages[b] = (e.pages, e.searched_rows, len(r.out_tokens) - 1)
    rec["inactive_slot_pages_zero"] = bool(pages[4][1] == pages[4][2])
    rec["pages_single_req_4slots"] = int(pages[4][0])
    rec["pages_single_req_1slot"] = int(pages[1][0])
    rows_out.append((
        "serve/inactive_pages", 0.0,
        f"zero_inactive={rec['inactive_slot_pages_zero']};"
        f"pages_4slot={pages[4][0]};pages_1slot={pages[1][0]}"))

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_serve.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rows_out
