"""Shared benchmark plumbing: datasets, method registry, measurement."""
from __future__ import annotations

import os
import sys
import time

import numpy as np

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.baselines import ExactMIPS, H2ALSH, PQBased, RangeLSH  # noqa: E402
from repro.core import ProMIPS, overall_ratio, recall_at_k  # noqa: E402
from repro.data.synthetic import DATASETS, paper_dataset, paper_queries  # noqa: E402

# CPU-budget sizes for the harness (full proxy sizes live in data/synthetic.py;
# EXPERIMENTS.md records the scaling). Paper m per dataset: §VIII-A4.
BENCH_SETS = {
    "netflix": dict(n=17770, m=6, page_bytes=4096),
    "yahoo": dict(n=20000, m=8, page_bytes=4096),
    "p53": dict(n=4000, m=6, page_bytes=65536),
    "sift": dict(n=30000, m=10, page_bytes=4096),
}
N_QUERIES = 20
SEEK_US = 50.0  # modeled 4 KB random-read latency for 'total time' (Fig 9)

_cache = {}


def load(name):
    if name not in _cache:
        spec = BENCH_SETS[name]
        x = paper_dataset(name)[: spec["n"]]
        q = paper_queries(name, N_QUERIES)
        _cache[name] = (np.ascontiguousarray(x), q)
    return _cache[name]


def build_promips(name, c=0.9, p=0.5, progressive=True, **kw):
    x, _ = load(name)
    spec = BENCH_SETS[name]
    t0 = time.time()
    pm = ProMIPS.build(x, m=spec["m"], c=c, p=p, page_bytes=spec["page_bytes"],
                       norm_strata=4 if progressive else 1, **kw)
    pm.build_seconds = time.time() - t0
    return pm


def build_baseline(name, cls, **kw):
    x, _ = load(name)
    spec = BENCH_SETS[name]
    m = cls(page_bytes=spec["page_bytes"], **kw)
    m.build(x)
    return m


def promips_searcher(pm, progressive, k):
    if progressive:
        return lambda q: pm.search_host_progressive(q, k=k)
    return lambda q: pm.search_host(q, k=k)


def evaluate(search_fn, name, k):
    """Run all queries; returns metrics dict (ratio, recall, pages, cpu_us)."""
    x, queries = load(name)
    from repro.baselines.exact import exact_topk
    eids, escores = exact_topk(x, queries, k)
    ratios, recalls, pages, times = [], [], [], []
    for i in range(len(queries)):
        t0 = time.perf_counter()
        out = search_fn(queries[i])
        dt = time.perf_counter() - t0
        ids, scores, st = out
        pg = st.pages if hasattr(st, "pages") else st["pages"]
        ratios.append(overall_ratio(np.asarray(scores), escores[i]))
        recalls.append(recall_at_k(np.asarray(ids), eids[i]))
        pages.append(pg)
        times.append(dt * 1e6)
    return dict(ratio=float(np.mean(ratios)), recall=float(np.mean(recalls)),
                pages=float(np.mean(pages)), cpu_us=float(np.mean(times)),
                total_us=float(np.mean(times) + np.mean(pages) * SEEK_US),
                guarantee_frac=float(np.mean([r >= 0.9 for r in ratios])))


def emit(rows, out_list=None):
    """Print the required ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
        if out_list is not None:
            out_list.append({"name": name, "us_per_call": us, "derived": derived})
