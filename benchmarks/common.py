"""Shared benchmark plumbing: datasets, registry-driven builds, measurement.

All method construction goes through the unified `repro.api` facade — a
benchmark names a registry backend plus options, never a concrete class, so
adding a method to the sweep is a registry entry (DESIGN.md §9).
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro import api  # noqa: E402
from repro.core import overall_ratio, recall_at_k  # noqa: E402
from repro.data.synthetic import DATASETS, paper_dataset, paper_queries  # noqa: E402

# CPU-budget sizes for the harness (full proxy sizes live in data/synthetic.py;
# EXPERIMENTS.md records the scaling). Paper m per dataset: §VIII-A4.
BENCH_SETS = {
    "netflix": dict(n=17770, m=6, page_bytes=4096),
    "yahoo": dict(n=20000, m=8, page_bytes=4096),
    "p53": dict(n=4000, m=6, page_bytes=65536),
    "sift": dict(n=30000, m=10, page_bytes=4096),
}
N_QUERIES = 20
SEEK_US = 50.0  # modeled 4 KB random-read latency for 'total time' (Fig 9)

# The accuracy-figure sweep: label -> (backend, build opts). "promips+" is
# the beyond-paper progressive/norm-adaptive configuration of the same
# backend; everything is a registry lookup, no per-method code paths. The
# ProMIPS entries select search_path="host" — the paper-faithful sequential
# search whose resident-4KB-page accounting IS the figures' metric
# (device-runtime latency has its own bench: run.py --quick / --api).
METHOD_SPECS = {
    "promips": ("promips", dict(search_path="host")),
    "promips+": ("promips", dict(mode="progressive", search_path="host")),
    "h2alsh": ("h2alsh", {}),
    "rangelsh": ("rangelsh", {}),
    "pq": ("pq", dict(n_cells=32)),
}

_cache = {}


def load(name):
    if name not in _cache:
        spec = BENCH_SETS[name]
        x = paper_dataset(name)[: spec["n"]]
        q = paper_queries(name, N_QUERIES)
        _cache[name] = (np.ascontiguousarray(x), q)
    return _cache[name]


def build_method(name, label, c=0.9, p0=0.5, **extra):
    """Build one sweep method on one dataset through the facade."""
    backend, opts = METHOD_SPECS[label]
    return build_backend(name, backend, c=c, p0=p0, **dict(opts, **extra))


def build_backend(name, backend, c=0.9, p0=0.5, k=10, **opts):
    """`api.build` with the dataset's page size / paper m wired in."""
    x, _ = load(name)
    spec = BENCH_SETS[name]
    if backend == "promips":
        opts.setdefault("m", spec["m"])
    return api.build(x, backend=backend,
                     guarantee=api.GuaranteeConfig(c=c, p0=p0, k=k),
                     page_bytes=spec["page_bytes"], seed=0, **opts)


def evaluate(searcher, name, k):
    """Per-query facade search; returns metrics dict (ratio, recall, pages,
    cpu_us). Uniform across every backend: one `SearchResult` contract."""
    x, queries = load(name)
    from repro.baselines.exact import exact_topk
    eids, escores = exact_topk(x, queries, k)
    searcher.search(queries[0], k=k)  # warm-up: jit compile / lazy host state
    ratios, recalls, pages, times = [], [], [], []
    for i in range(len(queries)):
        res = searcher.search(queries[i], k=k)
        ratios.append(overall_ratio(res.scores[0], escores[i]))
        recalls.append(recall_at_k(res.ids[0], eids[i]))
        pages.append(res.pages)
        times.append(res.wall_time_s * 1e6)
    return dict(ratio=float(np.mean(ratios)), recall=float(np.mean(recalls)),
                pages=float(np.mean(pages)), cpu_us=float(np.mean(times)),
                total_us=float(np.mean(times) + np.mean(pages) * SEEK_US),
                guarantee_frac=float(np.mean([r >= 0.9 for r in ratios])))


def emit(rows, out_list=None):
    """Print the required ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
        if out_list is not None:
            out_list.append({"name": name, "us_per_call": us, "derived": derived})
