# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: reproduces every evaluation axis of paper §VIII on
shape-matched synthetic proxies (see benchmarks/common.py for sizes).

  PYTHONPATH=src python -m benchmarks.run [--only fig5]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")))
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

# --sharded simulates a pod on this host: force 8 host devices BEFORE any
# import below can initialize the jax backend (XLA reads the flag once).
if "--sharded" in sys.argv:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import functools  # noqa: E402
import subprocess  # noqa: E402
from datetime import datetime, timezone  # noqa: E402

from benchmarks import common  # noqa: E402
from benchmarks import paper_figures as F  # noqa: E402

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
# Repo-root records the bench functions (re)write; every run APPENDS the
# fresh record to results/bench/history.jsonl with a timestamp, so the
# BENCH_*.json numbers gain a trajectory instead of being overwritten.
BENCH_FILES = ("BENCH_search.json", "BENCH_stream.json", "BENCH_api.json",
               "BENCH_sharded.json", "BENCH_obs.json", "BENCH_tune.json",
               "BENCH_robust.json", "BENCH_serve.json")


@functools.lru_cache(maxsize=1)
def _provenance() -> dict:
    """Code + toolchain identity stamped into every history record, so a
    number can always be traced back to the commit and jax build that
    produced it (computed once per process; 'unknown' outside a checkout)."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=ROOT, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        commit = "unknown"
    import jax
    return {"commit": commit, "jax_version": jax.__version__,
            "platform": jax.default_backend()}


def _append_history(out_dir: str, bench: str, rows, t_start: float) -> None:
    ts = datetime.now(timezone.utc).isoformat(timespec="seconds")
    entry = {"ts": ts, "bench": bench, **_provenance(),
             "rows": [{"name": n, "us_per_call": u, "derived": d}
                      for n, u, d in rows]}
    for fname in BENCH_FILES:
        path = os.path.join(ROOT, fname)
        if os.path.exists(path) and os.path.getmtime(path) >= t_start:
            with open(path) as f:
                entry.setdefault("records", {})[fname] = json.load(f)
    with open(os.path.join(out_dir, "history.jsonl"), "a") as f:
        f.write(json.dumps(entry) + "\n")

BENCHES = [
    ("fig4a_index_size", F.fig4a_index_size),
    ("fig4b_preprocessing_time", F.fig4b_preprocessing_time),
    ("fig5-9_ratio_recall_pages_time", F.fig5_6_overall_ratio_recall),
    ("fig10_impact_of_c", F.fig10_impact_of_c),
    ("fig11_impact_of_p", F.fig11_impact_of_p),
    ("table2_complexity_scaling", F.table2_complexity_scaling),
    ("ablation_beyond_paper", F.ablation_beyond_paper),
    ("search_runtime", F.bench_search_runtime),
    ("device_throughput", F.bench_device_throughput),
    ("stream_churn", lambda: F.bench_stream(quick=False)),
    ("api_registry", lambda: F.bench_api(quick=False)),
    ("sharded_fanout", lambda: F.bench_sharded(quick=False)),
    ("obs_breakdown", lambda: F.bench_obs(quick=False)),
    ("tune_autotuner", lambda: F.bench_tune(smoke=True)),
    ("robust_durability", lambda: F.bench_robust(quick=False)),
    ("serve_frontend", lambda: F.bench_serve(quick=False)),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--quick", action="store_true",
                    help="fast smoke: host vs scan/batched/fused runtime "
                         "comparison plus the n=100k large-n point where "
                         "the fused path must beat the exact scan (writes "
                         "BENCH_search.json; ~30s)")
    ap.add_argument("--stream", action="store_true",
                    help="streaming-index smoke: insert throughput + search "
                         "latency vs delta fraction (writes BENCH_stream.json)")
    ap.add_argument("--api", action="store_true",
                    help="registry sweep: build time, on-disk index bytes, "
                         "us/query and recall vs exact for every registered "
                         "backend (writes BENCH_api.json)")
    ap.add_argument("--sharded", action="store_true",
                    help="sharded fan-out smoke: in-graph fused vs batched "
                         "verification inside shard_map at n=100k, us/query "
                         "and recall vs device count over 8 forced host "
                         "devices (writes BENCH_sharded.json)")
    ap.add_argument("--obs", action="store_true",
                    help="observability smoke: span-tracer overhead on/off "
                         "at smoke scale plus the per-phase latency "
                         "breakdown (frontend/prefilter/verify/merge) at "
                         "the large-n point, with a Chrome-trace export "
                         "(writes BENCH_obs.json)")
    ap.add_argument("--tune", action="store_true",
                    help="offline autotuner bench: coordinate-descent "
                         "tuning run on a temp cache, tuned-vs-hand-picked "
                         "interleaved ratio, parity + empty-cache-noop "
                         "audits (writes BENCH_tune.json)")
    ap.add_argument("--robust", action="store_true",
                    help="robustness smoke: WAL'd vs plain stream workload "
                         "overhead, crash-recovery wall time + replay "
                         "rows/s + bit-parity, and the serve degradation "
                         "ladder under open-loop overload with per-tier "
                         "p50/p99 + recall vs declared floors (writes "
                         "BENCH_robust.json)")
    ap.add_argument("--serve", action="store_true",
                    help="serve-frontend smoke: open-loop Zipfian ramp "
                         "through the degradation ladder (p50/p99 latency, "
                         "queue wait, qps, shed/expired fractions, cache "
                         "hit rate, tier occupancy), cache-on vs cache-off "
                         "throughput at saturation, cold-traffic cache "
                         "bit-parity and the inactive-slot page-accounting "
                         "check (writes BENCH_serve.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="with --tune: smallest cutout + tightest budget "
                         "(the ci.sh tune tier)")
    args = ap.parse_args()

    if args.quick:
        benches = [("search_runtime", lambda: F.bench_search_runtime(quick=True))]
    elif args.stream:
        benches = [("stream_churn", lambda: F.bench_stream(quick=True))]
    elif args.api:
        benches = [("api_registry", lambda: F.bench_api(quick=True))]
    elif args.sharded:
        benches = [("sharded_fanout", lambda: F.bench_sharded(quick=True))]
    elif args.obs:
        benches = [("obs_breakdown", lambda: F.bench_obs(quick=True))]
    elif args.tune:
        benches = [("tune_autotuner", lambda: F.bench_tune(smoke=args.smoke))]
    elif args.robust:
        benches = [("robust_durability", lambda: F.bench_robust(quick=True))]
    elif args.serve:
        benches = [("serve_frontend", lambda: F.bench_serve(quick=True))]
    else:
        benches = BENCHES
    os.makedirs(args.out, exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        rows = fn()
        common.emit(rows)
        sys.stdout.flush()
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in rows], f, indent=1)
        _append_history(args.out, name, rows, t0)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
