# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: reproduces every evaluation axis of paper §VIII on
shape-matched synthetic proxies (see benchmarks/common.py for sizes).

  PYTHONPATH=src python -m benchmarks.run [--only fig5]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")))
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from benchmarks import common  # noqa: E402
from benchmarks import paper_figures as F  # noqa: E402

BENCHES = [
    ("fig4a_index_size", F.fig4a_index_size),
    ("fig4b_preprocessing_time", F.fig4b_preprocessing_time),
    ("fig5-9_ratio_recall_pages_time", F.fig5_6_overall_ratio_recall),
    ("fig10_impact_of_c", F.fig10_impact_of_c),
    ("fig11_impact_of_p", F.fig11_impact_of_p),
    ("table2_complexity_scaling", F.table2_complexity_scaling),
    ("ablation_beyond_paper", F.ablation_beyond_paper),
    ("search_runtime", F.bench_search_runtime),
    ("device_throughput", F.bench_device_throughput),
    ("stream_churn", lambda: F.bench_stream(quick=False)),
    ("api_registry", lambda: F.bench_api(quick=False)),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--quick", action="store_true",
                    help="fast smoke: host-vs-scan-vs-batched runtime "
                         "comparison only (writes BENCH_search.json)")
    ap.add_argument("--stream", action="store_true",
                    help="streaming-index smoke: insert throughput + search "
                         "latency vs delta fraction (writes BENCH_stream.json)")
    ap.add_argument("--api", action="store_true",
                    help="registry sweep: build time, on-disk index bytes, "
                         "us/query and recall vs exact for every registered "
                         "backend (writes BENCH_api.json)")
    args = ap.parse_args()

    if args.quick:
        benches = [("search_runtime", lambda: F.bench_search_runtime(quick=True))]
    elif args.stream:
        benches = [("stream_churn", lambda: F.bench_stream(quick=True))]
    elif args.api:
        benches = [("api_registry", lambda: F.bench_api(quick=True))]
    else:
        benches = BENCHES
    os.makedirs(args.out, exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        rows = fn()
        common.emit(rows)
        sys.stdout.flush()
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in rows], f, indent=1)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
