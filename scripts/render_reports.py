#!/usr/bin/env python3
"""Render §Dry-run and §Roofline tables in EXPERIMENTS.md from results/."""
import glob
import json
import os
import re

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table():
    rows = ["| arch | shape | mesh | status | mem/chip (arg+temp) GB | HLO flops | collectives (top) | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    recs = []
    for f in glob.glob(f"{ROOT}/results/dryrun/*/*/*.json"):
        recs.append(json.load(open(f)))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r.get("multi_pod", False)))
    for r in recs:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | {r['status']} | — | — | — | — |")
            continue
        mem = (r.get("argument_size_in_bytes", 0) + r.get("temp_size_in_bytes", 0)) / 1e9
        coll = r.get("collective_bytes", {})
        top = max(coll.items(), key=lambda kv: kv[1])[0] if coll else "-"
        topv = coll.get(top, 0) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{r.get('argument_size_in_bytes',0)/1e9:.1f}+{r.get('temp_size_in_bytes',0)/1e9:.1f}"
            f"={mem:.1f} | {r.get('hlo_flops',0):.2e} | {top} {topv:.1f}GB | "
            f"{r.get('compile_s','-')} |")
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"].startswith("skipped"))
    head = (f"\n**{len(recs)} cells: {ok} ok, {skip} annotated skips, "
            f"{len(recs)-ok-skip} failures.**\n\n")
    return head + "\n".join(rows) + "\n"


def roofline_table():
    rows = ["| arch | shape | t_comp s | t_mem s | t_coll s | dominant | useful-FLOP frac | bound_mfu | one-line fix for the dominant term |",
            "|---|---|---|---|---|---|---|---|---|"]
    fixes = {
        "compute": "raise arithmetic intensity (larger per-chip tiles, bf16 everywhere)",
        "memory": "fuse attention/gating into the Pallas kernels; fewer microbatches",
        "collective": "sequence-parallel TP (reduce-scatter), FSDP weight gather, EP all-to-all",
    }
    recs = []
    for f in sorted(glob.glob(f"{ROOT}/results/roofline/*.json")):
        recs.append(json.load(open(f)))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    for r in recs:
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']} | — | — | — |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flops_frac']:.2f} | "
            f"{r['bound_mfu']:.3f} | {fixes[r['dominant']]} |")
    return "\n" + "\n".join(rows) + "\n"


def obs_report():
    """Per-phase latency table from BENCH_obs.json -> results/obs/report.md
    (DESIGN.md §14's numbers, regenerated per run)."""
    src = os.path.join(ROOT, "BENCH_obs.json")
    if not os.path.exists(src):
        return None
    rec = json.load(open(src))
    ln = rec["large_n"]
    lines = [
        "# Observability report (BENCH_obs.json)",
        "",
        f"Smoke overhead (n={rec['smoke']['n']}, "
        f"{rec['smoke']['rounds']} interleaved rounds): "
        f"disabled {rec['smoke']['overhead_disabled_frac']:+.4f}, "
        f"enabled {rec['smoke']['overhead_enabled_frac']:+.4f} "
        "vs the uninstrumented baseline.",
        "",
        f"Large-n fenced breakdown (n={ln['n']}, batch={ln['batch']}, "
        f"reps={ln['reps']}, e2e {ln['e2e_us']:.0f} us/batch, "
        f"phase sum / e2e = {ln['phase_sum_frac']:.3f}):",
        "",
        "| phase | us/batch | % of e2e |",
        "|---|---|---|",
    ]
    for ph, us in ln["phases_us"].items():
        lines.append(f"| {ph} | {us:.0f} | {100 * us / ln['e2e_us']:.1f} |")
    lines += ["", f"Chrome trace (load in Perfetto): `{ln['chrome_trace']}`",
              f"Registered metrics: {len(rec['registered_metrics'])} "
              f"(undeclared: {rec['undeclared'] or 'none'})", ""]
    out = os.path.join(ROOT, "results", "obs", "report.md")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    open(out, "w").write("\n".join(lines))
    return out


def splice(text, start, end, payload):
    pat = re.compile(re.escape(start) + r".*?" + re.escape(end), re.S)
    return pat.sub(start + "\n" + payload + end, text)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    if os.path.exists(path):
        text = open(path).read()
        if os.path.isdir(f"{ROOT}/results/dryrun"):
            text = splice(text, "<!-- DRYRUN_TABLE_START -->",
                          "<!-- DRYRUN_TABLE_END -->", dryrun_table())
        if os.path.isdir(f"{ROOT}/results/roofline"):
            text = splice(text, "<!-- ROOFLINE_TABLE_START -->",
                          "<!-- ROOFLINE_TABLE_END -->", roofline_table())
        open(path, "w").write(text)
        print("EXPERIMENTS.md tables refreshed")
    out = obs_report()
    if out:
        print(f"obs report written to {out}")


if __name__ == "__main__":
    main()
