#!/usr/bin/env bash
# Tier-1 verify + fast perf smoke. Run from anywhere; results land in
# results/bench/ and the runtime comparison in BENCH_search.json (repo root)
# so the perf trajectory is recorded per commit.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (minus the stream/api/guarantee tiers, run separately below) =="
python -m pytest -q --ignore=tests/test_stream.py --ignore=tests/test_api.py \
    --ignore=tests/test_guarantees.py

echo "== streaming-index tier (insert/delete/compact paths) =="
python -m pytest -q tests/test_stream.py

echo "== unified-API tier (registry conformance + persistence round trips) =="
python -m pytest -q tests/test_api.py

echo "== multi-device tier (8 host devices): guarantee suite =="
# Theorem-2 recall floors for host / fused / sharded-fused with 8 shards
# under shard_map. (The sharded in-graph parity tests in
# tests/test_distributed.py already ran in tier-1 — they force their own
# 8-device subprocesses, so re-running them under this flag adds nothing.)
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q tests/test_guarantees.py

echo "== benchmark smoke (host vs scan vs batched vs fused runtime) =="
python -m benchmarks.run --quick --out results/bench

echo "== perf guard (pruning engaged + fused >= batched, fails loudly) =="
python - <<'PY'
import json, sys
rec = json.load(open("BENCH_search.json"))
ok = True
if not rec.get("pruning_engaged"):
    print("PERF GUARD FAIL: pruning not engaged on the smoke bench "
          f"(pages_frac_of_blocks={rec.get('pages_frac_of_blocks')})")
    ok = False
speedup = rec.get("speedup_fused_vs_batched", 0.0)
if speedup < 1.0:
    print(f"PERF GUARD FAIL: fused verification regressed below batched "
          f"(x{speedup:.2f} < x1.00)")
    ok = False
large = rec.get("large_n", {})
if large:
    if not large.get("pruning_engaged"):
        print("PERF GUARD FAIL: pruning not engaged at the large-n point "
              f"(pages_frac_of_blocks={large.get('pages_frac_of_blocks')})")
        ok = False
    if large.get("recall", 0.0) < 0.95:
        print(f"PERF GUARD FAIL: large-n recall {large.get('recall')} < 0.95")
        ok = False
    # the PR-4 headline: the pruned path beats the exact per-query scan at
    # large n. Judged on the SHIPPED config: with a tuning-cache entry
    # installed that is the tuned arm, without one tuned == hand-picked, so
    # max() is the honest pick either way. Hard-fail a clear regression;
    # tolerate host jitter near 1.0.
    vs_exact = max(large.get("speedup_fused_vs_exact", 0.0),
                   large.get("speedup_tuned_vs_exact", 0.0))
    if vs_exact < 0.9:
        print(f"PERF GUARD FAIL: large-n pruned path slower than the exact "
              f"scan (x{vs_exact:.2f} < x0.90)")
        ok = False
    elif vs_exact < 1.0:
        print(f"PERF GUARD WARN: large-n pruned-vs-exact x{vs_exact:.2f} "
              "dipped below x1.00 — wall-clock jitter or a real regression; "
              "re-run before trusting it")
    # honesty guard vs the jit'd dense scan (PR 6): on this CPU box the
    # frontend alone costs ~one exact_jit batch, so < x1.00 is EXPECTED
    # (DESIGN.md §13 has the breakdown); the hard floor only catches the
    # fused path collapsing outright.
    vs_jit = large.get("speedup_fused_vs_exact_jit", 0.0)
    if vs_jit < 0.05:
        print(f"PERF GUARD FAIL: large-n fused collapsed vs the jit scan "
              f"(x{vs_jit:.2f} < x0.05)")
        ok = False
    elif vs_jit < 1.0:
        print(f"PERF GUARD WARN: large-n fused-vs-exact_jit x{vs_jit:.2f} "
              "< x1.00 — structural on this CPU container, see DESIGN.md "
              "§13 (the TPU DMA walk is what monetizes the page cut)")
    # autotuner (PR 8): with the committed tuning cache installed, the
    # cache-resolved config must not lose to the pinned hand-picked one
    # beyond the noise floor (interleaved same-session ratio). With no
    # cache entry the tuned arm IS the hand-picked arm, so ~1.0 passes.
    vs_default = large.get("speedup_tuned_vs_default", 1.0)
    if vs_default < 0.9:
        print(f"PERF GUARD FAIL: tuned config slower than hand-picked at "
              f"large n (x{vs_default:.2f} < x0.90, "
              f"config_source={large.get('config_source')})")
        ok = False
    # sketch prefilter (PR 6): must actually cut pages at the large-n
    # point while holding the recall floor
    pf_on = large.get("pages_frac_of_blocks", 1.0)
    pf_off = large.get("pages_frac_noprefilter", 0.0)
    if pf_on >= pf_off:
        print(f"PERF GUARD FAIL: prefilter does not cut large-n pages "
              f"({pf_on:.3f} on vs {pf_off:.3f} off)")
        ok = False
    if pf_on >= 0.3:
        print(f"PERF GUARD FAIL: large-n prefilter pages_frac {pf_on:.3f} "
              f">= 0.30")
        ok = False
# smoke-scale prefilter guard: fewer pages than off AND recall >= 0.95
sp_on = rec.get("prefilter_on_pages_frac")
sp_off = rec.get("prefilter_off_pages_frac")
if sp_on is not None:
    if sp_on >= sp_off:
        print(f"PERF GUARD FAIL: smoke prefilter does not cut pages "
              f"({sp_on:.3f} on vs {sp_off:.3f} off)")
        ok = False
    if rec.get("prefilter_on_recall", 0.0) < 0.95:
        print(f"PERF GUARD FAIL: smoke prefilter recall "
              f"{rec.get('prefilter_on_recall')} < 0.95")
        ok = False
print(f"perf guard: pruning_engaged={rec.get('pruning_engaged')} "
      f"fused_vs_batched=x{speedup:.2f} "
      f"large_n_fused_vs_exact=x{large.get('speedup_fused_vs_exact', 0.0):.2f} "
      f"large_n_fused_vs_exact_jit="
      f"x{large.get('speedup_fused_vs_exact_jit', 0.0):.2f} "
      f"large_n_recall={large.get('recall', 0.0):.3f} "
      f"prefilter_pages_frac={large.get('pages_frac_of_blocks', 0.0):.3f}"
      f"(off {large.get('pages_frac_noprefilter', 0.0):.3f}) "
      f"tuned_vs_default=x{large.get('speedup_tuned_vs_default', 0.0):.2f}"
      f"({large.get('config_source', '?')})")
sys.exit(0 if ok else 1)
PY

echo "== sharded smoke (in-graph fused vs batched inside shard_map, 8 devices) =="
python -m benchmarks.run --sharded --out results/bench

echo "== sharded perf guard (fused >= batched at the max device count) =="
python - <<'PY'
import json, sys
rec = json.load(open("BENCH_sharded.json"))
ok = True
speedup = rec.get("speedup_sharded_fused_vs_batched", 0.0)
if speedup < 1.0:
    print(f"PERF GUARD FAIL: sharded-fused regressed below sharded-batched "
          f"(x{speedup:.2f} < x1.00 at {rec.get('max_devices')} devices)")
    ok = False
if rec.get("recall", 0.0) < 0.95:
    print(f"PERF GUARD FAIL: sharded recall {rec.get('recall')} < 0.95")
    ok = False
print(f"sharded perf guard: fused_vs_batched=x{speedup:.2f} "
      f"recall={rec.get('recall', 0.0):.3f} "
      f"devices={rec.get('max_devices')}")
sys.exit(0 if ok else 1)
PY

echo "== observability tier (span tracer + metrics registry) =="
python -m pytest -q tests/test_obs.py

echo "== obs smoke (tracer overhead on/off + fenced per-phase breakdown) =="
python -m benchmarks.run --obs --out results/bench

echo "== obs guard (disabled <1%, enabled <5%, spans account for e2e) =="
python - <<'PY'
import json, sys
rec = json.load(open("BENCH_obs.json"))
ok = True
dis = rec["smoke"]["overhead_disabled_frac"]
en = rec["smoke"]["overhead_enabled_frac"]
if dis >= 0.01:
    print(f"OBS GUARD FAIL: disabled-tracer overhead {dis:+.4f} >= 1%")
    ok = False
if en >= 0.05:
    print(f"OBS GUARD FAIL: enabled-tracer overhead {en:+.4f} >= 5%")
    ok = False
frac = rec["large_n"]["phase_sum_frac"]
if not 0.85 <= frac <= 1.15:
    print(f"OBS GUARD FAIL: phase sum / e2e = {frac:.3f} outside "
          "[0.85, 1.15] — the spans do not account for the batch latency")
    ok = False
if rec["undeclared"]:
    print(f"OBS GUARD FAIL: metric names outside the declared glossary: "
          f"{rec['undeclared']}")
    ok = False
print(f"obs guard: overhead_disabled={dis:+.4f} overhead_enabled={en:+.4f} "
      f"phase_sum_frac={frac:.3f} "
      f"metrics={len(rec['registered_metrics'])} declared")
sys.exit(0 if ok else 1)
PY

echo "== tune smoke (offline autotuner on a temp cache + parity audits) =="
python -m benchmarks.run --tune --smoke --out results/bench

echo "== tune guard (cached tuned >= hand-picked, parity, empty-cache noop) =="
python - <<'PY'
import json, sys
rec = json.load(open("BENCH_tune.json"))
ok = True
# the descent's winner, re-measured through the installed cache, must not
# lose to the pinned hand-picked config beyond the noise floor
ratio = rec.get("speedup_cached_vs_handpicked", 0.0)
if ratio < 0.9:
    print(f"TUNE GUARD FAIL: cache-resolved config slower than hand-picked "
          f"(x{ratio:.2f} < x0.90)")
    ok = False
if not rec.get("tuned_parity"):
    print("TUNE GUARD FAIL: tuned config changed (ids, scores) — the "
          "parity gate let a lossy candidate ship")
    ok = False
if not rec.get("empty_cache_noop"):
    print("TUNE GUARD FAIL: empty/disabled cache changed results — "
          "default-knob search must be bit-identical to hand-picked")
    ok = False
print(f"tune guard: cached_vs_handpicked=x{ratio:.2f} "
      f"parity={rec.get('tuned_parity')} "
      f"empty_cache_noop={rec.get('empty_cache_noop')} "
      f"descent_speedup=x{rec.get('speedup_tuned_vs_default', 0.0):.2f} "
      f"candidates={rec.get('n_candidates')}")
sys.exit(0 if ok else 1)
PY

echo "== robust smoke (WAL overhead + crash recovery + degradation ladder) =="
# tests/test_robust.py (crash-at-every-boundary parity sweep, corruption
# matrix, retry/backoff, ladder) already ran in tier-1 above; this tier
# guards the three MEASURED robustness numbers
python -m benchmarks.run --robust --out results/bench

echo "== robust guard (bit-parity, WAL workload overhead <=5%, tier floors) =="
python - <<'PY'
import json, sys
rec = json.load(open("BENCH_robust.json"))
ok = True
if not rec.get("recovery_bit_parity"):
    print("ROBUST GUARD FAIL: recovered searcher is NOT bit-identical to "
          "the live one (ids/scores diverged after snapshot+WAL replay)")
    ok = False
wl = rec.get("wal_workload_overhead_frac", 1.0)
if wl >= 0.05:
    print(f"ROBUST GUARD FAIL: WAL overhead on the streaming workload "
          f"{wl:+.4f} >= 5% (fsync={rec.get('wal_fsync')}; the bare "
          f"append-path ratio {rec.get('wal_append_overhead_frac'):+.3f} "
          "is informational — see bench_robust docstring)")
    ok = False
for t in rec.get("tiers", []):
    if not t["meets_floor"]:
        print(f"ROBUST GUARD FAIL: tier {t['tier']} (budget {t['budget']}) "
              f"recall {t['recall_vs_full']:.3f} < declared floor "
              f"{t['declared_floor']}")
        ok = False
ov = rec.get("overload", {})
if ov.get("stepdowns", 0) < 1:
    print("ROBUST GUARD FAIL: open-loop overload never stepped the ladder "
          f"down (queue backlog {ov.get('requests')} requests, "
          f"shed_rate={ov.get('shed_rate')})")
    ok = False
if ov.get("final_state") != "ok":
    print(f"ROBUST GUARD FAIL: engine did not recover to 'ok' after the "
          f"overload drained (final_state={ov.get('final_state')!r})")
    ok = False
print(f"robust guard: bit_parity={rec.get('recovery_bit_parity')} "
      f"wal_workload_overhead={wl:+.4f} "
      f"replay_rows_per_s={rec.get('replay_rows_per_s', 0):.0f} "
      f"shed_rate={ov.get('shed_rate', 0):.2f} "
      f"stepdowns={ov.get('stepdowns')} stepups={ov.get('stepups')} "
      f"tier_recalls={[round(t['recall_vs_full'], 3) for t in rec.get('tiers', [])]}")
sys.exit(0 if ok else 1)
PY

echo "== serve tier (continuous batching + hot-query cache + loadgen) =="
# tests/test_serve.py also ran in tier-1 above; re-running it here keeps the
# tier self-contained (it is the regression net for the §17 engine bugs)
python -m pytest -q tests/test_serve.py tests/test_loadgen.py

echo "== serve smoke (Zipf ramp through the ladder + cache arms) =="
python -m benchmarks.run --serve --out results/bench

echo "== serve guard (SLA bounds, cache >= no-cache, parity, page accounting) =="
python - <<'PY'
import json, sys
rec = json.load(open("BENCH_serve.json"))
ok = True
dec = rec["declared"]
ramp = rec["ramp"]
p99 = ramp["latency_p99_s"]
if p99 > dec["latency_p99_bound_s"]:
    print(f"SERVE GUARD FAIL: ramp p99 latency {p99:.2f}s exceeds the "
          f"declared bound {dec['latency_p99_bound_s']}s")
    ok = False
qps = ramp["queries_per_s"]
if qps < dec["queries_per_s_floor"]:
    print(f"SERVE GUARD FAIL: ramp completed-queries/s {qps:.2f} below the "
          f"declared floor {dec['queries_per_s_floor']}")
    ok = False
if ramp["stepdowns"] < 1 or ramp["max_tier"] < 1:
    print(f"SERVE GUARD FAIL: the ramp never tripped the degradation "
          f"ladder (stepdowns={ramp['stepdowns']}, "
          f"max_tier={ramp['max_tier']})")
    ok = False
if ramp["final_state"] != "ok":
    print(f"SERVE GUARD FAIL: engine did not recover to 'ok' after the "
          f"ramp drained (final_state={ramp['final_state']!r})")
    ok = False
hot = rec["hot"]
spd = hot["speedup_cache_on_vs_off"]
if spd < dec["hot_speedup_floor"]:
    print(f"SERVE GUARD FAIL: cache-on throughput regressed below "
          f"cache-off on the Zipfian load (x{spd:.2f} < "
          f"x{dec['hot_speedup_floor']:.2f})")
    ok = False
elif spd < 1.0:
    print(f"SERVE GUARD WARN: cache-on vs cache-off x{spd:.2f} < x1.00 — "
          "within wall-clock noise at this vocab (the forward pass "
          "dominates the step; see BENCH_serve.json declared comment)")
if hot["searched_rows_on"] >= hot["searched_rows_off"]:
    print(f"SERVE GUARD FAIL: the hot-query cache did not cut searched "
          f"rows ({hot['searched_rows_on']} on vs "
          f"{hot['searched_rows_off']} off at hit_rate="
          f"{hot['cache_hit_rate']:.2f})")
    ok = False
if not rec["cache_cold_bit_parity"]:
    print("SERVE GUARD FAIL: cache-on decode is NOT bit-identical to "
          "cache-off on cold traffic (the cache changed what was decoded)")
    ok = False
if not rec["inactive_slot_pages_zero"]:
    print("SERVE GUARD FAIL: pages were attributed to inactive decode "
          "slots (searched rows != decode steps for a single request on "
          "a 4-slot engine)")
    ok = False
print(f"serve guard: ramp_p99={p99:.2f}s qps={qps:.2f} "
      f"shed={ramp['shed_frac']:.2f} expired={ramp['expired_frac']:.2f} "
      f"hit_rate={ramp['cache']['hit_rate']:.2f} "
      f"max_tier={ramp['max_tier']} "
      f"cache_on_vs_off=x{spd:.2f} "
      f"cold_parity={rec['cache_cold_bit_parity']} "
      f"inactive_pages_zero={rec['inactive_slot_pages_zero']}")
sys.exit(0 if ok else 1)
PY

echo "== stream smoke (insert throughput + latency vs delta fraction) =="
python -m benchmarks.run --stream --out results/bench

echo "== api smoke (registry sweep: build/disk/us-per-query/recall) =="
python -m benchmarks.run --api --out results/bench

echo "== BENCH_search.json =="
cat BENCH_search.json

echo "== BENCH_stream.json =="
cat BENCH_stream.json

echo "== BENCH_api.json =="
cat BENCH_api.json

echo "== BENCH_sharded.json =="
cat BENCH_sharded.json

echo "== BENCH_obs.json =="
cat BENCH_obs.json

echo "== BENCH_tune.json =="
cat BENCH_tune.json

echo "== BENCH_robust.json =="
cat BENCH_robust.json

echo "== BENCH_serve.json =="
cat BENCH_serve.json
