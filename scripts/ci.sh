#!/usr/bin/env bash
# Tier-1 verify + fast perf smoke. Run from anywhere; results land in
# results/bench/ and the runtime comparison in BENCH_search.json (repo root)
# so the perf trajectory is recorded per commit.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (minus the stream/api tiers, run separately below) =="
python -m pytest -q --ignore=tests/test_stream.py --ignore=tests/test_api.py

echo "== streaming-index tier (insert/delete/compact paths) =="
python -m pytest -q tests/test_stream.py

echo "== unified-API tier (registry conformance + persistence round trips) =="
python -m pytest -q tests/test_api.py

echo "== benchmark smoke (host vs scan vs batched runtime) =="
python -m benchmarks.run --quick --out results/bench

echo "== stream smoke (insert throughput + latency vs delta fraction) =="
python -m benchmarks.run --stream --out results/bench

echo "== api smoke (registry sweep: build/disk/us-per-query/recall) =="
python -m benchmarks.run --api --out results/bench

echo "== BENCH_search.json =="
cat BENCH_search.json

echo "== BENCH_stream.json =="
cat BENCH_stream.json

echo "== BENCH_api.json =="
cat BENCH_api.json
