#!/usr/bin/env bash
# Tier-1 verify + fast perf smoke. Run from anywhere; results land in
# results/bench/ and the runtime comparison in BENCH_search.json (repo root)
# so the perf trajectory is recorded per commit.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (minus the stream tier, run separately below) =="
python -m pytest -q --ignore=tests/test_stream.py

echo "== streaming-index tier (insert/delete/compact paths) =="
python -m pytest -q tests/test_stream.py

echo "== benchmark smoke (host vs scan vs batched runtime) =="
python -m benchmarks.run --quick --out results/bench

echo "== stream smoke (insert throughput + latency vs delta fraction) =="
python -m benchmarks.run --stream --out results/bench

echo "== BENCH_search.json =="
cat BENCH_search.json

echo "== BENCH_stream.json =="
cat BENCH_stream.json
