"""Serving engine: continuous batching + ProMIPS-vs-exact greedy agreement."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=8), max_new_tokens=5)
            for _ in range(5)]  # more requests than slots
    eng.run()
    for r in reqs:
        assert len(r.out_tokens) >= 2
    assert eng.steps > 0
    assert not eng.active.any() and not eng.queue


def test_promips_greedy_matches_exact(small_model):
    """c-AMIP approximate argmax decoding reproduces exact greedy decoding
    (high-p index on the embedding rows)."""
    cfg, params = small_model
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab, size=8) for _ in range(3)]

    eng_e = DecodeEngine(params, cfg, batch_slots=3, max_len=64,
                         logits_mode="exact")
    reqs_e = [eng_e.submit(p, max_new_tokens=6) for p in prompts]
    eng_e.run()

    eng_p = DecodeEngine(params, cfg, batch_slots=3, max_len=64,
                         logits_mode="promips",
                         promips_kwargs=dict(m=8, c=0.95, p=0.95))
    reqs_p = [eng_p.submit(p, max_new_tokens=6) for p in prompts]
    eng_p.run()

    agree = sum(a.out_tokens == b.out_tokens for a, b in zip(reqs_e, reqs_p))
    assert agree >= 2, [(a.out_tokens, b.out_tokens) for a, b in zip(reqs_e, reqs_p)]
