"""Serving engine: continuous batching + ProMIPS-vs-exact greedy agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=8), max_new_tokens=5)
            for _ in range(5)]  # more requests than slots
    eng.run()
    for r in reqs:
        assert len(r.out_tokens) >= 2
    assert eng.steps > 0
    assert not eng.active.any() and not eng.queue


def test_promips_greedy_matches_exact(small_model):
    """c-AMIP approximate argmax decoding reproduces exact greedy decoding
    (high-p index on the embedding rows)."""
    cfg, params = small_model
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab, size=8) for _ in range(3)]

    eng_e = DecodeEngine(params, cfg, batch_slots=3, max_len=64,
                         logits_mode="exact")
    reqs_e = [eng_e.submit(p, max_new_tokens=6) for p in prompts]
    eng_e.run()

    eng_p = DecodeEngine(params, cfg, batch_slots=3, max_len=64,
                         logits_mode="promips",
                         promips_kwargs=dict(m=8, c=0.95, p=0.95))
    reqs_p = [eng_p.submit(p, max_new_tokens=6) for p in prompts]
    eng_p.run()

    agree = sum(a.out_tokens == b.out_tokens for a, b in zip(reqs_e, reqs_p))
    assert agree >= 2, [(a.out_tokens, b.out_tokens) for a, b in zip(reqs_e, reqs_p)]


def test_promips_fused_runtime_decodes_identically(small_model):
    """A fused-verification search_runtime is a first-class engine option
    (PR 5: trace-safe, bit-identical search results to batched) — decoded
    tokens must match the default batched config token-for-token. The
    default stays "batched": at decode-shaped batches the single batched
    graph measures faster per step on the CPU oracle (engine.__init__
    comment has the numbers)."""
    from repro.core.runtime import RuntimeConfig

    cfg, params = small_model
    rng = np.random.RandomState(2)
    prompts = [rng.randint(1, cfg.vocab, size=8) for _ in range(3)]

    outs = {}
    for verification in ("batched", "fused"):
        eng = DecodeEngine(
            params, cfg, batch_slots=3, max_len=64, logits_mode="promips",
            promips_kwargs=dict(m=8, c=0.95, p=0.95),
            search_runtime=RuntimeConfig(
                mode="two_phase", verification=verification,
                norm_adaptive=True, cs_prune=True))
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run()
        outs[verification] = [r.out_tokens for r in reqs]
    assert outs["fused"] == outs["batched"], outs
    eng_default = DecodeEngine(params, cfg, batch_slots=3, max_len=64,
                               logits_mode="promips",
                               promips_kwargs=dict(m=8, c=0.95, p=0.95))
    assert eng_default.search_runtime.verification == "batched"


# -- continuous-batching internals (scripted decode: the fake replaces the
# jit'd decode step so token emission — and therefore slot lifecycle — is
# fully deterministic; admission prefill still runs the real model) ----------

def _scripted_decode(eng, vocab, eos_for=None):
    """Every slot decodes token 5 forever, except ``eos_for`` = {slot: call#}
    which emits the engine's eos at that decode call."""
    state = {"calls": 0}

    def fake(params, cache, tokens):
        logits = np.zeros((eng.b, vocab), np.float32)
        logits[:, 5] = 1.0
        for slot, at_call in (eos_for or {}).items():
            if state["calls"] == at_call:
                logits[slot, :] = 0.0
                logits[slot, eng.eos_id] = 1.0
        state["calls"] += 1
        return jnp.asarray(logits), cache

    eng._decode = fake
    return state


def test_slot_release_on_eos(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    _scripted_decode(eng, cfg.vocab, eos_for={0: 1})  # slot 0 ends 2nd decode
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=50)
            for _ in range(2)]

    eng.step()  # admit both; decode call 0
    assert eng.active.tolist() == [True, True]
    assert reqs[0].slot == 0 and reqs[1].slot == 1
    eng.step()  # decode call 1: slot 0 emits EOS
    assert eng.active.tolist() == [False, True]
    assert eng.requests[0] is None, "EOS slot must be released"
    assert reqs[0].out_tokens[-1] == eng.eos_id
    assert eng.requests[1] is reqs[1], "other slot keeps running"


def test_queued_admission_single_slot_prefill(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    _scripted_decode(eng, cfg.vocab, eos_for={0: 1})
    rng = np.random.RandomState(1)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=50)
            for _ in range(3)]

    eng.step()
    assert len(eng.queue) == 1 and reqs[2].slot == -1
    eng.step()  # slot 0 freed by EOS
    eng.step()  # queued request admitted into the freed slot via 1-row prefill
    assert reqs[2].slot == 0 and eng.requests[0] is reqs[2]
    assert not eng.queue
    assert len(reqs[2].out_tokens) >= 1, "admission prefill emits a token"
    assert eng.active.tolist() == [True, True]


def test_page_accounting_multi_request(small_model):
    """Exact-mode page counter follows the documented per-step formula over
    a multi-request run with slot turnover."""
    cfg, params = small_model
    b = 2
    eng = DecodeEngine(params, cfg, batch_slots=b, max_len=64)
    _scripted_decode(eng, cfg.vocab)  # nobody hits EOS; lengths drive exits
    rng = np.random.RandomState(2)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=4)
            for _ in range(3)]

    per_step = lambda active: (cfg.vocab_padded * cfg.d_model * 4 // 4096
                               * active // b)
    expected = 0
    while eng.queue or eng.active.any():
        eng._admit()
        active = int(eng.active.sum())
        if not eng.step():
            break
        expected += per_step(active)
    assert eng.pages == expected and eng.pages > 0
    assert all(len(r.out_tokens) == 4 for r in reqs)


def test_engine_delete_retires_vocab_ids(small_model):
    """delete() tombstones vocab ids in the streaming embedding index, so
    approximate greedy decoding can never emit them again (DESIGN.md §8)."""
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                       logits_mode="promips",
                       promips_kwargs=dict(m=8, c=0.95, p=0.95))
    rng = np.random.RandomState(3)
    prompt = rng.randint(1, cfg.vocab, size=6)
    r1 = eng.submit(prompt, max_new_tokens=5)
    eng.run()
    banned = {t for t in r1.out_tokens if t != eng.eos_id}
    assert banned, "need at least one non-eos decoded token to retire"

    eng.delete(sorted(banned))
    r2 = eng.submit(prompt, max_new_tokens=5)
    eng.run()
    assert not (set(r2.out_tokens) & banned), \
        "retired vocab ids must never be decoded again"


def test_engine_delete_with_unpadded_vocab(small_model):
    """Regression: prefill logits cover vocab_padded rows; the retired-id
    mask must still apply when vocab is not a multiple of 512."""
    import dataclasses
    cfg, _ = small_model
    cfg = dataclasses.replace(cfg, vocab=600)  # vocab_padded = 1024
    assert cfg.vocab_padded != cfg.vocab
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                       logits_mode="promips",
                       promips_kwargs=dict(m=8, c=0.95, p=0.95))
    rng = np.random.RandomState(5)
    prompt = rng.randint(1, cfg.vocab, size=6)
    r1 = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    assert all(t < cfg.vocab for t in r1.out_tokens)
    banned = {t for t in r1.out_tokens if t != eng.eos_id}
    eng.delete(sorted(banned))
    r2 = eng.submit(prompt, max_new_tokens=4)
    eng.run()  # must not crash in _admit's prefill masking
    assert not (set(r2.out_tokens) & banned)
    assert all(t < cfg.vocab for t in r2.out_tokens)

    # exact mode must also never emit an id from the vocab_padded tail
    eng_e = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    r3 = eng_e.submit(prompt, max_new_tokens=4)
    eng_e.run()
    assert all(t < cfg.vocab for t in r3.out_tokens)


def test_engine_update_refreshes_embeddings(small_model):
    """update() routes refreshed rows into the delta segment: the next decode
    step scores them exactly, so a boosted copy of the winning embedding wins."""
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                       logits_mode="promips",
                       promips_kwargs=dict(m=8, c=0.95, p=0.95))
    rng = np.random.RandomState(4)
    prompt = rng.randint(1, cfg.vocab, size=6)
    r1 = eng.submit(prompt, max_new_tokens=3)
    eng.run()
    winners = [t for t in r1.out_tokens[1:] if t != eng.eos_id]
    assert winners, "need a decoded winner to clone"
    t_win = winners[0]

    boosted = next(i for i in range(1, cfg.vocab)
                   if i != t_win and i not in r1.out_tokens)
    w = np.asarray(eng.params["embed"][t_win], np.float32)
    eng.update([boosted], 50.0 * w[None, :])
    assert np.allclose(np.asarray(eng.params["embed"][boosted], np.float32),
                       50.0 * w, atol=1e-1)

    r2 = eng.submit(prompt, max_new_tokens=3)
    eng.run()
    eng.join_compaction()
    assert boosted in r2.out_tokens, \
        "refreshed delta row must be searchable from the next decode step"
