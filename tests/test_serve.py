"""Serving engine: continuous batching + ProMIPS-vs-exact greedy agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_continuous_batching(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=8), max_new_tokens=5)
            for _ in range(5)]  # more requests than slots
    eng.run()
    for r in reqs:
        assert len(r.out_tokens) >= 2
    assert eng.steps > 0
    assert not eng.active.any() and not eng.queue


def test_promips_greedy_matches_exact(small_model):
    """c-AMIP approximate argmax decoding reproduces exact greedy decoding
    (high-p index on the embedding rows)."""
    cfg, params = small_model
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab, size=8) for _ in range(3)]

    eng_e = DecodeEngine(params, cfg, batch_slots=3, max_len=64,
                         logits_mode="exact")
    reqs_e = [eng_e.submit(p, max_new_tokens=6) for p in prompts]
    eng_e.run()

    eng_p = DecodeEngine(params, cfg, batch_slots=3, max_len=64,
                         logits_mode="promips",
                         promips_kwargs=dict(m=8, c=0.95, p=0.95))
    reqs_p = [eng_p.submit(p, max_new_tokens=6) for p in prompts]
    eng_p.run()

    agree = sum(a.out_tokens == b.out_tokens for a, b in zip(reqs_e, reqs_p))
    assert agree >= 2, [(a.out_tokens, b.out_tokens) for a, b in zip(reqs_e, reqs_p)]


def test_promips_fused_runtime_decodes_identically(small_model):
    """A fused-verification search_runtime is a first-class engine option
    (PR 5: trace-safe, bit-identical search results to batched) — decoded
    tokens must match the default batched config token-for-token. The
    default stays "batched": at decode-shaped batches the single batched
    graph measures faster per step on the CPU oracle (engine.__init__
    comment has the numbers)."""
    from repro.core.runtime import RuntimeConfig

    cfg, params = small_model
    rng = np.random.RandomState(2)
    prompts = [rng.randint(1, cfg.vocab, size=8) for _ in range(3)]

    outs = {}
    for verification in ("batched", "fused"):
        eng = DecodeEngine(
            params, cfg, batch_slots=3, max_len=64, logits_mode="promips",
            promips_kwargs=dict(m=8, c=0.95, p=0.95),
            search_runtime=RuntimeConfig(
                mode="two_phase", verification=verification,
                norm_adaptive=True, cs_prune=True))
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run()
        outs[verification] = [r.out_tokens for r in reqs]
    assert outs["fused"] == outs["batched"], outs
    eng_default = DecodeEngine(params, cfg, batch_slots=3, max_len=64,
                               logits_mode="promips",
                               promips_kwargs=dict(m=8, c=0.95, p=0.95))
    assert eng_default.search_runtime.verification == "batched"


# -- continuous-batching internals (scripted decode: the fake replaces the
# jit'd decode step so token emission — and therefore slot lifecycle — is
# fully deterministic; admission prefill still runs the real model) ----------

def _scripted_decode(eng, vocab, eos_for=None):
    """Every slot decodes token 5 forever, except ``eos_for`` = {slot: call#}
    which emits the engine's eos at that decode call."""
    state = {"calls": 0}

    def fake(params, cache, tokens):
        logits = np.zeros((eng.b, vocab), np.float32)
        logits[:, 5] = 1.0
        for slot, at_call in (eos_for or {}).items():
            if state["calls"] == at_call:
                logits[slot, :] = 0.0
                logits[slot, eng.eos_id] = 1.0
        state["calls"] += 1
        return jnp.asarray(logits), cache

    eng._decode = fake
    return state


def test_slot_release_on_eos(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    _scripted_decode(eng, cfg.vocab, eos_for={0: 1})  # slot 0 ends 2nd decode
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=50)
            for _ in range(2)]

    eng.step()  # admit both; decode call 0
    assert eng.active.tolist() == [True, True]
    assert reqs[0].slot == 0 and reqs[1].slot == 1
    eng.step()  # decode call 1: slot 0 emits EOS
    assert eng.active.tolist() == [False, True]
    assert eng.requests[0] is None, "EOS slot must be released"
    assert reqs[0].out_tokens[-1] == eng.eos_id
    assert eng.requests[1] is reqs[1], "other slot keeps running"


def test_queued_admission_single_slot_prefill(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    _scripted_decode(eng, cfg.vocab, eos_for={0: 1})
    rng = np.random.RandomState(1)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=50)
            for _ in range(3)]

    eng.step()
    assert len(eng.queue) == 1 and reqs[2].slot == -1
    eng.step()  # slot 0 freed by EOS
    eng.step()  # queued request admitted into the freed slot via 1-row prefill
    assert reqs[2].slot == 0 and eng.requests[0] is reqs[2]
    assert not eng.queue
    assert len(reqs[2].out_tokens) >= 1, "admission prefill emits a token"
    assert eng.active.tolist() == [True, True]


def test_page_accounting_multi_request(small_model):
    """Exact-mode page counter follows the documented per-step formula over
    a multi-request run with slot turnover."""
    cfg, params = small_model
    b = 2
    eng = DecodeEngine(params, cfg, batch_slots=b, max_len=64)
    _scripted_decode(eng, cfg.vocab)  # nobody hits EOS; lengths drive exits
    rng = np.random.RandomState(2)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=4)
            for _ in range(3)]

    per_step = lambda active: (cfg.vocab_padded * cfg.d_model * 4 // 4096
                               * active // b)
    expected = 0
    while eng.queue or eng.active.any():
        eng._admit()
        active = int(eng.active.sum())
        if not eng.step():
            break
        expected += per_step(active)
    assert eng.pages == expected and eng.pages > 0
    # max_new_tokens=4 DECODED tokens + the prefill argmax = 5 total
    assert all(len(r.out_tokens) == 5 for r in reqs)


def test_engine_delete_retires_vocab_ids(small_model):
    """delete() tombstones vocab ids in the streaming embedding index, so
    approximate greedy decoding can never emit them again (DESIGN.md §8)."""
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                       logits_mode="promips",
                       promips_kwargs=dict(m=8, c=0.95, p=0.95))
    rng = np.random.RandomState(3)
    prompt = rng.randint(1, cfg.vocab, size=6)
    r1 = eng.submit(prompt, max_new_tokens=5)
    eng.run()
    banned = {t for t in r1.out_tokens if t != eng.eos_id}
    assert banned, "need at least one non-eos decoded token to retire"

    eng.delete(sorted(banned))
    r2 = eng.submit(prompt, max_new_tokens=5)
    eng.run()
    assert not (set(r2.out_tokens) & banned), \
        "retired vocab ids must never be decoded again"


def test_engine_delete_with_unpadded_vocab(small_model):
    """Regression: prefill logits cover vocab_padded rows; the retired-id
    mask must still apply when vocab is not a multiple of 512."""
    import dataclasses
    cfg, _ = small_model
    cfg = dataclasses.replace(cfg, vocab=600)  # vocab_padded = 1024
    assert cfg.vocab_padded != cfg.vocab
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                       logits_mode="promips",
                       promips_kwargs=dict(m=8, c=0.95, p=0.95))
    rng = np.random.RandomState(5)
    prompt = rng.randint(1, cfg.vocab, size=6)
    r1 = eng.submit(prompt, max_new_tokens=4)
    eng.run()
    assert all(t < cfg.vocab for t in r1.out_tokens)
    banned = {t for t in r1.out_tokens if t != eng.eos_id}
    eng.delete(sorted(banned))
    r2 = eng.submit(prompt, max_new_tokens=4)
    eng.run()  # must not crash in _admit's prefill masking
    assert not (set(r2.out_tokens) & banned)
    assert all(t < cfg.vocab for t in r2.out_tokens)

    # exact mode must also never emit an id from the vocab_padded tail
    eng_e = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    r3 = eng_e.submit(prompt, max_new_tokens=4)
    eng_e.run()
    assert all(t < cfg.vocab for t in r3.out_tokens)


def test_engine_update_refreshes_embeddings(small_model):
    """update() routes refreshed rows into the delta segment: the next decode
    step scores them exactly, so a boosted copy of the winning embedding wins."""
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                       logits_mode="promips",
                       promips_kwargs=dict(m=8, c=0.95, p=0.95))
    rng = np.random.RandomState(4)
    prompt = rng.randint(1, cfg.vocab, size=6)
    r1 = eng.submit(prompt, max_new_tokens=3)
    eng.run()
    winners = [t for t in r1.out_tokens[1:] if t != eng.eos_id]
    assert winners, "need a decoded winner to clone"
    t_win = winners[0]

    boosted = next(i for i in range(1, cfg.vocab)
                   if i != t_win and i not in r1.out_tokens)
    w = np.asarray(eng.params["embed"][t_win], np.float32)
    eng.update([boosted], 50.0 * w[None, :])
    assert np.allclose(np.asarray(eng.params["embed"][boosted], np.float32),
                       50.0 * w, atol=1e-1)

    r2 = eng.submit(prompt, max_new_tokens=3)
    eng.run()
    eng.join_compaction()
    assert boosted in r2.out_tokens, \
        "refreshed delta row must be searchable from the next decode step"


def _scripted_hidden(eng, d, seed=7):
    """Replace the jit'd hidden-state decode with a deterministic per-token
    map (token id -> fixed random vector): each slot's query row depends
    ONLY on its own last token, never on the batch composition, so search
    results and page counts are exactly comparable across engines with
    different slot counts."""
    def fake(params, cache, tokens):
        toks = np.asarray(tokens)[:, 0]
        rows = np.stack([np.random.RandomState(seed + int(t)).randn(d)
                         for t in toks]).astype(np.float32)
        return jnp.asarray(rows), cache
    eng._decode_hidden = fake


def _promips_engine(small_model, **kw):
    cfg, params = small_model
    kw.setdefault("promips_kwargs", dict(m=8, c=0.95, p=0.95))
    return DecodeEngine(params, cfg, max_len=64, logits_mode="promips", **kw)


# -- decode-loop bug regressions (all three fail on the pre-§17 engine) ------

def test_inactive_slots_cost_zero_pages(small_model):
    """Regression: the promips decode search must not touch (or account)
    pages for inactive slots. A single request on a 4-slot engine costs
    exactly what it costs on a 1-slot engine, and decodes the same
    tokens."""
    cfg, params = small_model
    prompt = np.arange(1, 7).astype(np.int32)
    runs = {}
    for b in (1, 4):
        eng = _promips_engine(small_model, batch_slots=b, result_cache=0)
        _scripted_hidden(eng, cfg.d_model)
        r = eng.submit(prompt, max_new_tokens=5)
        eng.run()
        runs[b] = (r.out_tokens, eng.pages, eng.searched_rows)
    assert runs[4][0] == runs[1][0], "tokens must not depend on slot count"
    assert runs[4][1] == runs[1][1] > 0, \
        "pages attributed to inactive slots must be zero"
    assert runs[4][2] == runs[1][2], "only active rows may be searched"


def test_max_new_tokens_counts_decoded_tokens(small_model):
    """Regression: a request asking for N new tokens gets N decode steps
    (the prefill argmax in out_tokens[0] does not count against N)."""
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=1, max_len=64)
    _scripted_decode(eng, cfg.vocab)  # token 5 forever, never EOS
    rng = np.random.RandomState(0)
    r = eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=3)
    eng.run()
    assert len(r.out_tokens) == 1 + 3, \
        "N decoded tokens after the prefill argmax"
    assert r.out_tokens[1:] == [5, 5, 5]


def test_zero_deadline_expires_at_admission(small_model):
    """Regression: deadline_s=0.0 means 'already expired', not 'no
    deadline' (None is the only no-deadline sentinel). Also covers the
    all-queued-requests-expired admission path: _admit must expire every
    one and drain cleanly."""
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    rng = np.random.RandomState(1)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=4,
                       deadline_s=0.0) for _ in range(3)]
    assert all(r is not None and r.deadline is not None for r in reqs)
    stepped = eng.step()
    assert stepped is False, "nothing was admitted, nothing decoded"
    assert all(r.expired and not r.out_tokens for r in reqs)
    assert eng.deadline_drops == 3
    assert not eng.active.any() and not eng.queue


# -- admission/expiry path coverage ------------------------------------------

def test_deadline_crossing_between_admit_and_first_step(small_model):
    """A deadline crossed after admission but before the first decode step
    terminates the request at that step, with partial tokens retained."""
    import time
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=1, max_len=64)
    _scripted_decode(eng, cfg.vocab)
    rng = np.random.RandomState(2)
    r = eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=50,
                   deadline_s=30.0)
    eng._admit()
    assert r.slot == 0 and len(r.out_tokens) == 1  # prefill argmax landed
    r.deadline = time.perf_counter()               # cross it before step 1
    eng.step()
    assert r.expired and len(r.out_tokens) == 2, "partial tokens retained"
    assert not eng.active.any() and eng.requests[0] is None
    assert eng.deadline_drops == 1


def test_health_shedding_exactly_while_backlog_at_cap(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=1, max_len=64,
                       max_queue=2)
    _scripted_decode(eng, cfg.vocab)
    rng = np.random.RandomState(3)
    sub = lambda: eng.submit(rng.randint(1, cfg.vocab, size=6),
                             max_new_tokens=50)
    assert eng.health()["state"] == "ok"
    assert sub() is not None and eng.health()["state"] == "ok"
    assert sub() is not None
    assert eng.health()["state"] == "shedding", "backlog at max_queue"
    assert sub() is None and eng.shed == 1      # cap enforced
    eng.step()                                   # one admitted off the queue
    assert len(eng.queue) == 1
    assert eng.health()["state"] == "ok", "below the cap: no longer shedding"


# -- continuous batching (batched prefill + refill knob) ---------------------

def test_batched_prefill_one_call_per_length_group(small_model):
    """All requests admitted in one step prefill together: one
    model_lib.prefill call per distinct prompt length, and the emitted
    prefill tokens match the one-request-at-a-time path."""
    cfg, params = small_model
    rng = np.random.RandomState(4)
    prompts = [rng.randint(1, cfg.vocab, size=s) for s in (6, 6, 8, 6)]

    eng = DecodeEngine(params, cfg, batch_slots=4, max_len=64)
    _scripted_decode(eng, cfg.vocab)
    reqs = [eng.submit(p, max_new_tokens=2) for p in prompts]
    eng.step()
    assert eng.prefill_calls == 2, "len-6 group (3 reqs) + len-8 group"
    assert eng.active.sum() == 4 and [r.slot for r in reqs] == [0, 1, 2, 3]

    # sequential reference: one engine, one slot, one prefill per request
    ref_tokens = []
    for p in prompts:
        e1 = DecodeEngine(params, cfg, batch_slots=1, max_len=64)
        _scripted_decode(e1, cfg.vocab)
        r = e1.submit(p, max_new_tokens=2)
        e1.step()
        ref_tokens.append(r.out_tokens[0])
    assert [r.out_tokens[0] for r in reqs] == ref_tokens


def test_max_refill_caps_admissions_per_step(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=4, max_len=64,
                       max_refill=1)
    _scripted_decode(eng, cfg.vocab)
    rng = np.random.RandomState(5)
    for _ in range(3):
        eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=50)
    for expect in (1, 2, 3):
        eng.step()
        assert int(eng.active.sum()) == expect
    with pytest.raises(ValueError, match="max_refill"):
        DecodeEngine(params, cfg, batch_slots=2, max_len=64, max_refill=0)


def test_refill_happens_every_step_under_turnover(small_model):
    """A freed slot is refilled from the queue on the very next step even
    while other slots keep decoding (continuous batching, not fixed
    admission rounds)."""
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    _scripted_decode(eng, cfg.vocab, eos_for={0: 1})
    rng = np.random.RandomState(6)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=50)
            for _ in range(3)]
    eng.step()                       # both admitted
    eng.step()                       # slot 0 EOSes
    assert eng.active.tolist() == [False, True]
    eng.step()                       # freed slot refilled immediately
    assert eng.active.tolist() == [True, True]
    assert reqs[2].slot == 0


# -- hot-query result cache --------------------------------------------------

def test_cache_bit_parity_on_cold_traffic(small_model):
    """Cache-on decoding is bit-identical to cache-off on cold (all
    distinct) traffic — the cache's correctness contract."""
    cfg, params = small_model
    rng = np.random.RandomState(7)
    prompts = [rng.randint(1, cfg.vocab, size=6) for _ in range(4)]
    outs = {}
    for rc in (0, 64):
        eng = _promips_engine(small_model, batch_slots=2, result_cache=rc)
        reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
        eng.run()
        outs[rc] = [r.out_tokens for r in reqs]
        if rc:
            st = eng.qcache.stats()
            assert st["misses"] == eng.searched_rows
            assert st["hits"] + st["misses"] >= eng.searched_rows
    assert outs[64] == outs[0]


def test_cache_hits_on_repeated_prompt(small_model):
    """A repeated prompt drives bit-identical hidden states through the
    decode loop: the second pass is served from the cache (searches
    skipped) and decodes the identical token stream."""
    cfg, params = small_model
    rng = np.random.RandomState(8)
    prompt = rng.randint(1, cfg.vocab, size=6)
    eng = _promips_engine(small_model, batch_slots=1, result_cache=256)
    r1 = eng.submit(prompt, max_new_tokens=5)
    eng.run()
    searched_cold = eng.searched_rows
    assert eng.qcache.hits == 0
    r2 = eng.submit(prompt, max_new_tokens=5)
    eng.run()
    assert r2.out_tokens == r1.out_tokens
    assert eng.qcache.hits > 0, "hot prompt must hit the result cache"
    assert eng.searched_rows < 2 * searched_cold, "hits skip the search"


def test_cache_eviction_and_mutation_invalidation(small_model):
    cfg, params = small_model
    rng = np.random.RandomState(9)
    eng = _promips_engine(small_model, batch_slots=1, result_cache=2)
    for _ in range(3):
        eng.submit(rng.randint(1, cfg.vocab, size=6), max_new_tokens=4)
    eng.run()
    assert eng.qcache.evictions > 0, "capacity 2 must evict under churn"
    assert len(eng.qcache) == 2
    hits, misses = eng.qcache.hits, eng.qcache.misses
    # mutation wholesale-invalidates (a cached row may name a stale id)
    eng.delete([1])
    assert len(eng.qcache) == 0
    assert (eng.qcache.hits, eng.qcache.misses) == (hits, misses), \
        "invalidation is not an eviction and touches no counters"
    d = cfg.d_model
    eng.update([2], np.ones((1, d), np.float32))
    assert len(eng.qcache) == 0


def test_cache_counters_in_metrics_snapshot(small_model):
    from repro.obs import metrics
    cfg, params = small_model
    metrics.reset()
    rng = np.random.RandomState(10)
    prompt = rng.randint(1, cfg.vocab, size=6)
    eng = _promips_engine(small_model, batch_slots=1, result_cache=64,
                          obs=True)
    for _ in range(2):
        eng.submit(prompt, max_new_tokens=4)
    eng.run()
    snap = eng.metrics_snapshot()
    assert snap["serve.cache_hits"] == eng.qcache.hits > 0
    assert snap["serve.cache_misses"] == eng.qcache.misses > 0
    assert snap["result_cache"]["hit_rate"] == eng.qcache.hit_rate
    assert snap["searched_rows"] == eng.searched_rows
    metrics.reset()


def test_result_cache_resolves_from_tune_space_defaults(small_model):
    """result_cache/max_refill default from the autotuner's serve section
    (hand-picked values when the cache has no entry for this shape)."""
    from repro.tune import space
    eng = _promips_engine(small_model, batch_slots=2)
    assert eng.qcache.capacity == \
        space.HAND_PICKED["serve"]["result_cache_size"]
    assert eng.max_refill == space.HAND_PICKED["serve"]["max_refill_per_step"]
    eng2 = _promips_engine(small_model, batch_slots=2, result_cache=0)
    assert eng2.qcache.capacity == 0
