"""Property tests for `core/search_common.py` (+ the shared Condition-A
accounting in `kernels/ref._verify_core`):

  * `topk_merge` is idempotent in the rank-select sense — re-ranking its own
    output, merging an empty batch, and merging strictly-dominated
    candidates are all exact no-ops (rows included) — and commutative in
    the merge ORDER of candidate batches
    (score multisets agree always; rows agree when scores are unique),
  * its tie handling is bit-consistent with `jax.lax.top_k` under heavily
    duplicated scores, and identical between the numpy and jnp backends
    (the host / device agreement every parity suite leans on),
  * the Condition-A accounting is EXACTLY the sequential budgeted scan it
    reconstructs (simulated per query in plain Python) and monotone in the
    scan budget: selecting more slots never decreases pages, candidates or
    any rank of the running top-k.

Every property runs over a seeded case grid (always, no optional deps);
when `hypothesis` is installed the same checkers also run under its fuzzer
for a much wider seed sweep (the module does NOT skip itself offline — the
seeded grid is the regression floor, hypothesis is the amplifier).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import search_common as sc
from repro.kernels import ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # offline container: seeded grid still runs
    HAVE_HYPOTHESIS = False

# a small value pool forces heavy score ties — the regime where merge rules
# actually differ between implementations
TIE_POOL = np.asarray([-2.0, -0.5, 0.0, 0.25, 1.0, 3.5], np.float32)


def _empty(k, xp):
    return (xp.full((k,), -xp.inf, dtype=xp.float32),
            xp.full((k,), -1, dtype=xp.int32))


def _case(seed: int):
    rng = np.random.RandomState(seed)
    n_a, n_b = rng.randint(1, 12, size=2)
    k = int(rng.randint(1, 8))
    sa = rng.choice(TIE_POOL, size=n_a).astype(np.float32)
    sb = rng.choice(TIE_POOL, size=n_b).astype(np.float32)
    ra = np.arange(n_a, dtype=np.int32)
    rb = np.arange(100, 100 + n_b, dtype=np.int32)
    return k, sa, ra, sb, rb


# ---------------------------------------------------------------------------
# property checkers (shared by the seeded grid and the hypothesis sweep)
# ---------------------------------------------------------------------------

def check_merge_idempotent(k, scores, rows):
    # The merge ranks OCCURRENCES (the runtime's rounds feed disjoint row
    # sets — mask1 &= ~mask0 — so the same row is never scored twice), so
    # "idempotent" means its three no-op identities, rows included:
    #   1. re-ranking its own sorted output reproduces it exactly,
    #   2. merging an empty candidate batch changes nothing,
    #   3. merging candidates strictly below the running k-th changes nothing.
    for xp in (np, jnp):
        s0, r0 = _empty(k, xp)
        s1, r1 = sc.topk_merge(s0, r0, xp.asarray(scores), xp.asarray(rows),
                               k, xp=xp)
        s2, r2 = sc.topk_merge(*_empty(k, xp), s1, r1, k, xp=xp)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        s3, r3 = sc.topk_merge(s1, r1, xp.zeros((0,), xp.float32),
                               xp.zeros((0,), xp.int32), k, xp=xp)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s3))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r3))
        kth = np.asarray(s1)[k - 1]
        dominated = scores[scores < kth]
        s4, r4 = sc.topk_merge(s1, r1, xp.asarray(dominated),
                               xp.full((len(dominated),), 7, dtype=xp.int32),
                               k, xp=xp)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s4))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r4))


def check_merge_commutative(k, sa, ra, sb, rb):
    for xp in (np, jnp):
        s0, r0 = _empty(k, xp)
        sab, rab = sc.topk_merge(*sc.topk_merge(s0, r0, xp.asarray(sa),
                                                xp.asarray(ra), k, xp=xp),
                                 xp.asarray(sb), xp.asarray(rb), k, xp=xp)
        sba, rba = sc.topk_merge(*sc.topk_merge(s0, r0, xp.asarray(sb),
                                                xp.asarray(rb), k, xp=xp),
                                 xp.asarray(sa), xp.asarray(ra), k, xp=xp)
        np.testing.assert_array_equal(np.asarray(sab), np.asarray(sba))
        all_scores = np.concatenate([sa, sb])
        if len(np.unique(all_scores)) == len(all_scores):
            # unique scores => the ranking is order-free, rows must agree too
            np.testing.assert_array_equal(np.asarray(rab), np.asarray(rba))


def check_tie_rule_matches_lax_top_k(k, scores, rows):
    """numpy stable-argsort backend == jnp backend == raw lax.top_k over the
    same concatenation, bit-for-bit, under duplicated scores."""
    s0np, r0np = _empty(k, np)
    s_np, r_np = sc.topk_merge(s0np, r0np, scores, rows, k, xp=np)
    s0j, r0j = _empty(k, jnp)
    s_j, r_j = sc.topk_merge(s0j, r0j, jnp.asarray(scores), jnp.asarray(rows),
                             k, xp=jnp)
    np.testing.assert_array_equal(s_np, np.asarray(s_j))
    np.testing.assert_array_equal(r_np, np.asarray(r_j))
    cat_s = jnp.concatenate([s0j, jnp.asarray(scores)])
    cat_r = np.concatenate([r0np, rows])
    top_s, idx = jax.lax.top_k(cat_s, k)
    np.testing.assert_array_equal(np.asarray(top_s), s_np)
    np.testing.assert_array_equal(cat_r[np.asarray(idx)], r_np)


def _verify_case(seed: int):
    rng = np.random.RandomState(seed)
    b = int(rng.randint(1, 4))
    n_slots = int(rng.randint(2, 8))
    page_rows = int(rng.randint(1, 5))
    k = int(rng.randint(1, 6))
    r = n_slots * page_rows
    scores = rng.choice(TIE_POOL, size=(b, r)).astype(np.float32)
    rvalid = rng.rand(r) > 0.2
    sel = rng.rand(b, n_slots) > 0.4
    c_half = rng.choice(TIE_POOL, size=b).astype(np.float32)
    n_init = int(rng.randint(0, k + 1))
    init_s = np.full((b, k), -np.inf, np.float32)
    init_s[:, :n_init] = -np.sort(
        -rng.choice(TIE_POOL, size=(b, n_init)).astype(np.float32), axis=1)
    init_r = np.where(init_s > -np.inf,
                      rng.randint(1000, 2000, size=(b, k)), -1).astype(np.int32)
    return b, n_slots, page_rows, k, scores, rvalid, sel, c_half, init_s, init_r


def _run_verify(case, sel):
    b, n_slots, page_rows, k, scores, rvalid, _, c_half, init_s, init_r = case
    rows_flat = np.arange(n_slots * page_rows, dtype=np.int32)
    out = ref._verify_core(jnp.asarray(scores), jnp.asarray(rvalid),
                           jnp.asarray(sel), jnp.asarray(init_s),
                           jnp.asarray(init_r), jnp.asarray(c_half),
                           jnp.asarray(rows_flat), k=k, page_rows=page_rows)
    return [np.asarray(o) for o in out]


def _sequential_reference(case, sel):
    """Plain-Python budgeted sequential scan: the semantics `_verify_core`
    (and through it the fused kernel + batched graph) must reconstruct."""
    b, n_slots, page_rows, k, scores, rvalid, _, c_half, init_s, init_r = case
    top_s = np.empty((b, k), np.float32)
    top_r = np.empty((b, k), np.int32)
    cnt = np.zeros((b, n_slots), np.int32)
    pages = np.zeros(b, np.int32)
    cand = np.zeros(b, np.int32)
    for q in range(b):
        h = int(np.sum(init_s[q] >= c_half[q]))
        live_rows = []
        for j in range(n_slots):
            rows = np.arange(j * page_rows, (j + 1) * page_rows)
            hits = int(np.sum((scores[q, rows] >= c_half[q]) & rvalid[rows]))
            if sel[q, j]:
                cnt[q, j] = hits
                if h < k:                       # Condition-A stop not yet hit
                    pages[q] += 1
                    cand[q] += int(np.sum(rvalid[rows]))
                    live_rows.extend(r for r in rows if rvalid[r])
                h += hits
        # merge carried entries first, then live rows ascending: stable
        # descending sort == lax.top_k's lowest-index-among-ties rule
        all_s = np.concatenate([init_s[q],
                                scores[q, live_rows].astype(np.float32)])
        all_r = np.concatenate([init_r[q],
                                np.asarray(live_rows, np.int32)])
        order = np.argsort(-all_s, kind="stable")[:k]
        top_s[q] = all_s[order]
        top_r[q] = np.where(top_s[q] > -np.inf, all_r[order], -1)
    return top_s, top_r, cnt, pages, cand


def check_condition_a_sequential_and_monotone(seed):
    case = _verify_case(seed)
    b, n_slots = case[0], case[1]
    sel = case[6]
    got = _run_verify(case, sel)
    want = _sequential_reference(case, sel)
    for name, g, w in zip(("top_s", "top_r", "cnt", "pages", "cand"),
                          got, want):
        np.testing.assert_array_equal(g, w, err_msg=f"{name} (seed={seed})")

    # monotone in budget: selecting only the first t slots never increases
    # any accounting and never improves any rank of the top-k
    prev = None
    for t in range(n_slots + 1):
        sel_t = sel.copy()
        sel_t[:, t:] = False
        top_s, _, _, pages, cand = _run_verify(case, sel_t)
        if prev is not None:
            p_top, p_pages, p_cand = prev
            assert (pages >= p_pages).all(), (seed, t)
            assert (cand >= p_cand).all(), (seed, t)
            assert (top_s >= p_top).all(), (seed, t)
        prev = (top_s, pages, cand)


# ---------------------------------------------------------------------------
# seeded grid (always runs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(12))
def test_merge_idempotent(seed):
    k, sa, ra, _, _ = _case(seed)
    check_merge_idempotent(k, sa, ra)


@pytest.mark.parametrize("seed", range(12))
def test_merge_commutative(seed):
    check_merge_commutative(*_case(seed))


@pytest.mark.parametrize("seed", range(12))
def test_tie_rule_matches_lax_top_k(seed):
    k, sa, ra, _, _ = _case(seed)
    check_tie_rule_matches_lax_top_k(k, sa, ra)


@pytest.mark.parametrize("seed", range(10))
def test_condition_a_sequential_and_monotone(seed):
    check_condition_a_sequential_and_monotone(seed)


# ---------------------------------------------------------------------------
# hypothesis amplifier (when installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    seeds = st.integers(min_value=0, max_value=2**31 - 1)

    @given(seeds)
    @settings(max_examples=60, deadline=None)
    def test_merge_idempotent_fuzz(seed):
        k, sa, ra, _, _ = _case(seed)
        check_merge_idempotent(k, sa, ra)

    @given(seeds)
    @settings(max_examples=60, deadline=None)
    def test_merge_commutative_fuzz(seed):
        check_merge_commutative(*_case(seed))

    @given(seeds)
    @settings(max_examples=60, deadline=None)
    def test_tie_rule_fuzz(seed):
        k, sa, ra, _, _ = _case(seed)
        check_tie_rule_matches_lax_top_k(k, sa, ra)

    @given(seeds)
    @settings(max_examples=25, deadline=None)
    def test_condition_a_fuzz(seed):
        check_condition_a_sequential_and_monotone(seed)
