"""Streaming index subsystem (DESIGN.md §8): delta segments, tombstones,
snapshot consistency, compaction parity, seeded-build determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.exact import exact_topk
from repro.core.index import IndexArrays, build_index
from repro.core.promips import ProMIPS
from repro.core.runtime import RuntimeConfig, search, search_segments
from repro.core.sharded import MutableShardedProMIPS
from repro.stream import MutableProMIPS
from repro.stream.compaction import rebuild_base

BUILD = dict(m=8, seed=7)
K = 10


def _corpus(n=1200, d=24, seed=0):
    rng = np.random.RandomState(seed)
    x = rng.randn(n, d).astype(np.float32) * (1 + rng.rand(n, 1).astype(np.float32))
    q = rng.randn(6, d).astype(np.float32)
    return x, q


def _alive_state(st: MutableProMIPS):
    """(gids, rows) reconstructed from the SNAPSHOT arrays — an oracle
    independent of `MutableProMIPS.alive_items()` (host bookkeeping): it
    reads what the device search actually sees. A dedicated test asserts
    the two agree."""
    snap = st.snapshot()
    ba = np.asarray(snap.base_alive)
    bi = np.asarray(snap.arrays.ids)
    bx = np.asarray(snap.arrays.x)
    dv = np.asarray(snap.delta_valid)
    return (np.concatenate([bi[ba], np.asarray(snap.delta_gids)[dv]]),
            np.concatenate([bx[ba], np.asarray(snap.delta_x)[dv]]))


def _exact_ref(st, q, k=K):
    gids, rows = _alive_state(st)
    pos, scores = exact_topk(rows, q, k)
    return gids[pos], scores


def test_clean_stream_equals_static_index():
    """A write-free stream is bit-identical to the plain runtime search."""
    x, q = _corpus()
    st = MutableProMIPS(x, **BUILD)
    ids, scores, stats = st.search(q, k=K)

    ref = build_index(x, **BUILD)
    arrays = jax.tree.map(jnp.asarray, ref.arrays)
    rid, rsc, _ = search(arrays, ref.meta, q, RuntimeConfig(k=K))
    assert np.array_equal(np.asarray(ids), np.asarray(rid))
    assert np.array_equal(np.asarray(scores), np.asarray(rsc))


def test_delta_rows_scored_exactly():
    """Inserted rows merge into the top-k with EXACT inner products."""
    x, q = _corpus()
    st = MutableProMIPS(x, **BUILD)
    rng = np.random.RandomState(1)
    new = rng.randn(40, x.shape[1]).astype(np.float32) * 3  # big norms: must win
    gids = np.arange(10_000, 10_040)
    st.insert(gids, new)

    ids, scores, _ = st.search(q, k=K)
    ids, scores = np.asarray(ids), np.asarray(scores)
    for b in range(len(q)):
        for j in range(K):
            g = ids[b, j]
            if g >= 10_000:
                want = float(new[g - 10_000] @ q[b])
                assert scores[b, j] == pytest.approx(want, rel=1e-5)
    assert (ids >= 10_000).any(), "high-norm delta rows should reach the top-k"


def test_tombstones_mask_deleted_rows():
    x, q = _corpus()
    st = MutableProMIPS(x, **BUILD)
    first, _, _ = st.search(q, k=K)
    victims = np.unique(np.asarray(first)[:, :3].ravel())
    st.delete(victims)

    ids, scores, _ = st.search(q, k=K)
    assert not np.isin(np.asarray(ids), victims).any()
    eids, escores = _exact_ref(st, q)
    rec = np.mean([len(set(np.asarray(ids)[b]) & set(eids[b])) / K
                   for b in range(len(q))])
    assert rec == 1.0
    np.testing.assert_allclose(np.sort(np.asarray(scores), axis=1),
                               np.sort(escores, axis=1), rtol=1e-5)


def test_update_moves_row_to_delta():
    x, q = _corpus()
    st = MutableProMIPS(x, **BUILD)
    st.update([0, 1], 5.0 * np.ones((2, x.shape[1]), np.float32))
    ids, scores, _ = st.search(q, k=K)
    ids, scores = np.asarray(ids), np.asarray(scores)
    for b in range(len(q)):
        for j in range(K):
            if ids[b, j] in (0, 1):
                assert scores[b, j] == pytest.approx(float(5.0 * q[b].sum()), rel=1e-4)
    assert st.n_alive == x.shape[0]


def test_alive_items_matches_snapshot_view():
    """Host bookkeeping (alive_items) and the published snapshot arrays
    agree row-for-row after arbitrary churn."""
    x, _ = _corpus(n=300, d=16, seed=20)
    st = MutableProMIPS(x, **BUILD)
    rng = np.random.RandomState(21)
    _random_ops(st, rng, rounds=8, id_base=40_000)
    ag, ar = st.alive_items()
    sg, sr = _alive_state(st)
    assert np.array_equal(ag, sg)
    assert np.array_equal(ar, sr)


def test_snapshot_isolation_under_writes():
    """An in-flight search (old snapshot) is immune to concurrent writes."""
    x, q = _corpus()
    st = MutableProMIPS(x, **BUILD)
    snap0 = st.snapshot()
    top0, _, _ = search_segments(snap0, q, RuntimeConfig(k=K))
    victim = int(np.asarray(top0)[0, 0])

    st.delete([victim])
    again, _, _ = search_segments(snap0, q, RuntimeConfig(k=K))
    assert np.array_equal(np.asarray(again), np.asarray(top0)), \
        "old snapshot must keep answering for its epoch"
    fresh, _, _ = st.search(q, k=K)
    assert victim not in set(np.asarray(fresh)[0])
    assert st.snapshot().epoch > snap0.epoch


def _random_ops(st, rng, rounds, id_base):
    """Random interleaving of insert/delete/update against live state."""
    alive = set(np.asarray(st._base.arrays.ids))
    alive.discard(-1)
    nxt = id_base
    for _ in range(rounds):
        op = rng.choice(["insert", "delete", "update"])
        if op == "insert":
            cnt = rng.randint(1, 12)
            gids = np.arange(nxt, nxt + cnt)
            nxt += cnt
            st.insert(gids, rng.randn(cnt, st.d).astype(np.float32))
            alive.update(gids.tolist())
        elif op == "delete" and alive:
            victims = rng.choice(sorted(alive), size=min(8, len(alive)),
                                 replace=False)
            st.delete(victims)
            alive.difference_update(victims.tolist())
        elif alive:
            targets = rng.choice(sorted(alive), size=min(4, len(alive)),
                                 replace=False)
            st.update(targets, rng.randn(len(targets), st.d).astype(np.float32))
    return alive


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_parity_after_churn_and_compaction(seed):
    """Acceptance: any interleaving of inserts/deletes/updates followed by
    compaction returns IDENTICAL (ids, scores) to a fresh build over the
    surviving rows; pre-compaction recall over the same state is exact-top-k
    against the alive oracle (delta scored exactly)."""
    x, q = _corpus(n=700, d=16, seed=seed)
    st = MutableProMIPS(x, **BUILD)
    rng = np.random.RandomState(100 + seed)
    _random_ops(st, rng, rounds=12, id_base=50_000)

    # pre-compaction: merged results recall the exact top-k over alive rows
    ids_pre, scores_pre, _ = st.search(q, k=K)
    eids, escores = _exact_ref(st, q)
    rec = np.mean([len(set(np.asarray(ids_pre)[b]) & set(eids[b])) / K
                   for b in range(len(q))])
    assert rec == 1.0
    gids, rows = _alive_state(st)

    # post-compaction: bit-identical to the cold build over the survivors
    st.compact()
    assert st.churn_fraction == 0.0 and st.n_alive == len(gids)
    ids_post, scores_post, _ = st.search(q, k=K)
    fresh = rebuild_base(gids, rows, dict(BUILD))
    fid, fsc, _ = search(jax.tree.map(jnp.asarray, fresh.arrays), fresh.meta,
                         q, RuntimeConfig(k=K))
    assert np.array_equal(np.asarray(ids_post), np.asarray(fid))
    assert np.array_equal(np.asarray(scores_post), np.asarray(fsc))


def test_background_compaction_absorbs_concurrent_writes():
    """Writes landing while the rebuild runs are replayed onto the new base."""
    x, q = _corpus(n=600, d=16, seed=3)
    st = MutableProMIPS(x, auto_compact=True,
                        **BUILD)
    rng = np.random.RandomState(9)
    alive = _random_ops(st, rng, rounds=30, id_base=80_000)
    # keep writing regardless of whether the trigger already fired
    extra = np.arange(90_000, 90_020)
    st.insert(extra, rng.randn(20, st.d).astype(np.float32))
    alive.update(extra.tolist())
    st.join_compaction(timeout=120)

    gids, _ = _alive_state(st)
    assert set(gids.tolist()) == alive
    ids, _, _ = st.search(q, k=K)
    eids, _ = _exact_ref(st, q)
    rec = np.mean([len(set(np.asarray(ids)[b]) & set(eids[b])) / K
                   for b in range(len(q))])
    assert rec == 1.0


def test_delta_overflow_triggers_synchronous_compact():
    x, q = _corpus(n=400, d=16, seed=4)
    st = MutableProMIPS(x, delta_capacity=32, **BUILD)
    rng = np.random.RandomState(5)
    for i in range(4):  # 4 x 20 rows > capacity 32 -> must self-compact
        st.insert(np.arange(70_000 + i * 20, 70_000 + (i + 1) * 20),
                  rng.randn(20, st.d).astype(np.float32))
    assert st.n_alive == 400 + 80
    ids, _, _ = st.search(q, k=K)
    eids, _ = _exact_ref(st, q)
    assert len(set(np.asarray(ids)[0]) & set(eids[0])) == K


def test_write_validation():
    x, _ = _corpus(n=200, d=16, seed=6)
    st = MutableProMIPS(x, delta_capacity=64, **BUILD)
    with pytest.raises(ValueError):
        st.insert([0], np.zeros((1, 16), np.float32))  # id 0 already alive
    with pytest.raises(KeyError):
        st.delete([999_999])
    st.delete([3])
    with pytest.raises(KeyError):
        st.delete([3])  # double delete
    gids = st.add(np.ones((2, 16), np.float32))
    assert gids.tolist() == [200, 201]
    assert st.n_alive == 201

    with pytest.raises(ValueError):
        st.insert([300, 300], np.zeros((2, 16), np.float32))  # dup in call
    with pytest.raises(ValueError):
        st.delete([200, 200])  # dup in call — must mutate nothing
    assert st.n_alive == 201
    with pytest.raises(ValueError):
        st.insert([2 ** 31], np.zeros((1, 16), np.float32))  # int32 overflow
    with pytest.raises(ValueError):  # batch larger than the delta itself
        st.update(np.arange(10, 80),
                  np.zeros((70, 16), np.float32))
    assert st._is_alive(10), "oversized update must not tombstone anything"
    # update bigger than the FREE delta space but within capacity: the insert
    # half self-compacts and the replacements land — nothing is lost
    st.insert(np.arange(300, 350), np.ones((50, 16), np.float32))
    st.update(np.arange(300, 340), 2 * np.ones((40, 16), np.float32))
    assert st.n_alive == 251


def test_sharded_mutable_churn():
    """Per-shard deltas: writes routed by contiguous ID range keep the pod
    path's global top-k correct under churn."""
    x, q = _corpus(n=800, d=16, seed=8)
    sh = MutableShardedProMIPS(x, 2, **BUILD)
    assert [s.meta.n for s in sh.shards] == [400, 400]
    rng = np.random.RandomState(11)

    sh.delete(np.arange(0, 30))            # shard 0 range
    sh.delete(np.arange(500, 520))         # shard 1 range
    new = rng.randn(40, 16).astype(np.float32) * 2.5
    sh.insert(np.arange(2_000, 2_040), new)  # past the corpus: last shard
    assert sh.shards[1]._delta.count == 40 and sh.shards[0]._delta.count == 0
    sh.update(np.arange(100, 104), rng.randn(4, 16).astype(np.float32))
    assert sh.n_alive == 800 - 50 + 40

    def oracle():
        gid_all, row_all = [], []
        for s in sh.shards:
            g, r = _alive_state(s)
            gid_all.append(g)
            row_all.append(r)
        g, r = np.concatenate(gid_all), np.concatenate(row_all)
        pos, sc = exact_topk(r, q, K)
        return g[pos], sc

    ids, scores, stats = sh.search(q, k=K)
    eids, escores = oracle()
    rec = np.mean([len(set(ids[b]) & set(eids[b])) / K for b in range(len(q))])
    assert rec == 1.0 and stats.pages > 0
    assert stats.to_dict()["queries"] == len(q)

    sh.compact()
    ids2, scores2, _ = sh.search(q, k=K)
    eids2, escores2 = oracle()
    rec2 = np.mean([len(set(ids2[b]) & set(eids2[b])) / K for b in range(len(q))])
    assert rec2 == 1.0
    np.testing.assert_allclose(np.sort(scores2, 1), np.sort(escores2, 1), rtol=1e-5)


# -- seeded-build determinism (the contract compaction rebuilds rely on) -----

def test_build_determinism_same_seed_bit_identical():
    x, _ = _corpus(n=900, d=24, seed=12)
    a = build_index(x, m=8, seed=13, norm_strata=2)
    b = build_index(x, m=8, seed=13, norm_strata=2)
    for field in IndexArrays._fields:
        assert np.array_equal(np.asarray(getattr(a.arrays, field)),
                              np.asarray(getattr(b.arrays, field))), field
    assert a.meta == b.meta

    pm1 = ProMIPS.build(x, m=8, seed=13)
    pm2 = ProMIPS.build(x, m=8, seed=13)
    assert np.array_equal(pm1.index.arrays.p, pm2.index.arrays.p)

    c = build_index(x, m=8, seed=14)
    assert not np.array_equal(a.arrays.p, c.arrays.p), \
        "different seed should draw a different projection"


def test_rebuild_base_order_invariant():
    """rebuild_base canonicalizes row order, so any presentation order of the
    same surviving set compacts to a bit-identical base."""
    x, _ = _corpus(n=500, d=16, seed=14)
    gids = np.arange(500)
    perm = np.random.RandomState(15).permutation(500)
    a = rebuild_base(gids, x, dict(BUILD))
    b = rebuild_base(gids[perm], x[perm], dict(BUILD))
    for field in IndexArrays._fields:
        assert np.array_equal(np.asarray(getattr(a.arrays, field)),
                              np.asarray(getattr(b.arrays, field))), field
