"""End-to-end behaviour of the paper's system: build -> search -> guarantee,
plus the launcher cell-builder lowering on a small mesh (dry-run preflight)."""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.baselines.exact import exact_topk
from repro.core import ProMIPS, overall_ratio
from repro.data.synthetic import paper_dataset, paper_queries

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_quickstart_path():
    """The README quickstart: paper-default parameters on a Netflix-like
    corpus must give ratio >= c for >= p of queries."""
    x = paper_dataset("netflix")[:4000]
    q = paper_queries("netflix", 12)
    pm = ProMIPS.build(x, m=6, c=0.9, p=0.5)  # paper defaults (m per §VIII-A4)
    eids, escores = exact_topk(x, q, 10)
    ratios, pages = [], []
    for i in range(len(q)):
        ids, scores, st = pm.search_host(q[i], k=10)
        ratios.append(overall_ratio(scores, escores[i]))
        pages.append(st.pages)
    assert np.mean([r >= 0.9 for r in ratios]) >= 0.5
    assert np.mean(ratios) >= 0.85


def test_dryrun_cell_builder_small_mesh():
    """Every cell kind lowers under a 2x2 mesh in a subprocess (preflight of
    the 512-device dry-run; full matrix in results/dryrun)."""
    code = textwrap.dedent("""
        import jax
        from repro.configs import get_config, SHAPES_BY_NAME
        from repro.launch import specs as S
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        for arch, shape in [("tinyllama-1.1b", "train_4k"),
                            ("xlstm-1.3b", "long_500k"),
                            ("whisper-base", "decode_32k")]:
            cfg = get_config(arch)
            sh = SHAPES_BY_NAME[shape]
            fn, args, in_sh, out_sh = S.build_cell(cfg, sh, mesh)
            jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
            print("LOWERED", arch, shape)
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.count("LOWERED") == 3


def test_dryrun_results_if_present():
    """If the full dry-run matrix has been produced, every cell must be ok
    or an annotated skip (this is the §Dry-run acceptance check)."""
    import glob
    import json
    root = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    files = glob.glob(os.path.join(root, "*", "*", "*.json"))
    if not files:
        import pytest
        pytest.skip("dry-run matrix not generated in this environment")
    bad = []
    for f in files:
        rec = json.load(open(f))
        if rec["status"] not in ("ok", "skipped(full-attention)"):
            bad.append((f, rec["status"]))
    assert not bad, bad
