"""Quick-Probe (paper Section V): Theorems 3 & 4 bounds, packing, Algorithm 2
host/device agreement."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly offline
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp
from repro.core.projections import make_projection, project
from repro.core.quick_probe import (
    build_group_table, group_lower_bounds, pack_codes, pack_codes_np,
    quick_probe, unpack_bits)


@given(st.integers(1, 30), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(m, n, seed):
    rng = np.random.RandomState(seed)
    p = rng.standard_normal((n, m)).astype(np.float32)
    codes = pack_codes_np(p)
    assert np.array_equal(codes, np.asarray(pack_codes(jnp.asarray(p))))
    bits = np.asarray(unpack_bits(jnp.asarray(codes), m))
    assert np.array_equal(bits, (p >= 0).astype(np.float32))


@given(st.integers(2, 24), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_theorem3_lower_bound_valid(m, seed):
    """LB_g <= dis(P(o), P(q)) for every member o of group g."""
    rng = np.random.RandomState(seed)
    n, d = 128, 24
    x = rng.standard_normal((n, d)).astype(np.float32)
    q = rng.standard_normal(d).astype(np.float32)
    a = make_projection(d, m, seed=seed % 97)
    po, pq = project(x, a), project(q, a)
    codes = pack_codes_np(po)
    qcode = pack_codes_np(pq[None])[0]
    lb = np.asarray(group_lower_bounds(jnp.asarray(codes), jnp.uint32(qcode),
                                       jnp.asarray(pq)))
    true = np.linalg.norm(po - pq[None], axis=1)
    assert np.all(lb <= true + 1e-3 * np.abs(true) + 1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_theorem4_upper_bound_valid(seed):
    """dis(o, q) <= ||o||_1 + ||q||_1 (original space)."""
    rng = np.random.RandomState(seed)
    d = rng.randint(2, 64)
    o = rng.standard_normal(d) * rng.gamma(2, 2)
    q = rng.standard_normal(d) * rng.gamma(2, 2)
    assert np.linalg.norm(o - q) <= np.abs(o).sum() + np.abs(q).sum() + 1e-9


def test_group_table_min_l1_is_min():
    rng = np.random.RandomState(0)
    n, m, d = 300, 6, 16
    x = rng.standard_normal((n, d)).astype(np.float32)
    a = make_projection(d, m, seed=0)
    p = project(x, a)
    l1 = np.abs(x).sum(1).astype(np.float32)
    codes = pack_codes_np(p)
    table = build_group_table(codes, l1, p)
    for gi in range(len(table.code)):
        members = np.nonzero(codes == table.code[gi])[0]
        assert np.isclose(table.min_l1[gi], l1[members].min())
        assert codes[table.rep_row[gi]] == table.code[gi]
        assert np.isclose(l1[table.rep_row[gi]], l1[members].min())


def test_quick_probe_vectorised_equals_sequential():
    """Vectorised Algorithm 2 == faithful ascending-LB sequential scan."""
    from repro.core.chi2 import chi2_ppf_host
    rng = np.random.RandomState(3)
    n, d, m, c, p = 500, 24, 8, 0.9, 0.5
    x = (rng.standard_normal((n, d)) * 0.2).astype(np.float32)  # small norms
    q = rng.standard_normal(d).astype(np.float32) * 3
    a = make_projection(d, m, seed=1)
    po, pq = project(x, a), project(q, a)
    l1 = np.abs(x).sum(1).astype(np.float32)
    codes = pack_codes_np(po)
    table = build_group_table(codes, l1, po)
    x_p = chi2_ppf_host(p, m)
    row, radius, ok = quick_probe(
        table, jnp.asarray(pq), jnp.float32(np.abs(q).sum()), c, x_p)
    # sequential reference
    qcode = pack_codes_np(pq[None])[0]
    lb = np.asarray(group_lower_bounds(jnp.asarray(table.code), jnp.uint32(qcode),
                                       jnp.asarray(pq)))
    order = np.argsort(lb, kind="stable")
    chosen, best_v, best_g = -1, -np.inf, order[0]
    for g in order:
        val = lb[g] ** 2 / max(c * (table.min_l1[g] + np.abs(q).sum()) ** 2, 1e-30)
        if val >= x_p:
            chosen = g
            break
        if val > best_v:
            best_v, best_g = val, g
    if chosen < 0:
        chosen = best_g
    assert int(row) == int(table.rep_row[chosen])
    exp_r = np.linalg.norm(table.rep_proj[chosen] - pq)
    assert np.isclose(float(radius), exp_r, rtol=1e-5)
