"""Distributed substrate tests — run in subprocesses with a multi-device
host platform so the main pytest process keeps its single real CPU device."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_int8_psum_shard_map():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.compression import int8_psum
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("data",))
        x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 40.0
        f = shard_map(lambda s: int8_psum(s, "data"), mesh=mesh,
                      in_specs=P("data"), out_specs=P("data"), check_rep=False)
        got = np.asarray(f(x))
        want = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), (8, 16))
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert err < 0.02, err     # int8 quantisation error bound
        print("OK", err)
    """)
    assert "OK" in out


def test_sharded_promips_search():
    out = _run("""
        import jax, numpy as np
        from repro.core.sharded import (build_sharded, sharded_search,
                                        device_put_sharded_index)
        from repro.baselines.exact import exact_topk
        from repro.core import overall_ratio
        from repro.data.synthetic import mf_factors
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        x = mf_factors(4000, 48, 12, decay=0.3, seed=0)
        q = mf_factors(8, 48, 12, decay=0.3, seed=1)
        sh = build_sharded(x, 4, m=6, c=0.9, p=0.7, norm_strata=4)
        shd = device_put_sharded_index(sh, mesh)
        ids, scores, pages = sharded_search(shd, q, 10, mesh,
                                            budget=sh.meta.n_blocks)
        eids, escores = exact_topk(x, q, 10)
        rs = [overall_ratio(np.asarray(scores)[i], escores[i]) for i in range(8)]
        frac = np.mean([r >= 0.9 for r in rs])
        assert frac >= 0.7, (frac, rs)
        print("OK", np.mean(rs))
    """)
    assert "OK" in out


def test_sharded_fused_in_graph_parity():
    """verification="fused" inside sharded_search's shard_map runs the
    in-graph fused driver: bit-identical ids/scores/pages to the batched
    graph AND to the eager host-orchestrated per-shard fused searches
    merged with the same all-gather + top_k rule."""
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import RuntimeConfig
        from repro.core.runtime import search as runtime_search
        from repro.core.sharded import (build_sharded, sharded_search,
                                        device_put_sharded_index)
        from repro.data.synthetic import mf_factors
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("model",))
        x = mf_factors(8000, 48, 12, decay=0.3, seed=0, norm_tail=0.3)
        q = mf_factors(16, 48, 12, decay=0.3, seed=1)
        sh = build_sharded(x, 8, m=6, c=0.9, p=0.7, norm_strata=4)
        shd = device_put_sharded_index(sh, mesh)
        cfg_f = RuntimeConfig(mode="two_phase", verification="fused",
                              norm_adaptive=True, cs_prune=True)
        cfg_b = dataclasses.replace(cfg_f, verification="batched")
        ids_f, s_f, pages_f = sharded_search(shd, q, 10, mesh, runtime=cfg_f)
        ids_b, s_b, pages_b = sharded_search(shd, q, 10, mesh, runtime=cfg_b)
        np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_b))
        np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_b))
        assert int(pages_f) == int(pages_b), (pages_f, pages_b)

        # eager reference: host-orchestrated fused per shard + same merge
        cfg = dataclasses.replace(cfg_f, k=10)
        ids_all, s_all, pages = [], [], 0
        for s in range(8):
            arrays = jax.tree.map(lambda a: jnp.asarray(np.asarray(a)[s]),
                                  sh.arrays)
            i_, sc, st = runtime_search(arrays, sh.meta,
                                        jnp.asarray(q, jnp.float32), cfg)
            ids_all.append(np.asarray(i_)); s_all.append(np.asarray(sc))
            pages += int(np.sum(np.asarray(st.pages)))
        flat_i = np.concatenate(ids_all, axis=1)
        flat_s = np.concatenate(s_all, axis=1)
        best_s, pos = jax.lax.top_k(jnp.asarray(flat_s), 10)
        best_i = np.take_along_axis(flat_i, np.asarray(pos), axis=1)
        np.testing.assert_array_equal(np.asarray(ids_f), best_i)
        np.testing.assert_array_equal(np.asarray(s_f), np.asarray(best_s))
        assert pages == int(pages_f), (pages, pages_f)
        print("OK", pages)
    """)
    assert "OK" in out


def test_sharded_prefilter_parity():
    """The sketch prefilter under shard_map (8 shards): in-graph fused and
    batched agree bit-for-bit, match the eager per-shard host-fused merge,
    and read fewer pages than prefilter-off."""
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import RuntimeConfig
        from repro.core.runtime import search as runtime_search
        from repro.core.sharded import (build_sharded, sharded_search,
                                        device_put_sharded_index)
        from repro.data.synthetic import mf_factors
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("model",))
        x = mf_factors(8000, 48, 12, decay=0.3, seed=0, norm_tail=0.3)
        q = mf_factors(16, 48, 12, decay=0.3, seed=1)
        sh = build_sharded(x, 8, m=6, c=0.9, p=0.7, norm_strata=4)
        shd = device_put_sharded_index(sh, mesh)
        cfg_f = RuntimeConfig(mode="two_phase", verification="fused",
                              norm_adaptive=True, cs_prune=True,
                              prefilter=True, prefilter_eps=0.3)
        cfg_b = dataclasses.replace(cfg_f, verification="batched")
        ids_f, s_f, pages_f = sharded_search(shd, q, 10, mesh, runtime=cfg_f)
        ids_b, s_b, pages_b = sharded_search(shd, q, 10, mesh, runtime=cfg_b)
        np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_b))
        np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_b))
        assert int(pages_f) == int(pages_b), (pages_f, pages_b)
        _, _, pages_off = sharded_search(
            shd, q, 10, mesh,
            runtime=dataclasses.replace(cfg_f, prefilter=False))
        assert int(pages_f) < int(pages_off), (pages_f, pages_off)

        cfg = dataclasses.replace(cfg_f, k=10)
        ids_all, s_all, pages = [], [], 0
        for s in range(8):
            arrays = jax.tree.map(lambda a: jnp.asarray(np.asarray(a)[s]),
                                  sh.arrays)
            i_, sc, st = runtime_search(arrays, sh.meta,
                                        jnp.asarray(q, jnp.float32), cfg)
            ids_all.append(np.asarray(i_)); s_all.append(np.asarray(sc))
            pages += int(np.sum(np.asarray(st.pages)))
        flat_i = np.concatenate(ids_all, axis=1)
        flat_s = np.concatenate(s_all, axis=1)
        best_s, pos = jax.lax.top_k(jnp.asarray(flat_s), 10)
        best_i = np.take_along_axis(flat_i, np.asarray(pos), axis=1)
        np.testing.assert_array_equal(np.asarray(ids_f), best_i)
        np.testing.assert_array_equal(np.asarray(s_f), np.asarray(best_s))
        assert pages == int(pages_f), (pages, pages_f)
        print("OK", int(pages_f), int(pages_off))
    """)
    assert "OK" in out


def test_train_sharded_and_elastic_restore(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    out = _run(f"""
        import sys
        sys.argv = ["train", "--arch", "tinyllama-1.1b", "--reduced",
                    "--steps", "8", "--batch", "4", "--seq", "64",
                    "--ckpt-dir", {ckpt!r}, "--ckpt-every", "4",
                    "--log-every", "0"]
        from repro.launch.train import main
        losses = main()
        print("FIRST", losses[0], losses[-1])
    """, devices=4)
    assert "FIRST" in out
    # resume on a DIFFERENT device count (elastic reshard on load)
    out2 = _run(f"""
        import sys
        sys.argv = ["train", "--arch", "tinyllama-1.1b", "--reduced",
                    "--steps", "12", "--batch", "4", "--seq", "64",
                    "--ckpt-dir", {ckpt!r}, "--log-every", "0"]
        from repro.launch.train import main
        losses = main()
        print("RESUMED", len(losses))
    """, devices=2)
    assert "RESUMED 4" in out2


def test_grad_compression_error_feedback():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import (compress_grads,
                                                   error_feedback_init)
        params = {"w": jnp.zeros((64, 64))}
        ef = error_feedback_init(params)
        rng = np.random.RandomState(0)
        true_sum = np.zeros((64, 64))
        sent_sum = np.zeros((64, 64))
        for i in range(50):
            g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
            true_sum += np.asarray(g["w"])
            gq, ef = compress_grads(g, ef)
            sent_sum += np.asarray(gq["w"])
        # error feedback: accumulated compressed grads track the true sum
        rel = np.abs(sent_sum - true_sum).max() / np.abs(true_sum).max()
        assert rel < 0.05, rel
        print("OK", rel)
    """, devices=1)
    assert "OK" in out


def test_checkpoint_roundtrip(tmp_path):
    from repro.distributed import checkpoint as C
    import jax.numpy as jnp
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.int32)}}
    C.save(str(tmp_path), 7, tree)
    assert C.latest_step(str(tmp_path)) == 7
    out = C.restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10.0))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.ones((3, 4)))
    # incomplete checkpoints are invisible
    os.makedirs(tmp_path / "step_9", exist_ok=True)
    assert C.latest_step(str(tmp_path)) == 7


def test_straggler_monitor():
    import time
    from repro.distributed.fault import StragglerMonitor
    mon = StragglerMonitor(threshold=2.0)
    for _ in range(3):
        mon.start(); time.sleep(0.01); mon.stop()
    mon.start(); time.sleep(0.08)
    assert mon.stop() is True
    assert mon.events == 1
