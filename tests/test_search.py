"""End-to-end c-k-AMIP guarantees (Theorems 1-2) on host + device paths,
MIP-Search-I vs II, progressive mode, and the paper's accuracy metric."""
import numpy as np
import pytest

from repro.baselines.exact import exact_topk
from repro.core import ProMIPS, overall_ratio, recall_at_k


@pytest.fixture(scope="module")
def built(mf_corpus):
    x, q = mf_corpus
    pm = ProMIPS.build(x, m=8, c=0.9, p=0.5, norm_strata=4, page_bytes=2048)
    eids, escores = exact_topk(x, q, 10)
    return x, q, pm, eids, escores


def _guarantee_fraction(ratios, c):
    return np.mean([r >= c - 1e-6 for r in ratios])


def test_host_search_guarantee(built):
    """P[overall ratio >= c] >= p across queries (Theorem 2)."""
    x, q, pm, eids, escores = built
    ratios, pages = [], []
    for i in range(len(q)):
        ids, scores, st = pm.search_host(q[i], k=10)
        assert len(set(ids.tolist())) == 10  # no duplicates
        ratios.append(overall_ratio(scores, escores[i]))
        pages.append(st.pages)
    assert _guarantee_fraction(ratios, 0.9) >= 0.5
    assert np.mean(ratios) > 0.85


def test_host_progressive_guarantee_and_fewer_pages(built):
    x, q, pm, eids, escores = built
    r_prog, pg_prog, pg_paper = [], [], []
    for i in range(len(q)):
        ids, scores, st = pm.search_host_progressive(q[i], k=10)
        r_prog.append(overall_ratio(scores, escores[i]))
        pg_prog.append(st.pages)
        _, _, st2 = pm.search_host(q[i], k=10)
        pg_paper.append(st2.pages)
    assert _guarantee_fraction(r_prog, 0.9) >= 0.5
    assert np.mean(pg_prog) <= np.mean(pg_paper)  # beyond-paper: never worse


def test_incremental_matches_conditions(built):
    """MIP-Search-I terminates via A or B and satisfies the guarantee."""
    x, q, pm, eids, escores = built
    ratios = []
    for i in range(8):
        ids, scores, st = pm.search_incremental(q[i], k=10)
        assert st.stopped_by in ("A", "B", "exhausted")
        ratios.append(overall_ratio(scores, escores[i]))
    assert _guarantee_fraction(ratios, 0.9) >= 0.5


def test_device_matches_host_semantics(built):
    """Device mode (jit, batched) achieves the same guarantee."""
    x, q, pm, eids, escores = built
    ids, scores, stats = pm.search(q, k=10)
    ids, scores = np.asarray(ids), np.asarray(scores)
    ratios = [overall_ratio(scores[i], escores[i]) for i in range(len(q))]
    assert _guarantee_fraction(ratios, 0.9) >= 0.5
    assert not np.asarray(stats.exhausted).any()
    # ids valid & deduplicated
    for i in range(len(q)):
        got = ids[i][ids[i] >= 0]
        assert len(set(got.tolist())) == len(got)


def test_device_progressive(built):
    x, q, pm, eids, escores = built
    ids, scores, stats = pm.search_progressive(q, k=10)
    ratios = [overall_ratio(np.asarray(scores)[i], escores[i]) for i in range(len(q))]
    assert _guarantee_fraction(ratios, 0.9) >= 0.5


def test_full_budget_exact_recovery(mf_corpus):
    """With c -> 1, p -> 1 the search must return the exact MIPS top-k."""
    x, q = mf_corpus
    pm = ProMIPS.build(x, m=8, c=0.999, p=0.999, norm_strata=1)
    eids, escores = exact_topk(x, q[:8], 5)
    for i in range(8):
        ids, scores, st = pm.search_host(q[i], k=5)
        assert recall_at_k(ids, eids[i]) >= 0.8
        assert overall_ratio(scores, escores[i]) >= 0.99


def test_varying_c_p_tradeoff(mf_corpus):
    """Paper Figs. 10-11: smaller c or p => no more pages than larger."""
    x, q = mf_corpus
    pm = ProMIPS.build(x, m=8, c=0.9, p=0.5, norm_strata=4)
    pages = {}
    for c in (0.7, 0.9):
        pg = [pm.search_host(q[i], k=10, c=c)[2].pages for i in range(8)]
        pages[c] = np.mean(pg)
    assert pages[0.7] <= pages[0.9] + 1e-9
    for p in (0.3, 0.9):
        pg = [pm.search_host(q[i], k=10, p=p)[2].pages for i in range(8)]
        pages[f"p{p}"] = np.mean(pg)
    assert pages["p0.3"] <= pages["p0.9"] + 1e-9


def test_metrics():
    assert overall_ratio(np.array([9.0, 4.0]), np.array([10.0, 5.0])) == pytest.approx(0.85)
    assert recall_at_k(np.array([1, 2, 3]), np.array([3, 4, 5])) == pytest.approx(1 / 3)
