# NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests
# and benches must see the single real CPU device. Multi-device tests spawn
# subprocesses (tests/test_distributed.py) or run under their own module
# guard (pytest-forked not available offline).
import os
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def mf_corpus():
    """Small MF-structured corpus shared across search tests."""
    from repro.data.synthetic import mf_factors
    x = mf_factors(4000, 48, 12, decay=0.3, seed=0, norm_tail=0.3)
    q = mf_factors(32, 48, 12, decay=0.3, seed=1)
    return x, q
