"""Edge-case coverage the parity suites miss: k >= n_alive, a fully
tombstoned shard, an empty round-1 union, and B=1 decode-shaped batches
through the fused path (eager host driver AND the in-graph driver)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ProMIPS, RuntimeConfig, runtime_search
from repro.core.sharded import MutableShardedProMIPS
from repro.data.synthetic import mf_factors
from repro.stream.mutable import MutableProMIPS

D = 16


@pytest.fixture(scope="module")
def tiny():
    x = mf_factors(40, D, 4, decay=0.4, seed=0)
    q = mf_factors(3, D, 4, decay=0.4, seed=1)
    pm = ProMIPS.build(x, m=4, c=0.9, p=0.5, page_bytes=256)
    return x, jnp.asarray(q, jnp.float32), pm


# ---------------------------------------------------------------------------
# k >= n_alive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("verification", ["fused", "batched", "scan"])
def test_k_exceeds_corpus(tiny, verification):
    """k > n: every alive row comes back exactly once, the overflow slots
    are (-1, -inf), and all three verification backends agree bitwise."""
    x, q, pm = tiny
    k = 64
    ids, scores, st = pm.search(q, k=k, verification=verification)
    ids, scores = np.asarray(ids), np.asarray(scores)
    assert ids.shape == (3, k)
    for b in range(3):
        valid = ids[b][ids[b] >= 0]
        assert sorted(valid.tolist()) == list(range(len(x)))  # all rows, once
        assert np.all(np.isneginf(scores[b][ids[b] < 0]))
    # exact scores on the valid slots (runtime rescore contract)
    want = np.sort(np.asarray(q) @ x.T, axis=1)[:, ::-1]
    np.testing.assert_allclose(np.sort(scores, axis=1)[:, ::-1][:, :40],
                               want, rtol=1e-5)
    out_f = pm.search(q, k=k, verification="fused")
    np.testing.assert_array_equal(np.asarray(out_f[0]), ids)
    np.testing.assert_array_equal(np.asarray(out_f[1]), scores)


def test_k_exceeds_corpus_in_graph(tiny):
    """The in-graph fused driver handles k > n identically under jit."""
    x, q, pm = tiny
    cfg = RuntimeConfig(k=64)
    out_e = runtime_search(pm.arrays, pm.meta, q, cfg)
    out_t = jax.jit(lambda a: runtime_search(a, pm.meta, q, cfg))(pm.arrays)
    np.testing.assert_array_equal(np.asarray(out_e[0]), np.asarray(out_t[0]))
    np.testing.assert_array_equal(np.asarray(out_e[1]), np.asarray(out_t[1]))


def test_k_exceeds_n_alive_after_deletes():
    """Streaming index with tombstones: n_alive < k <= n_pad returns exactly
    the alive rows (tombstoned rows neither returned nor crowding out)."""
    x = mf_factors(40, D, 4, decay=0.4, seed=0)
    q = mf_factors(3, D, 4, decay=0.4, seed=1)
    ms = MutableProMIPS(x, ids=np.arange(40), m=4, c=0.9, p=0.5,
                        page_bytes=256)
    ms.delete(np.arange(10))
    ids, scores, st = ms.search(q, k=50)
    ids = np.asarray(ids)
    for b in range(3):
        valid = ids[b][ids[b] >= 0]
        assert sorted(valid.tolist()) == list(range(10, 40))
    # post-compaction: same alive set, same answers on the valid slots
    ms.compact()
    ids2, _, _ = ms.search(q, k=50)
    np.testing.assert_array_equal(ids, np.asarray(ids2))


def test_k_exceeds_n_alive_with_prefilter(tiny):
    """Sketch prefilter with k >= n_alive: the group-max threshold needs
    G = min(2k, NB) >= k distinct groups to be sound; below that it must
    degrade to tau = -inf (no pruning) so every alive row still comes back
    — bit-identical to prefilter-off, eager and under jit."""
    x, q, pm = tiny
    k = 64
    assert pm.meta.n_blocks < k  # the degenerate regime this test pins
    for verification in ("fused", "batched"):
        base = pm.search(q, k=k, verification=verification)
        out = pm.search(q, k=k, verification=verification,
                        prefilter=True, prefilter_eps=0.05)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(base[0]))
        np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(base[1]))
    cfg = RuntimeConfig(k=k, prefilter=True, prefilter_eps=0.05)
    out_t = jax.jit(lambda a: runtime_search(a, pm.meta, q, cfg))(pm.arrays)
    out_e = runtime_search(pm.arrays, pm.meta, q, cfg)
    np.testing.assert_array_equal(np.asarray(out_t[0]), np.asarray(out_e[0]))
    np.testing.assert_array_equal(np.asarray(out_t[1]), np.asarray(out_e[1]))


# ---------------------------------------------------------------------------
# fully tombstoned shard
# ---------------------------------------------------------------------------

def test_fully_tombstoned_shard():
    x = mf_factors(200, D, 4, decay=0.4, seed=2)
    q = mf_factors(4, D, 4, decay=0.4, seed=3)
    msh = MutableShardedProMIPS(x, 2, m=4, c=0.9, p=0.5, page_bytes=256)
    msh.delete(np.arange(100))          # shard 0 is now 100% dead
    assert msh.n_alive == 100
    ids, scores, st = msh.search(q, k=10)
    ids = np.asarray(ids)
    assert (ids >= 100).all(), ids      # only shard-1 rows can come back
    # exact over the alive half: the dead shard contributes nothing
    want = np.argsort(-(q @ x[100:].T), axis=1, kind="stable")[:, :10] + 100
    np.testing.assert_array_equal(ids, want)
    assert st.to_dict()["queries"] == 4
    # compacting the empty shard away keeps the same answers
    msh.compact()
    ids2, _, _ = msh.search(q, k=10)
    np.testing.assert_array_equal(ids, np.asarray(ids2))


# ---------------------------------------------------------------------------
# empty round union
# ---------------------------------------------------------------------------

def test_empty_union_round_is_identity(tiny):
    """An all-False (B, NB) selection must be an exact identity on the
    carried top-k with zero pages/candidates and no exhausted flag, on BOTH
    fused drivers (the host planner skips it; the in-graph driver routes it
    to the smallest switch branch with an all-False sel) — and on the
    batched round they must stay bit-identical to."""
    from repro.core import search_fused as sf
    from repro.core.search_device import TopK, _verify_batched
    from repro.core.search_graph import _fused_round_graph

    x, q, pm = tiny
    arrays, meta = pm.arrays, pm.meta
    b, k = q.shape[0], 5
    mask = jnp.zeros((b, meta.n_blocks), bool)
    rng = np.random.RandomState(0)
    top = TopK(scores=jnp.asarray(-np.sort(-rng.rand(b, k)).astype(np.float32)),
               rows=jnp.asarray(rng.randint(0, 40, (b, k)).astype(np.int32)))
    c_half = jnp.asarray(rng.rand(b).astype(np.float32))

    assert sf._plan_tile(np.zeros((b, meta.n_blocks), bool),
                         meta.n_blocks, meta.n_blocks) is None

    out_top, pages, cand, done_a, lost = jax.jit(
        lambda m, t: _fused_round_graph(arrays, q, m, t, c_half, k,
                                        meta.n_blocks, meta.n_blocks,
                                        meta.page_rows, None))(mask, top)
    np.testing.assert_array_equal(np.asarray(out_top.scores),
                                  np.asarray(top.scores))
    np.testing.assert_array_equal(np.asarray(out_top.rows),
                                  np.asarray(top.rows))
    assert not np.asarray(pages).any() and not np.asarray(cand).any()
    assert not np.asarray(lost).any()

    bt, bp, bc, _, bl = _verify_batched(arrays, meta, q, mask, top, c_half,
                                        k, meta.n_blocks, None)
    np.testing.assert_array_equal(np.asarray(bt.scores),
                                  np.asarray(out_top.scores))
    np.testing.assert_array_equal(np.asarray(bt.rows), np.asarray(out_top.rows))
    assert not np.asarray(bp).any() and not np.asarray(bl).any()


def test_prefilter_empty_survivor_round(tiny):
    """A round whose sketch survivor set is empty must be an identity, not
    a crash: (a) the round-2 survivor rule yields all-False when the
    running k-th score beats every upper bound, and (b) an aggressive eps
    end-to-end still returns k valid, exactly-scored rows, bit-identical
    across the fused drivers and the batched graph."""
    from repro.core import search_common as sc

    x, q, pm = tiny
    arrays, meta = pm.arrays, pm.meta
    b = q.shape[0]
    est = jnp.zeros((b, meta.n_blocks), jnp.float32)
    bnd = jnp.ones((b, meta.n_blocks), jnp.float32)
    bvalid = sc.block_valid_from_ids(arrays.ids, meta.page_rows)
    surv = sc.sketch_survivors_round2(
        jnp.ones((b, meta.n_blocks), bool), est, bnd, bvalid,
        jnp.full((b,), jnp.inf, jnp.float32))
    assert not np.asarray(surv).any()

    cfg = RuntimeConfig(k=3, prefilter=True, prefilter_eps=0.01)
    out_e = runtime_search(pm.arrays, pm.meta, q, cfg)
    ids = np.asarray(out_e[0])
    assert (ids >= 0).all()
    scores = np.asarray(out_e[1])
    np.testing.assert_allclose(
        scores, np.take_along_axis(np.asarray(q) @ x.T, ids, axis=1),
        rtol=1e-5)
    out_t = jax.jit(lambda a: runtime_search(a, pm.meta, q, cfg))(pm.arrays)
    out_b = runtime_search(pm.arrays, pm.meta, q,
                           RuntimeConfig(k=3, prefilter=True,
                                         prefilter_eps=0.01,
                                         verification="batched"))
    for out in (out_t, out_b):
        np.testing.assert_array_equal(np.asarray(out[0]), ids)
        np.testing.assert_array_equal(np.asarray(out[1]), scores)


# ---------------------------------------------------------------------------
# B=1 decode-shaped batches
# ---------------------------------------------------------------------------

def test_b1_decode_batch_through_fused(tiny):
    """B=1 (the decode engine's single-slot shape) through the fused path,
    eager and jit'd. At the untruncated default budget the returned IDS
    match the corresponding row of a full-batch search (per-query semantics
    don't depend on batch composition when nothing is truncated); scores
    agree to float tolerance only — XLA reassociates the verification dots
    differently per batch shape, the very reason `runtime._rescore` exists.
    Eager-vs-jit at the SAME B=1 shape stays bit-identical."""
    x, q, pm = tiny
    cfg = RuntimeConfig(k=4)
    ids_b, scores_b, _ = runtime_search(pm.arrays, pm.meta, q, cfg)
    for i in range(q.shape[0]):
        qi = q[i:i + 1]
        ids1, scores1, st1 = runtime_search(pm.arrays, pm.meta, qi, cfg)
        assert np.asarray(ids1).shape == (1, 4)
        np.testing.assert_array_equal(np.asarray(ids1)[0],
                                      np.asarray(ids_b)[i])
        np.testing.assert_allclose(np.asarray(scores1)[0],
                                   np.asarray(scores_b)[i], rtol=1e-5)
        ids_t, scores_t, _ = jax.jit(
            lambda a: runtime_search(a, pm.meta, qi, cfg))(pm.arrays)
        np.testing.assert_array_equal(np.asarray(ids_t), np.asarray(ids1))
        np.testing.assert_array_equal(np.asarray(scores_t),
                                      np.asarray(scores1))
