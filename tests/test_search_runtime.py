"""Unified two-phase runtime: scan-vs-batched device parity, host/device
agreement, and numpy-vs-jnp bit-exactness of the shared `search_common` core."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProMIPS, RuntimeConfig, runtime_search
from repro.core import search_common as sc


@pytest.fixture(scope="module")
def built(mf_corpus):
    x, q = mf_corpus
    pm = ProMIPS.build(x, m=8, c=0.9, p=0.5, norm_strata=4, page_bytes=2048)
    return x, q, pm


@pytest.mark.parametrize("norm_adaptive,cs_prune",
                         [(False, False), (True, True)])
def test_scan_vs_batched_parity(built, norm_adaptive, cs_prune):
    """Old (per-query lax.scan) vs new (batched Pallas verification) device
    search: identical ids, scores AND logical page/candidate accounting."""
    x, q, pm = built
    out_scan = pm.search(q, k=10, verification="scan",
                         norm_adaptive=norm_adaptive, cs_prune=cs_prune)
    out_bat = pm.search(q, k=10, verification="batched",
                        norm_adaptive=norm_adaptive, cs_prune=cs_prune)
    ids_s, scores_s, st_s = out_scan
    ids_b, scores_b, st_b = out_bat
    np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(scores_s), np.asarray(scores_b))
    for field in ("pages", "candidates", "probe_passed", "used_round2",
                  "exhausted", "rows"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_s, field)), np.asarray(getattr(st_b, field)),
            err_msg=f"stat {field} diverged between verification backends")


def test_device_agrees_with_host_top1(built):
    """Same index, same query: both device backends find the host top-1
    (small corpus, full budget, paper-faithful settings)."""
    x, q, pm = built
    for verification in ("scan", "batched"):
        ids_d, scores_d, _ = pm.search(q[:8], k=10, verification=verification)
        ids_d = np.asarray(ids_d)
        for i in range(8):
            ids_h, scores_h, _ = pm.search_host(q[i], k=10)
            assert ids_d[i, 0] == ids_h[0], (verification, i)


def test_runtime_facade_modes(built):
    """The runtime facade dispatches every mode and clamps budgets."""
    x, q, pm = built
    for cfg in (RuntimeConfig(k=5),
                RuntimeConfig(k=5, verification="scan"),
                RuntimeConfig(k=5, mode="progressive", cs_prune=True),
                RuntimeConfig(k=5, budget=10**9, norm_adaptive=True)):
        ids, scores, stats = runtime_search(pm.arrays, pm.meta, q[:4], cfg)
        assert np.asarray(ids).shape == (4, 5)
        assert np.all(np.diff(np.asarray(scores), axis=1) <= 1e-6)
    with pytest.raises(ValueError):
        runtime_search(pm.arrays, pm.meta, q[:2], RuntimeConfig(mode="nope"))


def test_search_common_numpy_jnp_bitexact():
    """The backend-neutral core returns bit-identical f32 on numpy and jnp."""
    rng = np.random.RandomState(7)
    n = 256
    best_ip = rng.standard_normal(n).astype(np.float32) * 10
    max_l2sq = np.float32(37.5)
    q_l2sq = (rng.standard_normal(n).astype(np.float32) ** 2) * 20
    local = (rng.standard_normal(n).astype(np.float32) ** 2) * 30
    proj_d2 = (rng.standard_normal(n).astype(np.float32) ** 2) * 5
    c, x_p = 0.9, 7.34

    cases = {
        "cond_a": (sc.condition_a(best_ip, max_l2sq, q_l2sq, c),
                   sc.condition_a(jnp.asarray(best_ip), max_l2sq,
                                  jnp.asarray(q_l2sq), c)),
        "denom": (sc.condition_b_denominator(best_ip, max_l2sq, q_l2sq, c, xp=np),
                  sc.condition_b_denominator(jnp.asarray(best_ip), max_l2sq,
                                             jnp.asarray(q_l2sq), c, xp=jnp)),
        "cond_b": (sc.condition_b(proj_d2, best_ip, max_l2sq, q_l2sq, c, x_p, xp=np),
                   sc.condition_b(jnp.asarray(proj_d2), jnp.asarray(best_ip),
                                  max_l2sq, jnp.asarray(q_l2sq), c, x_p, xp=jnp)),
        "comp_r": (sc.compensation_radius(best_ip, max_l2sq, q_l2sq, c, x_p, xp=np),
                   sc.compensation_radius(jnp.asarray(best_ip), max_l2sq,
                                          jnp.asarray(q_l2sq), c, x_p, xp=jnp)),
        "adaptive": (sc.adaptive_radii(local, best_ip, q_l2sq, c, x_p,
                                       cs_prune=True, xp=np),
                     sc.adaptive_radii(jnp.asarray(local), jnp.asarray(best_ip),
                                       jnp.asarray(q_l2sq), c, x_p,
                                       cs_prune=True, xp=jnp)),
        "sphere": (sc.sphere_select(proj_d2, local, best_ip),
                   sc.sphere_select(jnp.asarray(proj_d2), jnp.asarray(local),
                                    jnp.asarray(best_ip))),
    }
    for name, (np_out, jnp_out) in cases.items():
        np.testing.assert_array_equal(
            np.asarray(np_out, dtype=np.asarray(jnp_out).dtype),
            np.asarray(jnp_out), err_msg=f"{name}: numpy vs jnp mismatch")


def test_topk_merge_backends_agree():
    rng = np.random.RandomState(3)
    top_s = np.sort(rng.standard_normal(10).astype(np.float32))[::-1].copy()
    top_r = np.arange(10, dtype=np.int32)
    scores = rng.standard_normal(40).astype(np.float32)
    scores[5] = top_s[0]  # force a tie across the boundary
    rows = np.arange(100, 140, dtype=np.int32)
    s_np, r_np = sc.topk_merge(top_s, top_r, scores, rows, 10, xp=np)
    s_j, r_j = sc.topk_merge(jnp.asarray(top_s), jnp.asarray(top_r),
                             jnp.asarray(scores), jnp.asarray(rows), 10, xp=jnp)
    np.testing.assert_array_equal(s_np, np.asarray(s_j))
    np.testing.assert_array_equal(r_np, np.asarray(r_j))
