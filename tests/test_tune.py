"""Offline autotuner (PR 8): tuning-cache round trips, missing-key /
absent-cache fallback bit-identity, tuned-vs-default result parity for the
parity-safe knobs, cutout determinism, and the satellite contracts
(int-eps coercion, `kernel_cost`'s static_upper_bound flag)."""
import json
import os

import numpy as np
import pytest

from repro.core import ProMIPS, RuntimeConfig
from repro.core.search_common import DENSE_FRAC, next_pow2
from repro.tune import cache, cutout, space

STATS_EXACT = ("pages", "candidates", "probe_passed", "used_round2",
               "radius0", "radius1", "exhausted", "rows")


@pytest.fixture(scope="module")
def built(mf_corpus):
    x, q = mf_corpus
    pm = ProMIPS.build(x, m=8, c=0.9, p=0.5, norm_strata=4)
    return x, q, pm


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Point the tuning cache at a fresh temp file and clear the memo on
    both entry and exit, so tests never see the committed cache (or each
    other's)."""
    path = str(tmp_path / "tuning.json")
    monkeypatch.setenv(cache.ENV_VAR, path)
    cache.clear_memo()
    yield path
    cache.clear_memo()


def _assert_identical(out_a, out_b, label):
    ids_a, scores_a, st_a = out_a
    ids_b, scores_b, st_b = out_b
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b),
                                  err_msg=f"{label}: ids")
    np.testing.assert_array_equal(np.asarray(scores_a), np.asarray(scores_b),
                                  err_msg=f"{label}: scores")
    for field in STATS_EXACT:
        np.testing.assert_array_equal(
            np.asarray(getattr(st_a, field)),
            np.asarray(getattr(st_b, field)),
            err_msg=f"{label}: stat {field}")


# -- cache mechanics --------------------------------------------------------

def test_cache_round_trip(tmp_cache):
    key = cache.save_entry(100_000, 128,
                           runtime={"verification": "fused",
                                    "dense_frac": 0.8, "tile_cap": 96,
                                    "prefilter_eps": 0.1},
                           build={"page_bytes": 8192,
                                  "max_probe_groups": None})
    assert key == space.shape_key(100_000, 128)
    entry = cache.lookup(100_000, 128)
    assert entry is not None
    assert entry["runtime"]["dense_frac"] == 0.8
    assert entry["provenance"]["commit"]
    # shape bucketing: any n in the same pow2 bucket resolves the entry
    assert cache.lookup(90_000, 128) is not None
    assert cache.lookup(100_000, 64) is None
    rt = cache.resolved("runtime", 100_000, 128)
    assert rt["dense_frac"] == 0.8 and rt["tile_cap"] == 96
    bd = cache.resolved("build", 100_000, 128)
    assert bd["page_bytes"] == 8192
    # the on-disk document carries the key/provenance schema DESIGN §15
    # documents
    doc = json.load(open(tmp_cache))
    assert doc["version"] == 1
    assert doc["entries"][key]["key"]["d"] == 128


def test_cache_missing_and_corrupt(tmp_cache):
    # no file at all -> hand-picked everywhere, no exception
    assert cache.lookup(5000, 32) is None
    assert cache.resolved("runtime", 5000, 32) == \
        space.HAND_PICKED["runtime"]
    # corrupt file -> same
    with open(tmp_cache, "w") as f:
        f.write("{not json")
    cache.clear_memo()
    assert cache.lookup(5000, 32) is None
    assert cache.resolved("serve", 5000, 32) == space.HAND_PICKED["serve"]


def test_cache_disabled_by_empty_env(tmp_cache, monkeypatch):
    cache.save_entry(4000, 48, runtime={"dense_frac": 0.5})
    assert cache.lookup(4000, 48) is not None
    monkeypatch.setenv(cache.ENV_VAR, "")
    cache.clear_memo()
    assert cache.lookup(4000, 48) is None


def test_resolved_only_overlays_declared_keys(tmp_cache):
    cache.save_entry(4000, 48, runtime={"dense_frac": 0.5,
                                        "bogus_knob": 123})
    rt = cache.resolved("runtime", 4000, 48)
    assert rt["dense_frac"] == 0.5
    assert "bogus_knob" not in rt
    assert rt["verification"] == space.HAND_PICKED["runtime"]["verification"]


# -- fallback + tuned-entry bit-identity ------------------------------------

def test_absent_cache_bit_identical_to_explicit_defaults(built, tmp_cache):
    """The acceptance bar: with no cache (or no entry for this shape),
    None-knob searches equal the explicit hand-picked config bitwise —
    ids, scores AND stats."""
    x, q, pm = built
    out_none = pm.search(q, k=10, norm_adaptive=True, cs_prune=True)
    out_pin = pm.search(q, k=10, norm_adaptive=True, cs_prune=True,
                        dense_frac=DENSE_FRAC, tile_cap=pm.meta.n_blocks)
    _assert_identical(out_none, out_pin, "absent-cache")


@pytest.mark.parametrize("dense_frac", [0.5, 1.0])
def test_tuned_dense_frac_parity(built, tmp_cache, dense_frac):
    """dense_frac only picks dense vs sparse tile — result-bit-identical
    by construction, so a tuned value must change nothing but time."""
    x, q, pm = built
    cache.save_entry(len(x), x.shape[1],
                     runtime={"dense_frac": dense_frac})
    out_tuned = pm.search(q, k=10, norm_adaptive=True, cs_prune=True)
    os.environ[cache.ENV_VAR] = ""
    cache.clear_memo()
    try:
        out_default = pm.search(q, k=10, norm_adaptive=True, cs_prune=True)
    finally:
        os.environ[cache.ENV_VAR] = tmp_cache
        cache.clear_memo()
    _assert_identical(out_tuned, out_default, f"dense_frac={dense_frac}")


def test_tuned_tile_cap_parity(built, tmp_cache):
    """A tile_cap >= the actual union is lossless (it only removes pow2
    padding), so a tuned cap at n_blocks is bit-identical to uncapped."""
    x, q, pm = built
    cache.save_entry(len(x), x.shape[1],
                     runtime={"tile_cap": int(pm.meta.n_blocks)})
    out_tuned = pm.search(q, k=10, norm_adaptive=True, cs_prune=True)
    out_pin = pm.search(q, k=10, norm_adaptive=True, cs_prune=True,
                        dense_frac=DENSE_FRAC, tile_cap=pm.meta.n_blocks)
    _assert_identical(out_tuned, out_pin, "tile_cap=n_blocks")


def test_explicit_kwargs_beat_cache(built, tmp_cache):
    """An explicit dense_frac must win over an installed tuned entry: the
    two searches still agree bitwise (it's a perf knob), and the installed
    entry must not stop an explicit tile_cap below the union from
    truncating (exhausted flags prove the explicit value was used)."""
    x, q, pm = built
    cache.save_entry(len(x), x.shape[1],
                     runtime={"dense_frac": 0.5,
                              "tile_cap": int(pm.meta.n_blocks)})
    out_explicit = pm.search(q, k=10, norm_adaptive=True, cs_prune=True,
                             dense_frac=1.0, tile_cap=1)
    assert bool(np.asarray(out_explicit[2].exhausted).any()), \
        "tile_cap=1 should truncate; the cache entry must not override it"


def test_tuned_vs_default_parity_every_tuned_point(built, tmp_cache):
    """Every entry the coordinate descent can actually write is parity-
    gated; simulate one per declared runtime knob value and assert the
    resolved search still matches the hand-picked baseline bitwise.
    (verification variants are exercised via their own backend kwarg —
    all backends are bit-identical by the PR-4 parity suite.)"""
    x, q, pm = built
    baseline = pm.search(q, k=10, norm_adaptive=True, cs_prune=True,
                         dense_frac=DENSE_FRAC, tile_cap=pm.meta.n_blocks)
    for dense_frac in space.knob("dense_frac").candidates:
        cache.save_entry(len(x), x.shape[1],
                         runtime={"dense_frac": float(dense_frac),
                                  "tile_cap": int(pm.meta.n_blocks)})
        out = pm.search(q, k=10, norm_adaptive=True, cs_prune=True)
        _assert_identical(out, baseline, f"tuned dense_frac={dense_frac}")


# -- cutout generator -------------------------------------------------------

def test_cutout_deterministic_under_fixed_seed():
    x1, q1 = cutout.make_cutout(2000, 32, 8, seed=7)
    x2, q2 = cutout.make_cutout(2000, 32, 8, seed=7)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(q1, q2)
    x3, _ = cutout.make_cutout(2000, 32, 8, seed=8)
    assert not np.array_equal(x1, x3)


def test_cutout_matches_large_corpus_recipe():
    """The cutout at the LARGE_N shape IS the LARGE_N corpus — tuning
    measures the workload the bench runs."""
    from benchmarks.paper_figures import LARGE_N, _large_corpus
    cfg = LARGE_N
    x_b, q_b = _large_corpus()
    x_c, q_c = cutout.make_cutout(
        cfg["n"], cfg["d"], cfg["n_q"], rank=cfg["rank"],
        decay=cfg["decay"], norm_tail=cfg["norm_tail"], seed=0)
    np.testing.assert_array_equal(x_b, x_c)
    np.testing.assert_array_equal(q_b, q_c)


# -- parameter space / key schema -------------------------------------------

def test_shape_key_buckets_and_schema():
    assert space.n_bucket(100_000) == 131_072
    assert space.n_bucket(131_072) == 131_072
    key = space.shape_key(100_000, 128, platform="cpu", jax_version="0.4.37")
    assert key == "n131072:d128:cpu:jax0.4.37"
    for k in space.KNOBS:
        assert k.section in space.HAND_PICKED
        assert k.name in space.HAND_PICKED[k.section] or k.name == "tile_cap"


# -- satellite contracts ----------------------------------------------------

def test_runtime_config_coerces_int_eps():
    cfg = RuntimeConfig(k=10, prefilter=True, prefilter_eps=1)
    assert isinstance(cfg.prefilter_eps, float) and cfg.prefilter_eps == 1.0
    cfg2 = RuntimeConfig(k=10, dense_frac=1)
    assert isinstance(cfg2.dense_frac, float) and cfg2.dense_frac == 1.0


def test_runtime_config_validates_tune_knobs():
    with pytest.raises(ValueError):
        RuntimeConfig(k=10, dense_frac=0.0)
    with pytest.raises(ValueError):
        RuntimeConfig(k=10, dense_frac=1.5)
    with pytest.raises(ValueError):
        RuntimeConfig(k=10, tile_cap=0)
    with pytest.raises(ValueError):
        RuntimeConfig(k=10, tile_cap=True)


def test_kernel_cost_static_upper_bound_flag():
    import jax
    import jax.numpy as jnp
    from repro.launch.roofline import kernel_cost
    try:
        cost = kernel_cost(lambda a, b: a @ b,
                           jnp.ones((8, 8), jnp.float32),
                           jnp.ones((8, 8), jnp.float32))
    except Exception:
        pytest.skip("cost_analysis unavailable on this backend")
    assert cost["static_upper_bound"] is True


def test_max_probe_groups_caps_table():
    from repro.core.quick_probe import build_group_table, pack_codes_np
    rng = np.random.RandomState(0)
    p = rng.randn(500, 6).astype(np.float32)
    codes = pack_codes_np(p)
    l1 = np.abs(rng.randn(500)).astype(np.float32)
    full = build_group_table(codes, l1, p)
    capped = build_group_table(codes, l1, p, max_groups=8)
    assert len(capped.code) == 8 < len(full.code)
    # kept groups are exactly the smallest-min_l1 ones
    assert set(np.asarray(capped.min_l1)) == \
        set(np.sort(np.asarray(full.min_l1))[:8])


def test_tuned_point_smoke_descent():
    """End-to-end descent on a tiny cutout: runs inside budget, every
    candidate carries a status, and the winner passes the parity gate by
    construction (baseline reproduced bitwise)."""
    from repro.tune import search as tsearch
    x, q = cutout.make_cutout(1500, 24, 8, seed=0)
    entry = tsearch.tune_point(
        x, q,
        build_opts=dict(m=8, c=0.9, p=0.6, k_p=4, k_sp=4, norm_strata=2,
                        seed=0),
        search_opts=dict(k=5, norm_adaptive=True, cs_prune=True),
        budget_s=30.0, reps=2, include_build=False, stages=False,
        roofline=False, write=False)
    summary = entry["trace"]["summary"]
    assert summary["elapsed_s"] < 120.0
    assert {"verification", "dense_frac", "tile_cap",
            "prefilter_eps"} <= set(entry["runtime"])
    for rec in entry["trace"]["candidates"]:
        assert "status" in rec
    assert summary["speedup_tuned_vs_default"] > 0.0
