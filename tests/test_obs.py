"""Observability tier (DESIGN.md §14): span tracer, metrics registry, the
stats-contract choke point, and end-to-end metric-name resolution after one
smoke search per backend."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.promips import ProMIPS
from repro.core.runtime import RuntimeConfig
from repro.core.sharded import MutableShardedProMIPS
from repro.core import search_fused as sf
from repro.obs import metrics, trace
from repro.stream.mutable import MutableProMIPS


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts from tracer-off / empty-registry and leaves the
    process-wide switches the way it found them (off)."""
    trace.disable()
    trace.clear()
    metrics.disable()
    metrics.reset()
    yield
    trace.disable()
    trace.clear()
    trace.configure(capacity=8192)
    metrics.disable()
    metrics.reset()


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1500, 24)).astype(np.float32)
    q = rng.standard_normal((6, 24)).astype(np.float32)
    return x, q


@pytest.fixture(scope="module")
def pm(corpus):
    x, _ = corpus
    return ProMIPS.build(x, m=8, c=0.9, p=0.6, seed=0, norm_strata=4)


# -- span tracer -------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    assert trace.span("anything") is trace.span("other")
    assert trace.span("x") is trace._NULL
    with trace.span("x") as sp:
        assert sp.fence(123) == 123
    assert trace.spans() == []


def test_active_override_records_without_global_enable():
    with trace.span("forced", active=True):
        pass
    assert [s["name"] for s in trace.spans()] == ["forced"]
    # and active=False forces the no-op even when globally enabled
    trace.enable()
    assert trace.span("y", active=False) is trace._NULL


def test_ring_is_bounded_and_total_is_monotonic():
    trace.configure(capacity=4)
    trace.enable()
    t0 = trace.total()
    for i in range(10):
        with trace.span(f"s{i}"):
            pass
    assert len(trace.spans()) == 4
    assert [s["name"] for s in trace.spans()] == ["s6", "s7", "s8", "s9"]
    assert trace.total() == t0 + 10
    trace.clear()
    assert trace.spans() == [] and trace.total() == t0 + 10
    with pytest.raises(ValueError):
        trace.configure(capacity=0)


def test_fence_records_flag_and_returns_value(pm, corpus):
    _, q = corpus
    trace.enable(fence=True)
    arr = jnp.arange(4.0)
    with trace.span("fenced_one") as sp:
        out = sp.fence(arr)
    assert out is arr
    assert trace.spans()[-1]["fenced"] is True
    trace.disable()
    trace.enable(fence=False)
    with trace.span("unfenced") as sp:
        sp.fence(arr)
    assert trace.spans()[-1]["fenced"] is False


def test_span_feeds_declared_histogram():
    with trace.span("x", active=True, metric="search.batch_us"):
        pass
    snap = metrics.snapshot()
    assert snap["search.batch_us"]["count"] == 1


def test_export_chrome_trace(tmp_path):
    trace.enable()
    with trace.span("alpha"):
        with trace.span("beta"):
            pass
    path = trace.export_chrome_trace(str(tmp_path / "sub" / "trace.json"))
    doc = json.load(open(path))
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    names = {e["name"] for e in doc["traceEvents"]}
    assert names == {"alpha", "beta"}
    for e in doc["traceEvents"]:
        assert e["ph"] == "X" and "ts" in e and "dur" in e
        assert e["args"]["fenced"] is False


# -- metrics registry --------------------------------------------------------

def test_undeclared_metric_name_raises():
    with pytest.raises(ValueError, match="undeclared"):
        metrics.counter("search.made_up")
    with pytest.raises(ValueError, match="declared as a"):
        metrics.gauge("search.queries")   # declared as a counter


def test_histogram_log2_buckets():
    h = metrics.histogram("search.batch_us")
    assert h.bucket_of(0.5) == 0 and h.bucket_of(1.0) == 0
    assert h.bucket_of(1.5) == 1 and h.bucket_of(2.0) == 1
    assert h.bucket_of(3.0) == 2 and h.bucket_of(1024.0) == 10
    for v in (0.5, 3.0, 3.5, 1000.0):
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == 4 and d["buckets"] == {"0": 1, "2": 2, "10": 1}
    assert d["mean"] == pytest.approx(sum((0.5, 3.0, 3.5, 1000.0)) / 4)


def test_snapshot_only_contains_touched_instruments():
    metrics.counter("stream.deletes").inc(3)
    snap = metrics.snapshot()
    assert snap["stream.deletes"] == 3
    assert "serve.pages" not in snap
    # every live name must be declared (the ci.sh obs-guard invariant)
    assert set(snap) <= set(metrics.GLOSSARY)


def test_observe_search_gated_by_enable():
    metrics.observe_search({"pages": 5, "candidates": 7, "exhausted": 0,
                            "queries": 2})
    assert "search.pages" not in metrics.snapshot()
    metrics.enable()
    metrics.observe_search({"pages": 5, "candidates": 7, "exhausted": 0,
                            "queries": 2})
    snap = metrics.snapshot()
    assert snap["search.pages"] == 5 and snap["search.queries"] == 2


def test_prometheus_text_exposition():
    metrics.counter("search.pages").inc(11)
    h = metrics.histogram("search.batch_us")
    h.observe(3.0)
    h.observe(100.0)
    text = metrics.prometheus_text()
    assert "# HELP repro_search_pages" in text
    assert "# TYPE repro_search_pages counter" in text
    assert "repro_search_pages 11" in text
    assert "# TYPE repro_search_batch_us histogram" in text
    assert 'repro_search_batch_us_bucket{le="+Inf"} 2' in text
    assert "repro_search_batch_us_count 2" in text
    # cumulative buckets are nondecreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("repro_search_batch_us_bucket")]
    assert cums == sorted(cums)


def test_flush_jsonl(tmp_path):
    metrics.counter("search.pages").inc(2)
    path = str(tmp_path / "m" / "metrics.jsonl")
    metrics.flush_jsonl(path, extra={"run": "t1"})
    metrics.flush_jsonl(path, extra={"run": "t2"})
    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == 2
    assert lines[0]["run"] == "t1"
    assert lines[1]["metrics"]["search.pages"] == 2


# -- stats contract (core/stats.stats_totals choke point) --------------------

def test_all_stats_classes_share_the_normalized_key_set(pm, corpus):
    x, q = corpus
    qj = jnp.asarray(q, jnp.float32)
    expected = {"pages", "candidates", "exhausted", "queries"}

    _, _, device_stats = pm.search(qj, k=5)                    # SearchStats
    _, _, host_stats = pm.search_host(q[0], k=5)               # HostStats
    stream = MutableProMIPS(x[:800], m=8, c=0.9, p=0.6, seed=0)
    _, _, stream_stats = stream.search(qj, k=5)                # StreamStats
    shd = MutableShardedProMIPS(x, 2, m=8, c=0.9, p=0.6, seed=0)
    _, _, sharded_stats = shd.search(qj, k=5)                  # ShardedStats

    for st in (device_stats, host_stats, stream_stats, sharded_stats):
        d = st.to_dict()
        assert set(d) == expected, type(st).__name__
        assert all(isinstance(v, int) for v in d.values()), type(st).__name__
    # pre-aggregated sharded totals must still count the real batch size
    assert sharded_stats.to_dict()["queries"] == len(q)


def test_metrics_resolve_after_one_smoke_search_per_backend(pm, corpus):
    """Every metric name instrumentation emits during a smoke search on
    each backend resolves against the declared glossary, and the core
    search.* set is present."""
    x, q = corpus
    qj = jnp.asarray(q, jnp.float32)
    metrics.enable()
    trace.enable(fence=True)

    for verification in ("fused", "batched"):
        _, _, st = pm.search(qj, k=5, verification=verification,
                             norm_adaptive=True, cs_prune=True)
        st.to_dict()
    _, _, st = pm.search_host(q[0], k=5)                       # host
    st.to_dict()
    stream = MutableProMIPS(x[:800], m=8, c=0.9, p=0.6, seed=0)
    # a dirty snapshot (live delta rows) so the segment-merge span runs
    stream.insert(np.arange(800, 804), x[800:804])
    _, _, st = stream.search(qj, k=5)                          # stream
    st.to_dict()
    shd = MutableShardedProMIPS(x, 2, m=8, c=0.9, p=0.6, seed=0)
    _, _, st = shd.search(qj, k=5)                             # sharded
    st.to_dict()

    snap = metrics.snapshot()
    assert set(snap) <= set(metrics.GLOSSARY), \
        sorted(set(snap) - set(metrics.GLOSSARY))
    required = {"search.queries", "search.pages", "search.candidates",
                "search.exhausted", "search.batch_us", "search.frontend_us",
                "search.verify_round_us", "search.rescore_us",
                "sharded.dispatch_us", "sharded.merge_us", "search.merge_us",
                "fused.verify_retraces"}
    assert required <= set(snap), sorted(required - set(snap))
    assert snap["search.queries"] > 0
    assert snap["search.batch_us"]["count"] > 0


# -- bounded VERIFY_TRACES ring ----------------------------------------------

def test_verify_trace_ring_is_bounded_with_monotonic_total():
    ring = sf.TraceRing(capacity=3)
    for i in range(7):
        ring.append(("key", i))
    assert len(ring) == 3
    assert list(ring) == [("key", 4), ("key", 5), ("key", 6)]
    assert ring.total == 7
    assert ring[0] == ("key", 4) and ring[len(list(ring)):] == []
    assert bool(ring)
    ring.clear()
    assert len(ring) == 0 and not ring and ring.total == 7
    # the live module-level ring exposes the same surface
    assert isinstance(sf.VERIFY_TRACES, sf.TraceRing)
    assert sf.VERIFY_TRACES.total >= len(sf.VERIFY_TRACES)


def test_retrace_total_surfaces_as_gauge():
    before = sf.VERIFY_TRACES.total
    snap = metrics.snapshot()   # collector pulls the ring total
    assert snap["fused.verify_retraces"] == before


# -- serve-path telemetry ----------------------------------------------------

def test_engine_telemetry_and_shedding():
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve.engine import DecodeEngine

    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                       obs=True, max_queue=3)
    rng = np.random.RandomState(0)
    reqs = [eng.submit(rng.randint(1, cfg.vocab, size=8), max_new_tokens=4)
            for _ in range(3)]
    assert all(r is not None for r in reqs)
    assert eng.submit(rng.randint(1, cfg.vocab, size=8)) is None  # shed
    eng.run()

    snap = eng.metrics_snapshot()
    assert snap["steps"] == eng.steps and snap["queue_depth"] == 0
    assert snap["serve.requests_submitted"] == 3
    assert snap["serve.requests_shed"] == 1
    assert snap["serve.requests_completed"] == 3
    assert snap["serve.queue_wait_us"]["count"] == 3
    assert snap["serve.request_us"]["count"] == 3
    assert snap["serve.decode_steps"] == snap["serve.step_us"]["count"] > 0
    assert snap["serve.slot_occupancy"] == 0.0
    for r in reqs:
        assert 0.0 < r.t_submit <= r.t_admit <= r.t_done
    # non-serve engine state keys come from the engine, serve.* from the
    # registry; nothing outside the declared glossary leaks in
    assert {k for k in snap if "." in k} <= set(metrics.GLOSSARY)


# -- RuntimeConfig.obs -------------------------------------------------------

def test_runtime_config_obs_validation():
    with pytest.raises(ValueError, match="obs"):
        RuntimeConfig(obs="yes")
    assert RuntimeConfig(obs=True).obs is True
    assert RuntimeConfig().obs is False


def test_obs_toggle_is_bit_identical_and_records(pm, corpus):
    _, q = corpus
    qj = jnp.asarray(q, jnp.float32)
    ids_off, scores_off, _ = pm.search(qj, k=5, verification="fused",
                                       norm_adaptive=True, cs_prune=True)
    assert trace.spans() == []   # obs off: nothing recorded
    ids_on, scores_on, _ = pm.search(qj, k=5, verification="fused",
                                     norm_adaptive=True, cs_prune=True,
                                     obs=True)
    assert np.array_equal(np.asarray(ids_off), np.asarray(ids_on))
    assert np.array_equal(np.asarray(scores_off), np.asarray(scores_on))
    names = {s["name"] for s in trace.spans()}
    assert {"search", "select_frontend", "verify_round1"} <= names
