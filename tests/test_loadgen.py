"""Open-loop Zipfian load generator (serve/loadgen.py, DESIGN.md §17)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import DecodeEngine, LoadgenConfig, generate, run_load
from repro.serve.engine import DegradationPolicy
from repro.serve.loadgen import zipf_probs


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_zipf_probs_properties():
    p = zipf_probs(16, 1.1)
    assert p.shape == (16,)
    assert np.isclose(p.sum(), 1.0)
    assert np.all(np.diff(p) < 0)          # strictly hotter head
    u = zipf_probs(8, 0.0)                 # s=0 degenerates to uniform
    assert np.allclose(u, 1.0 / 8)


def test_generate_is_deterministic_and_in_range():
    cfg = LoadgenConfig(rate_qps=100.0, n_requests=40, zipf_s=1.2,
                        pool_size=6, prompt_lens=(3, 9),
                        max_new_tokens_choices=(2, 5),
                        deadline_mix=((None, 1.0), (0.25, 1.0)), seed=3)
    a1, a2 = generate(cfg, vocab=512), generate(cfg, vocab=512)
    assert len(a1) == 40
    assert [x.t for x in a1] == [x.t for x in a2]
    assert [x.pool_id for x in a1] == [x.pool_id for x in a2]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a1, a2))
    pool = {}
    for x in a1:
        assert x.t > 0 and 0 <= x.pool_id < 6
        assert 3 <= len(x.prompt) <= 9
        assert np.all((x.prompt >= 1) & (x.prompt < 512))
        assert x.max_new_tokens in (2, 5)
        assert x.deadline_s in (None, 0.25)
        # same pool_id => same prompt object contents every arrival
        if x.pool_id in pool:
            assert np.array_equal(pool[x.pool_id], x.prompt)
        pool[x.pool_id] = x.prompt
    assert sorted(x.t for x in a1) == [x.t for x in a1]  # monotone schedule


def test_zipf_skew_concentrates_on_head():
    cfg = LoadgenConfig(rate_qps=100.0, n_requests=400, zipf_s=1.5,
                        pool_size=16, seed=0)
    picks = np.bincount([a.pool_id for a in generate(cfg, 512)], minlength=16)
    assert picks[0] == picks.max()
    assert picks[0] > 400 / 16 * 2          # far above the uniform share


def test_ramp_compresses_late_gaps():
    base = dict(rate_qps=50.0, n_requests=200, pool_size=4, seed=7)
    flat = generate(LoadgenConfig(ramp=1.0, **base), 512)
    ramped = generate(LoadgenConfig(ramp=10.0, **base), 512)
    # a 10x ramp makes the BACK half of the schedule much denser than the
    # front half; the flat schedule has no such asymmetry on average
    def half_span(arr, lo, hi):
        return arr[hi].t - arr[lo].t
    r_front = half_span(ramped, 0, 99)
    r_back = half_span(ramped, 100, 199)
    assert r_back < r_front / 2
    assert ramped[-1].t < flat[-1].t


def test_config_validation():
    with pytest.raises(ValueError):
        LoadgenConfig(rate_qps=0.0)
    with pytest.raises(ValueError):
        LoadgenConfig(n_requests=0)
    with pytest.raises(ValueError):
        LoadgenConfig(zipf_s=-0.1)
    with pytest.raises(ValueError):
        LoadgenConfig(ramp=0.0)
    with pytest.raises(ValueError):
        LoadgenConfig(prompt_lens=(5, 3))
    with pytest.raises(ValueError):
        LoadgenConfig(max_new_tokens_choices=())
    with pytest.raises(ValueError):
        LoadgenConfig(deadline_mix=())


def test_run_load_completes_and_summarizes(small_model):
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=2, max_len=64)
    lg = LoadgenConfig(rate_qps=200.0, n_requests=8, pool_size=3,
                       prompt_lens=(4, 6), max_new_tokens_choices=(3,),
                       seed=1)
    arrivals = generate(lg, cfg.vocab)
    s = run_load(eng, arrivals, max_wall_s=60.0)
    assert s["requests"] == 8 and s["completed"] == 8
    assert s["shed_frac"] == 0.0 and s["expired_frac"] == 0.0
    assert s["decoded_tokens"] == 8 * 3
    assert s["queries_per_s"] > 0 and s["wall_s"] > 0
    assert s["latency_p99_s"] >= s["latency_p50_s"] > 0
    assert s["final_state"] == "ok"
    occ = s["tier_occupancy"]
    assert occ and abs(sum(occ.values()) - 1.0) < 1e-9
    # every arrival was annotated with its live Request
    assert all(a.request is not None and not a.shed for a in arrivals)
    assert all(len(a.request.out_tokens) == 4 for a in arrivals)


def test_run_load_accounts_shed_and_expired(small_model):
    """Saturating arrivals against a tiny engine with max_queue=1 must shed
    some requests at admission; a 0-second deadline mix must expire the
    rest of the queued ones — and the two fractions must reconcile with
    completed counts."""
    cfg, params = small_model
    eng = DecodeEngine(params, cfg, batch_slots=1, max_len=64, max_queue=1)
    lg = LoadgenConfig(rate_qps=1e4, n_requests=10, pool_size=2,
                       prompt_lens=(4, 4), max_new_tokens_choices=(2,),
                       deadline_mix=((0.0, 1.0),), seed=2)
    arrivals = generate(lg, cfg.vocab)
    s = run_load(eng, arrivals, max_wall_s=60.0)
    assert s["shed_frac"] > 0
    n_shed = sum(a.shed for a in arrivals)
    n_expired = sum(a.request.expired for a in arrivals if a.request)
    assert n_shed + n_expired + s["completed"] == 10
    assert s["expired_frac"] == n_expired / 10
    assert not eng.queue and not eng.active.any()


def test_run_load_trips_degradation_ladder(small_model):
    cfg, params = small_model
    pol = DegradationPolicy(tiers=(1.0, 0.5), recall_floors=(0.95, 0.8),
                            queue_high=2, queue_low=0, patience=1,
                            recovery=1000)
    eng = DecodeEngine(params, cfg, batch_slots=1, max_len=64,
                       logits_mode="promips",
                       promips_kwargs=dict(m=8, c=0.95, p=0.95),
                       degradation=pol)
    lg = LoadgenConfig(rate_qps=1e4, n_requests=8, pool_size=2,
                       prompt_lens=(4, 4), max_new_tokens_choices=(4,),
                       seed=4)
    s = run_load(eng, generate(lg, cfg.vocab), max_wall_s=120.0)
    assert s["stepdowns"] >= 1 and s["max_tier"] >= 1
    assert "1" in s["tier_occupancy"] and s["tier_occupancy"]["1"] > 0
    assert "cache" in s            # promips engine reports qcache stats
