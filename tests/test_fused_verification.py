"""Fused block-sparse verification (PR 4): three-way backend parity,
finite-budget semantics, pow2 tile bucketing / bounded jit cache, and the
batch-native selection frontend."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProMIPS, RuntimeConfig, runtime_search
from repro.core import search_fused as sf
from repro.core.quick_probe import quick_probe, quick_probe_batch
from repro.core.search_device import _group_table, select_blocks_batch
from repro.core.search_common import next_pow2
from repro.data.synthetic import mf_factors

STAT_FIELDS = ("pages", "candidates", "probe_passed", "used_round2",
               "radius0", "radius1", "exhausted", "rows")
# vs "scan" the radii are only ULP-equal: its per-block matvec dots
# reassociate differently than the one-matmul backends (the reason PR 1
# introduced the shared `_rescore`), and radius1 is a function of the raw
# running k-th score. ids/scores/pages/candidates/rows are still exact.
SCAN_STAT_FIELDS = tuple(f for f in STAT_FIELDS if f != "radius1")


@pytest.fixture(scope="module")
def built(mf_corpus):
    x, q = mf_corpus
    pm = ProMIPS.build(x, m=8, c=0.9, p=0.5, norm_strata=4, page_bytes=2048)
    return x, jnp.asarray(q, jnp.float32), pm


def _assert_same(out_a, out_b, label, fields=STAT_FIELDS):
    ids_a, scores_a, st_a = out_a
    ids_b, scores_b, st_b = out_b
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b),
                                  err_msg=f"{label}: ids")
    np.testing.assert_array_equal(np.asarray(scores_a), np.asarray(scores_b),
                                  err_msg=f"{label}: scores")
    for field in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(st_a, field)), np.asarray(getattr(st_b, field)),
            err_msg=f"{label}: stat {field}")


@pytest.mark.parametrize("norm_adaptive,cs_prune",
                         [(False, False), (True, True)])
def test_three_way_parity_full_budget(built, norm_adaptive, cs_prune):
    """fused vs batched vs scan at the guarantee-default full budget:
    bit-identical ids, scores AND every stats field (pages, candidates,
    rows, radii, exhausted)."""
    x, q, pm = built
    outs = {v: pm.search(q, k=10, verification=v,
                         norm_adaptive=norm_adaptive, cs_prune=cs_prune)
            for v in ("scan", "batched", "fused")}
    _assert_same(outs["fused"], outs["batched"], "fused-vs-batched")
    _assert_same(outs["fused"], outs["scan"], "fused-vs-scan",
                 fields=SCAN_STAT_FIELDS)
    np.testing.assert_allclose(
        np.asarray(outs["fused"][2].radius1), np.asarray(outs["scan"][2].radius1),
        rtol=1e-5, err_msg="fused-vs-scan: radius1 (ULP-level only)")


@pytest.mark.parametrize("budget", [4, 37, 128])
def test_fused_equals_batched_at_finite_budget(built, budget):
    """Finite-budget divergence semantics: "fused" caps the SHARED union
    tile at ``budget`` blocks exactly like "batched" (first budget union
    blocks in layout order, over-capped queries flagged ``exhausted``), so
    the two agree bit-for-bit at EVERY budget. "scan" budgets differently —
    each query's own selection is capped — so it is only guaranteed to
    agree at the full budget (test above)."""
    x, q, pm = built
    out_b = pm.search(q, k=10, budget=budget, budget2=budget,
                      verification="batched")
    out_f = pm.search(q, k=10, budget=budget, budget2=budget,
                      verification="fused")
    _assert_same(out_f, out_b, f"budget={budget}")


def test_fused_flags_exhausted_when_budget_truncates(built):
    x, q, pm = built
    _, _, st = pm.search(q, k=10, budget=2, budget2=2, verification="fused")
    assert np.asarray(st.exhausted).any()


def test_runtime_default_is_fused_and_validated(built):
    """RuntimeConfig exposes "fused" (the default) and rejects unknowns by
    name; the facade path dispatches it."""
    x, q, pm = built
    assert RuntimeConfig().verification == "fused"
    with pytest.raises(ValueError, match="fused"):
        RuntimeConfig(verification="nope")
    ids, scores, stats = runtime_search(pm.arrays, pm.meta, q[:4],
                                        RuntimeConfig(k=5))
    assert np.asarray(ids).shape == (4, 5)
    ids_b, scores_b, stats_b = runtime_search(
        pm.arrays, pm.meta, q[:4], RuntimeConfig(k=5, verification="batched"))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(scores_b))


def test_plan_tile_pow2_buckets():
    """Tile sizes are pow2-bucketed (or the cap): across EVERY possible
    union count the number of distinct compiled shapes stays O(log NB)."""
    n_blocks, n_batch = 500, 4
    sizes = set()
    for n_union in range(1, n_blocks + 1):
        mask = np.zeros((n_batch, n_blocks), bool)
        mask[:, np.random.RandomState(n_union).permutation(n_blocks)[:n_union]] = True
        plan = sf._plan_tile(mask, n_blocks, n_blocks)
        assert plan is not None
        slots, sel, lost, dense = plan
        assert not lost.any()
        sizes.add((len(slots), dense))
    assert len(sizes) <= int(np.ceil(np.log2(n_blocks))) + 2, sizes
    for ns, dense in sizes:
        assert dense or ns == min(next_pow2(ns), n_blocks) or ns == n_blocks
    assert sf._plan_tile(np.zeros((n_batch, n_blocks), bool), 500, 500) is None


def test_verify_jit_cache_stays_bounded(built):
    """End to end: searches over many different query batches (different
    union sizes each round) retrace the verification jits at most once per
    pow2 bucket (per round flavor: plain / dense / cached) — the jit cache
    is bounded by O(log n_blocks), NOT by the number of distinct union
    sizes. A second identical sweep must not add a single retrace."""
    x, q, pm = built
    sf.VERIFY_TRACES.clear()
    rng = np.random.RandomState(7)

    def sweep():
        r = np.random.RandomState(7)
        for i in range(6):
            scale = 0.25 * (i + 1)
            qi = jnp.asarray(scale * r.standard_normal((8, x.shape[1])),
                             jnp.float32)
            pm.search(qi, k=10, verification="fused", norm_adaptive=True,
                      cs_prune=True)

    sweep()
    traces = list(sf.VERIFY_TRACES)
    assert traces, "fused path never traced a verification round"
    assert len(traces) == len(set(traces)), "retraced an already-seen shape"
    # 4 flavors (sparse, dense +- score cache, cached) x O(log NB) buckets
    max_shapes = 4 * (int(np.ceil(np.log2(pm.meta.n_blocks))) + 2)
    assert len(set(traces)) <= max_shapes, traces
    sweep()  # identical unions -> every shape already compiled
    assert len(sf.VERIFY_TRACES) == len(traces), (
        "second identical sweep recompiled", sf.VERIFY_TRACES[len(traces):])


def test_quick_probe_batch_matches_vmap(built):
    """The batch-native Quick-Probe is bit-identical to vmap-of-per-query."""
    import jax

    x, q, pm = built
    arrays, meta = pm.arrays, pm.meta
    table = _group_table(arrays)
    q_proj = q @ arrays.a
    q_l1 = jnp.sum(jnp.abs(q), axis=1)
    rows_b, rad_b, ok_b = quick_probe_batch(table, q_proj, q_l1,
                                            meta.c, meta.x_p)
    rows_v, rad_v, ok_v = jax.vmap(
        lambda qp, ql: quick_probe(table, qp, ql, meta.c, meta.x_p)
    )(q_proj, q_l1)
    np.testing.assert_array_equal(np.asarray(rows_b), np.asarray(rows_v))
    np.testing.assert_array_equal(np.asarray(rad_b), np.asarray(rad_v))
    np.testing.assert_array_equal(np.asarray(ok_b), np.asarray(ok_v))


def test_blocks_from_radii_matches_bruteforce(built):
    """The block_sp_idx gather mapping == brute-force "any selected
    sub-partition in [block_sp_lo, block_sp_hi)" per block."""
    x, q, pm = built
    arrays = pm.arrays
    rng = np.random.RandomState(3)
    q_proj = q[:6] @ arrays.a
    radius = jnp.asarray(np.abs(rng.standard_normal(6)).astype(np.float32) * 3)
    got = np.asarray(select_blocks_batch(arrays, q_proj, radius))

    center = np.asarray(arrays.sp_center)
    d_sp = np.sqrt(np.maximum(
        (center * center).sum(-1)[None, :]
        - 2.0 * np.asarray(q_proj) @ center.T
        + (np.asarray(q_proj) ** 2).sum(-1)[:, None], 0.0))
    sel_sp = d_sp <= np.asarray(radius)[:, None] + np.asarray(arrays.sp_radius)
    lo, hi = np.asarray(arrays.block_sp_lo), np.asarray(arrays.block_sp_hi)
    want = np.stack([
        [bool(sel_sp[b, lo[nb]:hi[nb]].any()) for nb in range(len(lo))]
        for b in range(6)])
    np.testing.assert_array_equal(got, want)


def test_fused_dense_and_sparse_tiles_agree(built):
    """ops.block_mips dense (walk everything in place) vs explicit slot walk
    over the same blocks: identical outputs."""
    from repro.kernels import ops

    x, q, pm = built
    arrays, meta = pm.arrays, pm.meta
    n_blocks = meta.n_blocks
    b, k = 8, 5
    rng = np.random.RandomState(1)
    qj = q[:b]
    sel = jnp.asarray(rng.rand(b, n_blocks) > 0.6)
    init_s = jnp.full((b, k), -jnp.inf)
    init_r = jnp.full((b, k), -1, jnp.int32)
    c_half = jnp.asarray(rng.rand(b).astype(np.float32) * 10)
    valid = arrays.ids >= 0
    slots = jnp.arange(n_blocks, dtype=jnp.int32)
    args = (arrays.x, valid, qj, slots, sel, init_s, init_r, c_half)
    dense_out = ops.block_mips(*args, k=k, page_rows=meta.page_rows,
                               dense=True)
    sparse_out = ops.block_mips(*args, k=k, page_rows=meta.page_rows,
                                dense=False)
    for name, a, b_ in zip(("top_s", "top_r", "cnt", "pages", "cand"),
                           dense_out, sparse_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_),
                                      err_msg=name)


@pytest.mark.parametrize("budget", [None, 4, 37, 128])
def test_fused_in_graph_under_ambient_trace(built, budget):
    """`runtime_search` with verification="fused" inside jit — even with
    CONCRETE queries closed over but traced index arrays — runs the
    IN-GRAPH fused driver (`core/search_graph.py`), bit-identical to the
    eager host-orchestrated driver AND the batched graph at every budget:
    ids, scores and every stats field."""
    import jax

    x, q, pm = built
    q_np = np.asarray(q[:8])
    cfg = RuntimeConfig(k=5, budget=budget, budget2=budget,
                        norm_adaptive=True, cs_prune=True)
    traced = jax.jit(lambda arrays: runtime_search(arrays, pm.meta, q_np, cfg))
    out_t = traced(pm.arrays)
    out_e = runtime_search(pm.arrays, pm.meta, q_np, cfg)
    _assert_same(out_t, out_e, f"jit-fused-vs-eager-fused budget={budget}")
    cfg_b = RuntimeConfig(k=5, budget=budget, budget2=budget,
                          norm_adaptive=True, cs_prune=True,
                          verification="batched")
    out_b = runtime_search(pm.arrays, pm.meta, q_np, cfg_b)
    _assert_same(out_t, out_b, f"jit-fused-vs-batched budget={budget}")


def test_tile_buckets_cover_plan_tile_sizes():
    """The in-graph lax.switch branch list is exactly the set of tile sizes
    the host planner can choose: min(next_pow2(u), cap) for every union
    count u — so bucket selection by searchsorted reproduces the host
    driver's sizing rule, and the branch count stays O(log cap)."""
    from repro.core.search_graph import _tile_buckets

    for cap in (1, 2, 3, 37, 64, 500):
        sizes = _tile_buckets(cap)
        assert sizes[-1] == cap and sorted(set(sizes)) == list(sizes)
        want = {min(next_pow2(u), cap) for u in range(1, cap + 9)}
        assert set(sizes) == want, (cap, sizes, want)
        # searchsorted picks the same size the host planner computes
        for u in range(1, cap + 9):
            idx = int(np.searchsorted(np.asarray(sizes), u))
            idx = min(idx, len(sizes) - 1)
            assert sizes[idx] == min(next_pow2(u), cap), (cap, u)


def test_sharded_and_stream_get_fused_by_default(mf_corpus):
    """Every guaranteed backend rides the fused default: facade-built
    promips / promips-stream / sharded searchers run verification="fused"
    and return identical results to an explicit batched override."""
    from repro import api

    x, q = mf_corpus
    guarantee = api.GuaranteeConfig(c=0.9, p0=0.5, k=10)
    for backend in ("promips", "promips-stream", "sharded"):
        s = api.build(x, backend=backend, guarantee=guarantee, seed=0,
                      m=8, page_bytes=2048)
        assert s.runtime.verification == "fused", backend
        res = s.search(q, k=10)
        cfg_b = RuntimeConfig(k=10, verification="batched")
        res_b = s.search(q, k=10, runtime=cfg_b)
        np.testing.assert_array_equal(res.ids, res_b.ids, err_msg=backend)
        np.testing.assert_array_equal(res.scores, res_b.scores,
                                      err_msg=backend)
        for key in ("pages", "candidates", "exhausted"):
            assert res.stats[key] == res_b.stats[key], (backend, key)
