"""Unified index API (DESIGN.md §9): registry conformance, guarantee-first
config derivation, eager RuntimeConfig validation, and persistence.

The conformance suite is parametrized over EVERY registered backend: build
-> search -> (mutate if supports_mutation) -> save/load with bit-identical
post-load search results. A new backend only has to register to be covered.
"""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core import RuntimeConfig
from repro.core.chi2 import chi2_ppf_host
from repro.core.dim_opt import optimized_projected_dimension

K = 10
GUARANTEE = api.GuaranteeConfig(c=0.9, p0=0.6, k=K)


@pytest.fixture(scope="module")
def corpus():
    from repro.data.synthetic import mf_factors
    x = mf_factors(1500, 32, 8, decay=0.4, seed=0, norm_tail=0.3)
    q = mf_factors(6, 32, 8, decay=0.4, seed=1)
    return x, q


_built = {}


def build(backend, corpus, **opts):
    key = (backend, tuple(sorted(opts.items())))
    if key not in _built:
        x, _ = corpus
        _built[key] = api.build(x, backend=backend, guarantee=GUARANTEE,
                                seed=0, **opts)
    return _built[key]


# ---------------------------------------------------------------------------
# registry + guarantee config
# ---------------------------------------------------------------------------

def test_registry_contents():
    names = api.backends()
    for expected in ("promips", "promips-stream", "sharded", "exact",
                     "h2alsh", "pq", "rangelsh"):
        assert expected in names
    with pytest.raises(ValueError, match="registered backends"):
        api.get_backend("nope")
    with pytest.raises(ValueError, match="unknown backend"):
        api.build(np.zeros((4, 2), np.float32), backend="nope")


def test_guarantee_config_derivation():
    """(c, p0) -> m* via the §V-B cost model, x_p via the chi-square ppf."""
    g = api.GuaranteeConfig(c=0.8, p0=0.7, k=5)
    for n in (100, 5000, 200_000):
        plan = g.derive(n)
        assert plan.m == min(optimized_projected_dimension(n), 30)
        assert plan.x_p == pytest.approx(chi2_ppf_host(0.7, plan.m))
        assert plan.probe_groups == 2 ** plan.m
        assert plan.budget is None and plan.budget2 is None  # no truncation
    # larger corpora never want a smaller projected dimension
    ms = [g.derive(n).m for n in (100, 2000, 50_000, 1_000_000)]
    assert ms == sorted(ms)


def test_guarantee_config_validation():
    with pytest.raises(ValueError, match="c must be"):
        api.GuaranteeConfig(c=1.5)
    with pytest.raises(ValueError, match="p0 must be"):
        api.GuaranteeConfig(p0=0.0)
    with pytest.raises(ValueError, match="k must be"):
        api.GuaranteeConfig(k=0)


def test_build_respects_derived_m(corpus):
    """Without an explicit m override, the index uses the derived m*."""
    x, _ = corpus
    s = api.build(x, backend="promips", guarantee=GUARANTEE, seed=0)
    assert s.pm.meta.m == GUARANTEE.derive(len(x)).m
    assert s.pm.meta.c == GUARANTEE.c and s.pm.meta.p == GUARANTEE.p0
    s8 = build("promips", corpus, m=8)
    assert s8.pm.meta.m == 8


# ---------------------------------------------------------------------------
# eager RuntimeConfig validation (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,match", [
    (dict(verification="bogus"), "batched, scan"),
    (dict(mode="bogus"), "two_phase, progressive"),
    (dict(k=0), "k must be"),
    (dict(k=-3), "k must be"),
    (dict(budget=0), "budget must be"),
    (dict(budget=-5), "budget must be"),
    (dict(budget2=-1), "budget2 must be"),
])
def test_runtime_config_rejects_bad_values(kwargs, match):
    """Unknown choices / non-positive sizes fail FAST, naming the valid
    choices — not deep inside the jit'd device path."""
    with pytest.raises(ValueError, match=match):
        RuntimeConfig(**kwargs)


def test_runtime_validation_at_search_entry(corpus):
    """A config that dodged __post_init__ still fails at search() entry."""
    from repro.core import runtime_search
    s = build("promips", corpus, m=6)
    cfg = RuntimeConfig(k=5)
    object.__setattr__(cfg, "verification", "bogus")  # frozen bypass
    with pytest.raises(ValueError, match="batched, scan"):
        runtime_search(s.pm.arrays, s.pm.meta, corpus[1][:2], cfg)


# ---------------------------------------------------------------------------
# conformance: every registered backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", api.backends())
def test_conformance_build_search(backend, corpus):
    """Uniform semantics: shapes, descending scores, ids<->scores
    consistency (scores ARE the inner products of the returned rows), and
    the normalized stats contract."""
    x, q = corpus
    s = build(backend, corpus)
    assert s.name == backend
    assert isinstance(s.capabilities, api.Capabilities)
    assert s.n == len(x)
    assert s.index_bytes >= 0 and s.build_seconds >= 0

    res = s.search(q, k=K)
    assert isinstance(res, api.SearchResult)
    assert res.ids.shape == (len(q), K) and res.ids.dtype == np.int64
    assert res.scores.shape == (len(q), K) and res.scores.dtype == np.float32
    assert np.all(np.diff(res.scores, axis=1) <= 1e-5), "scores descending"
    for key in api.STAT_KEYS:
        assert key in res.stats, f"missing stat {key!r}"
    assert res.stats["queries"] == len(q)
    assert res.pages > 0 and res.candidates > 0
    # ids <-> scores consistency: every returned id's true inner product
    for i in range(len(q)):
        valid = res.ids[i] >= 0
        np.testing.assert_allclose(
            res.scores[i][valid], x[res.ids[i][valid]] @ q[i],
            rtol=1e-4, atol=1e-4,
            err_msg=f"{backend}: scores are not the true inner products")

    # single-row query convenience: (d,) behaves as a B=1 batch
    res1 = s.search(q[0], k=K)
    assert res1.ids.shape == (1, K)
    np.testing.assert_array_equal(res1.ids[0], res.ids[0])


@pytest.mark.parametrize("backend", api.backends())
def test_conformance_guaranteed_backends_recall(backend, corpus):
    """Backends claiming `guaranteed` must actually deliver near-exact
    results at these (easy-corpus) settings; unguaranteed baselines only
    need to beat a sanity floor."""
    from repro.baselines.exact import exact_topk
    x, q = corpus
    s = build(backend, corpus)
    eids, _ = exact_topk(x, q, K)
    res = s.search(q, k=K)
    recall = np.mean([len(set(res.ids[i]) & set(eids[i])) / K
                      for i in range(len(q))])
    assert recall >= (0.95 if s.capabilities.guaranteed else 0.2), \
        (backend, recall)


@pytest.mark.parametrize("backend", api.backends())
def test_conformance_mutation_gating(backend, corpus):
    """supports_mutation gates insert/delete/update/alive_items uniformly:
    mutable backends reflect writes in the next search, immutable ones
    raise UnsupportedOperation."""
    x, q = corpus
    s = build(backend, corpus)
    if not s.capabilities.supports_mutation:
        for op in (lambda: s.insert([len(x)], np.ones((1, x.shape[1]))),
                   lambda: s.delete([0]),
                   lambda: s.update([0], np.ones((1, x.shape[1]))),
                   s.alive_items):
            with pytest.raises(api.UnsupportedOperation, match=backend):
                op()
        return

    # fresh instance: mutation must not leak into the shared cache
    m = api.build(x, backend=backend, guarantee=GUARANTEE, seed=0)
    boost = 10.0 * x[int(np.argmax(x @ q[0]))]
    new_id = len(x) + 7
    m.insert([new_id], boost[None, :])
    res = m.search(q[0], k=K)
    assert res.ids[0, 0] == new_id, "inserted row must win the next search"

    m.delete([new_id])
    res = m.search(q[0], k=K)
    assert new_id not in res.ids[0], "deleted row must vanish"

    victim = int(res.ids[0, 0])
    m.update([victim], 20.0 * boost[None, :])
    res = m.search(q[0], k=K)
    assert res.ids[0, 0] == victim, "updated row must rank by its new vector"

    gids, rows = m.alive_items()
    assert len(gids) == len(x) == m.n
    assert victim in gids


# ---------------------------------------------------------------------------
# persistence: save -> load -> search is bit-identical (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", api.backends())
def test_persistence_round_trip_bit_identical(backend, corpus, tmp_path):
    x, q = corpus
    s = build(backend, corpus)
    before = s.search(q, k=K)

    path = s.save(str(tmp_path / backend))
    header = api.read_header(path)
    assert header["backend"] == backend
    assert header["seed"] == 0
    assert header["guarantee"]["c"] == GUARANTEE.c

    loaded = api.load(path)
    assert type(loaded) is type(s)
    assert loaded.guarantee == GUARANTEE and loaded.seed == 0
    after = loaded.search(q, k=K)
    np.testing.assert_array_equal(before.ids, after.ids)
    np.testing.assert_array_equal(before.scores, after.scores)


def test_persistence_mutated_stream_round_trip(corpus, tmp_path):
    """A stream with live delta rows + tombstones round-trips exactly, and
    the loaded stream keeps absorbing writes."""
    x, q = corpus
    rng = np.random.RandomState(3)
    s = api.build(x, backend="promips-stream", guarantee=GUARANTEE, seed=0)
    s.insert(np.arange(len(x), len(x) + 50),
             rng.randn(50, x.shape[1]).astype(np.float32))
    s.delete(np.arange(0, 20))
    s.update([30], 5.0 * x[30][None, :])
    before = s.search(q, k=K)

    loaded = api.load(s.save(str(tmp_path / "stream")))
    after = loaded.search(q, k=K)
    np.testing.assert_array_equal(before.ids, after.ids)
    np.testing.assert_array_equal(before.scores, after.scores)
    assert loaded.n == s.n

    loaded.insert([10 ** 6], np.ones((1, x.shape[1]), np.float32))
    assert loaded.n == s.n + 1


def test_persistence_load_dispatch_errors(tmp_path, corpus):
    with pytest.raises(FileNotFoundError):
        api.load(str(tmp_path / "missing"))
    x, _ = corpus
    s = build("exact", corpus)
    path = s.save(str(tmp_path / "exact_idx"))
    # loading through the wrong backend class is rejected
    with pytest.raises(ValueError, match="saved by backend"):
        api.get_backend("promips").load(path)


# ---------------------------------------------------------------------------
# facade plumbing
# ---------------------------------------------------------------------------

def test_core_reexports_facade():
    """core/__init__ re-exports the facade lazily (no import cycle)."""
    import repro.core as core
    assert core.build_searcher is api.build
    assert core.load_searcher is api.load
    assert core.GuaranteeConfig is api.GuaranteeConfig
    with pytest.raises(AttributeError):
        core.definitely_not_a_symbol


def test_engine_rejects_immutable_index(corpus):
    """serve.DecodeEngine takes any MUTABLE Searcher; immutable ones are
    rejected by capability, not by concrete type."""
    from repro.serve.engine import DecodeEngine
    x, _ = corpus
    s = build("promips", corpus)  # supports_mutation=False
    with pytest.raises(ValueError, match="supports_mutation"):
        DecodeEngine({"embed": np.zeros((8, 4), np.float32)}, object(),
                     logits_mode="promips", index=s)
    # an injected index with exact mode would be silently ignored — reject it
    m = api.build(x, backend="promips-stream", guarantee=GUARANTEE, seed=0)
    with pytest.raises(ValueError, match="logits_mode"):
        DecodeEngine({"embed": np.zeros((8, 4), np.float32)}, object(),
                     index=m)
    # promips_kwargs tune the default-built index only; with index= they
    # would be silently dropped — reject the combination
    with pytest.raises(ValueError, match="promips_kwargs"):
        DecodeEngine({"embed": np.zeros((8, 4), np.float32)}, object(),
                     logits_mode="promips", index=m,
                     promips_kwargs=dict(m=12))


def test_directly_constructed_adapter_is_usable(corpus):
    """Adapters restored via from_state (or built by hand) work without the
    registry stamping guarantee/seed — class defaults cover them."""
    x, q = corpus
    s = build("promips", corpus, m=6)
    arrays, meta = s.state()
    restored = type(s).from_state(arrays, meta)
    res = restored.search(q)          # k defaults via restored.guarantee
    assert res.ids.shape == (len(q), api.GuaranteeConfig().k)
    assert restored.seed == 0 and restored.guarantee == api.GuaranteeConfig()


def test_promips_host_search_path(corpus):
    """search_path='host' runs the paper-faithful sequential search (exact
    resident-page accounting) behind the same facade. Host and device are
    both c-AMIP-guaranteed but traverse differently (sequential Condition-A
    early stop vs block-granular selection), so the contract is the
    GUARANTEE, not identical ids: both must be near-exact here."""
    from repro.baselines.exact import exact_topk
    x, q = corpus
    host = build("promips", corpus, m=6, search_path="host")
    res_h = host.search(q, k=K)
    eids, _ = exact_topk(x, q, K)
    recall = np.mean([len(set(res_h.ids[i]) & set(eids[i])) / K
                      for i in range(len(q))])
    assert recall >= 0.9, recall
    assert res_h.stats["queries"] == len(q) and res_h.pages > 0

    # ablation knobs reach the host path (norm-adaptive prunes pages)
    pruned = build("promips", corpus, m=6, search_path="host",
                   norm_adaptive=True, cs_prune=True)
    assert pruned.search(q, k=K).pages <= res_h.pages

    with pytest.raises(ValueError, match="search_path"):
        build("promips", corpus, search_path="bogus")


def test_promips_host_path_round_trip(corpus, tmp_path):
    x, q = corpus
    s = build("promips", corpus, m=6, search_path="host")
    before = s.search(q, k=K)
    loaded = api.load(s.save(str(tmp_path / "host_idx")))
    assert loaded.search_path == "host"
    after = loaded.search(q, k=K)
    np.testing.assert_array_equal(before.ids, after.ids)
    np.testing.assert_array_equal(before.scores, after.scores)


def test_stream_compaction_config_round_trip(corpus, tmp_path):
    """A non-default compaction threshold survives save/load."""
    from repro.stream.compaction import CompactionConfig
    x, q = corpus
    s = api.build(x, backend="promips-stream", guarantee=GUARANTEE, seed=0,
                  auto_compact=True,
                  compaction=CompactionConfig(threshold=0.05))
    loaded = api.load(s.save(str(tmp_path / "stream_cc")))
    assert loaded.inner.compactor is not None
    assert loaded.inner.compactor.cfg.threshold == 0.05


def test_device_array_queries_pass_through(corpus):
    """jax-array queries skip the host round trip and return the same
    results as numpy queries (the serve decode hot path)."""
    import jax.numpy as jnp
    x, q = corpus
    s = build("promips", corpus, m=6)
    res_np = s.search(q, k=K)
    res_j = s.search(jnp.asarray(q), k=K)
    np.testing.assert_array_equal(res_np.ids, res_j.ids)
    np.testing.assert_array_equal(res_np.scores, res_j.scores)
    res_j1 = s.search(jnp.asarray(q[0]), k=K)  # single device row
    np.testing.assert_array_equal(res_j1.ids[0], res_np.ids[0])


def test_legacy_entry_points_still_work(corpus):
    """The deprecation-shim contract: pre-facade call signatures keep
    working (ProMIPS.build(...).search(...), baseline classes)."""
    from repro.baselines import H2ALSH
    from repro.core import ProMIPS
    x, q = corpus
    pm = ProMIPS.build(x, m=6, c=0.9, p=0.5)
    ids, scores, stats = pm.search(q[:4], k=5)
    assert np.asarray(ids).shape == (4, 5)
    ids_h, scores_h, st_h = pm.search_host(q[0], k=5)
    assert st_h.to_dict()["queries"] == 1
    bl = H2ALSH().build(x)
    ids_b, scores_b, st_b = bl.search(q[0], k=5)
    assert st_b["pages"] > 0
