"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.binary_probe import binary_probe_lb
from repro.kernels.decode_attention import decode_attention
from repro.kernels.mips_topk import mips_score


@pytest.mark.parametrize("r,b,d", [(64, 4, 32), (300, 17, 200), (1024, 1, 128),
                                   (129, 128, 384), (8, 8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mips_score_sweep(rng, r, b, d, dtype):
    x = jnp.asarray(rng.standard_normal((r, d)), dtype)
    q = jnp.asarray(rng.standard_normal((b, d)), dtype)
    valid = jnp.asarray(rng.rand(r) > 0.2)
    got = mips_score(x, q, valid, interpret=True)
    want = ref.mips_score_ref(x, q, valid)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    rel = jnp.abs(got - want) / (1.0 + jnp.abs(want))
    assert float(rel.max()) < tol


@pytest.mark.parametrize("g,m", [(1, 4), (64, 8), (700, 12), (4096, 16), (33, 30)])
def test_binary_probe_sweep(rng, g, m):
    codes = jnp.asarray(rng.randint(0, 2 ** min(m, 31), g), jnp.uint32)
    qc = jnp.uint32(rng.randint(0, 2 ** min(m, 31)))
    qp = jnp.asarray(rng.standard_normal(m), jnp.float32)
    got = binary_probe_lb(codes, qc, qp, interpret=True)
    want = ref.binary_probe_lb_ref(codes, qc, qp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,kh,g,dh,s,block", [
    (1, 1, 1, 32, 128, 64), (2, 4, 2, 64, 1000, 256),
    (3, 2, 8, 128, 512, 512), (2, 8, 1, 64, 300, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(rng, b, kh, g, dh, s, block, dtype):
    q = jnp.asarray(rng.standard_normal((b, kh, g, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kh, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kh, dh)), dtype)
    lens = jnp.asarray(rng.randint(1, s + 1, b), jnp.int32)
    got = decode_attention(q, k, v, lens, block_s=block, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol, rtol=1e-2)


def test_ops_wrappers_route(rng):
    """ops.* dispatches to ref when use_pallas=False and matches."""
    x = jnp.asarray(rng.standard_normal((100, 64)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
    valid = jnp.ones(100, bool)
    a = ops.mips_score(x, q, valid, use_pallas=True)
    b = ops.mips_score(x, q, valid, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    top, idx = ops.mips_topk(x, q, valid, k=7)
    want = np.sort(np.asarray(x @ q.T).T, axis=1)[:, ::-1][:, :7]
    np.testing.assert_allclose(np.asarray(top), want, atol=1e-4)


@pytest.mark.parametrize("nb,p,d,b,k,ns", [
    (12, 8, 32, 5, 4, 8), (30, 16, 64, 9, 10, 16), (6, 8, 48, 3, 12, 6),
    (20, 8, 128, 17, 1, 4)])
def test_block_mips_sweep(rng, nb, p, d, b, k, ns):
    """Fused block-sparse verification kernel (interpret) vs jnp oracle:
    streaming top-k, per-slot hit counts, Condition-A page/candidate
    accounting — with padding slots, invalid rows and carried-in tops."""
    from repro.kernels.block_mips import block_mips

    n = nb * p
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    valid = jnp.asarray(rng.rand(n) > 0.15)
    q = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    blocks = np.sort(rng.permutation(nb)[: ns - 1])
    slots = jnp.asarray(np.concatenate([blocks, [0]]), jnp.int32)  # pad slot
    sel = jnp.asarray(rng.rand(b, ns) > 0.4).at[:, ns - 1].set(False)
    init_s = jnp.sort(jnp.asarray(rng.standard_normal((b, k)), jnp.float32),
                      axis=1)[:, ::-1]
    init_r = jnp.asarray(rng.randint(0, n, (b, k)), jnp.int32)
    c_half = jnp.asarray(rng.standard_normal(b) * 2, jnp.float32)

    got = block_mips(x, valid, q, slots, sel, init_s, init_r, c_half,
                     k=k, page_rows=p, interpret=True)
    want = ref.block_mips_ref(x, valid, q, slots, sel, init_s, init_r, c_half,
                              k=k, page_rows=p)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=1e-4)
    for name, g, w in zip(("top_r", "cnt", "pages", "cand"),
                          got[1:], want[1:]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_mips_topk_defaults_and_fused_route(rng):
    """mips_topk defaults to the backend-aware path (oracle off-TPU, no
    silent interpret mode) and its Pallas route — the fused block_mips
    streaming top-k — matches the oracle's score+lax.top_k result."""
    x = jnp.asarray(rng.standard_normal((300, 64)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((6, 64)), jnp.float32)
    valid = jnp.ones(300, bool)
    top_d, idx_d = ops.mips_topk(x, q, valid, k=5)            # default: None
    top_o, idx_o = ops.mips_topk(x, q, valid, k=5, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(top_d), np.asarray(top_o))
    np.testing.assert_array_equal(np.asarray(idx_d), np.asarray(idx_o))
    top_p, idx_p = ops.mips_topk(x, q, valid, k=5, use_pallas=True,
                                 page_rows=64)
    np.testing.assert_allclose(np.asarray(top_p), np.asarray(top_o),
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(idx_p), np.asarray(idx_o))


def test_flash_train_attention_grads(rng):
    """Training flash attention (custom_vjp) vs naive softmax attention."""
    from repro.models.attention import _flash_causal
    B, S, H, KH, dh = 2, 80, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, dh)), jnp.float32)

    def naive(q, k, v):
        g = H // KH
        qf = q.reshape(B, S, KH, g, dh).astype(jnp.float32) * dh ** -0.5
        scores = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32))
        mask = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, -1)
        out = jnp.einsum("bkgst,btkd->bkgsd", w, v.astype(jnp.float32))
        return jnp.moveaxis(out, 3, 1).reshape(B, S, H, dh)

    f1 = lambda *a: jnp.sum(jnp.cos(_flash_causal(*a, block=32)))
    f2 = lambda *a: jnp.sum(jnp.cos(naive(*a)))
    assert abs(float(f1(q, k, v)) - float(f2(q, k, v))) < 1e-2
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
