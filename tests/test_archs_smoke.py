"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU, asserting shapes + finiteness (assignment §f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T


def _batch(cfg, b=2, s=24):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.concatenate([jnp.ones((b, s - 1), jnp.int32),
                                        -jnp.ones((b, 1), jnp.int32)], axis=1)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.ones((b, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio":
        batch["frames"] = jnp.ones((b, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_decode(arch_id):
    cfg = get_config(arch_id).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: T.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # decode two tokens
    cache = T.init_cache(cfg, 2, 48)
    tok = jnp.ones((2, 1), jnp.int32)
    step = jax.jit(lambda p, c, t: T.decode_step(p, cfg, c, t))
    logits, cache = step(params, cache, tok)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, cache = step(params, cache, tok)
    assert int(cache["len"][0]) == 2
    # padded vocab is masked
    if cfg.vocab_padded != cfg.vocab:
        assert float(np.asarray(logits)[0, cfg.vocab:].max()) < -1e29


@pytest.mark.parametrize("arch_id", ["tinyllama-1.1b", "whisper-base", "zamba2-1.2b"])
def test_prefill_then_decode(arch_id):
    cfg = get_config(arch_id).reduced()
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, b=2, s=12)
    cache, last_logits = jax.jit(
        lambda p, b: T.prefill(p, cfg, b, 32))(params, batch)
    assert last_logits.shape == (2, cfg.vocab_padded)
    logits, cache = jax.jit(
        lambda p, c, t: T.decode_step(p, cfg, c, t))(params, cache,
                                                     jnp.ones((2, 1), jnp.int32))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_train_step_reduces_loss():
    """A few optimizer steps on a reduced model reduce the loss."""
    from repro.train.loop import TrainCfg, init_state, make_train_step
    from repro.data.synthetic import TokenStream
    cfg = get_config("tinyllama-1.1b").reduced()
    tcfg = TrainCfg(lr=1e-3, warmup=2, total_steps=20, microbatches=2, remat="full")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    stream = TokenStream(vocab=cfg.vocab, batch=4, seq=64, seed=0)
    losses = []
    for i in range(12):
        state, m = step(state, stream.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_unroll_matches_scan():
    """UNROLL_SCANS (roofline mode) is numerically identical to scan mode."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    l1, _ = T.loss_fn(params, cfg, batch)
    T.UNROLL_SCANS = True
    try:
        l2, _ = T.loss_fn(params, cfg, batch)
    finally:
        T.UNROLL_SCANS = False
    assert abs(float(l1) - float(l2)) < 1e-4
