"""iDistance layout (Section VI, Algorithm 4, Formula 6) + index invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly offline
from hypothesis import given, settings, strategies as st

from repro.core.idistance import build_idistance, kmeans_np, ring_key_range
from repro.core.index import build_index


@given(st.integers(0, 2 ** 31 - 1), st.integers(20, 300))
@settings(max_examples=15, deadline=None)
def test_layout_invariants(seed, n):
    rng = np.random.RandomState(seed)
    p = rng.standard_normal((n, 6)).astype(np.float32)
    lay = build_idistance(p, k_p=3, n_key=8, k_sp=4, seed=seed % 11)
    # permutation is a bijection over rows
    assert sorted(lay.perm.tolist()) == list(range(n))
    # sub-partition segments tile [0, n) contiguously
    assert lay.sp_start[0] == 0 and lay.sp_start[-1] == n
    assert np.all(np.diff(lay.sp_start) > 0)
    # every point is inside its sub-partition sphere; keys follow Formula 6
    for s in range(len(lay.sp_radius)):
        rows = np.arange(lay.sp_start[s], lay.sp_start[s + 1])
        d = np.linalg.norm(p[lay.perm[rows]] - lay.sp_center[s], axis=1)
        assert np.all(d <= lay.sp_radius[s] + 1e-4)
        part = lay.sp_part[s]
        ring = lay.sp_key[s] - part * lay.c_key
        dc = np.linalg.norm(p[lay.perm[rows]] - lay.part_center[part], axis=1)
        assert np.all(np.floor(dc / lay.eps).astype(int) == ring)


def test_ring_key_range_covers_sphere():
    """Every point within radius r of q lies in one of the key windows."""
    rng = np.random.RandomState(1)
    p = rng.standard_normal((400, 5)).astype(np.float32)
    lay = build_idistance(p, k_p=4, n_key=10, k_sp=3, seed=0)
    q = rng.standard_normal(5).astype(np.float32)
    r = 1.0
    windows = ring_key_range(lay, q, r)
    keys_sorted = lay.keys  # sorted layout keys
    inside = np.nonzero(np.linalg.norm(p[lay.perm] - q, axis=1) <= r)[0]
    for row in inside:
        key = keys_sorted[row]
        assert any(lo <= key <= hi for lo, hi in windows), (key, windows)


def test_kmeans_basics():
    rng = np.random.RandomState(0)
    x = np.concatenate([rng.standard_normal((50, 3)) + 5,
                        rng.standard_normal((50, 3)) - 5]).astype(np.float32)
    centers, assign = kmeans_np(x, 2, seed=0)
    assert centers.shape == (2, 3)
    # the two clusters separate
    assert len(np.unique(assign[:50])) == 1 and len(np.unique(assign[50:])) == 1
    assert assign[0] != assign[-1]


@pytest.mark.parametrize("strata", [1, 4])
def test_build_index_invariants(strata):
    rng = np.random.RandomState(2)
    x = rng.standard_normal((800, 32)).astype(np.float32)
    idx = build_index(x, m=6, norm_strata=strata, page_bytes=1024)
    a, meta = idx.arrays, idx.meta
    n = meta.n
    # ids: a permutation with -1 padding
    ids = a.ids[a.ids >= 0]
    assert sorted(ids.tolist()) == list(range(n))
    # sorted arrays match original rows
    np.testing.assert_allclose(a.x[: n], x[a.ids[:n]], rtol=1e-6)
    # l2 norms + max
    np.testing.assert_allclose(a.l2sq[:n], (x[a.ids[:n]] ** 2).sum(1), rtol=1e-5)
    assert np.isclose(a.max_l2sq, (x * x).sum(1).max(), rtol=1e-5)
    # sub-partition max norms
    for s in range(meta.n_subparts):
        lo, hi = a.sp_start[s], a.sp_start[s + 1]
        assert np.isclose(a.sp_max_l2sq[s], a.l2sq[lo:hi].max(), rtol=1e-5)
    # block tables consistent
    assert meta.n_pad % meta.page_rows == 0
    for b in range(meta.n_blocks):
        lo_row, hi_row = b * meta.page_rows, min((b + 1) * meta.page_rows, n) - 1
        if lo_row >= n:
            continue
        sp_lo, sp_hi = a.block_sp_lo[b], a.block_sp_hi[b]
        assert a.sp_start[sp_lo] <= lo_row < a.sp_start[sp_hi]
        sps = a.block_sp_idx[b][a.block_sp_idx[b] >= 0]
        assert np.isclose(a.block_max_l2sq[b], a.sp_max_l2sq[sps].max(), rtol=1e-5)


def test_optimized_projected_dimension():
    from repro.core.dim_opt import optimized_projected_dimension, quick_probe_cost
    for n in (1000, 17770, 624961, 11164866):
        m = optimized_projected_dimension(n)
        costs = {mm: quick_probe_cost(mm, n) for mm in range(2, 25)}
        assert costs[m] == min(costs.values())
    # larger n -> larger m (monotone trend, paper §V-B)
    assert optimized_projected_dimension(11164866) >= optimized_projected_dimension(17770)
