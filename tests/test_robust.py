"""Durability + fault-injection subsystem (DESIGN.md §16): WAL crash-recovery
bit-parity at every record boundary, checksummed atomic snapshots, compaction
retry under injected faults, and the serve-path degradation ladder."""
import json
import os
import struct
import time

import jax
import numpy as np
import pytest

from repro import api
from repro.robust import (CorruptSnapshotError, FaultInjected, FaultInjector,
                          WAL_MAGIC, WalCorruptError, EwmaWatchdog, fault,
                          read_records, recover)
from repro.robust.wal import _HDR
from repro.stream import MutableProMIPS
from repro.stream.compaction import CompactionConfig

D = 16
BUILD = dict(m=4)


@pytest.fixture(autouse=True)
def _clean_faults():
    fault.disarm()
    yield
    fault.disarm()


def _corpus(n=240, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randn(n, D).astype(np.float32), rng


def _queries(rng, b=5):
    return rng.randn(b, D).astype(np.float32)


def _result_tuple(searcher, q, k=8):
    res = searcher.search(q, k=k)
    stats = dict(res.stats)
    stats.pop("wall_time_s", None)
    return np.asarray(res.ids), np.asarray(res.scores), stats


def _record_boundaries(wal_path):
    """Byte offset of every record boundary (including the magic-only 0th)."""
    blob = open(wal_path, "rb").read()
    offs = [len(WAL_MAGIC)]
    off = len(WAL_MAGIC)
    while off + _HDR.size <= len(blob):
        length, _crc = _HDR.unpack_from(blob, off)
        off += _HDR.size + length
        assert off <= len(blob)
        offs.append(off)
    return blob, offs


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------

def test_wal_records_roundtrip(tmp_path):
    x, rng = _corpus()
    wd = str(tmp_path / "wal")
    s = api.build(x, backend="promips-stream", seed=1, wal_dir=wd,
                  delta_capacity=64, **BUILD)
    s.insert([500, 501], rng.randn(2, D))
    s.delete([0, 1])
    s.update([10], rng.randn(1, D))
    recs, good, clean = read_records(os.path.join(wd, "wal.log"))
    assert clean
    assert [r.op for r in recs] == ["insert", "delete", "delete", "insert"]
    assert [r.seq for r in recs] == [1, 2, 3, 4]
    assert np.array_equal(recs[0].gids, [500, 501])
    assert recs[0].rows.shape == (2, D)
    assert recs[0].rows.dtype == np.float32


def test_wal_torn_tail_truncated_midlog_corruption_fatal(tmp_path):
    x, rng = _corpus()
    wd = str(tmp_path / "wal")
    s = api.build(x, backend="promips-stream", seed=1, wal_dir=wd,
                  delta_capacity=64, **BUILD)
    s.insert([500], rng.randn(1, D))
    s.delete([0])
    path = os.path.join(wd, "wal.log")
    blob, offs = _record_boundaries(path)

    # torn tail: half of the final record -> truncated, not an error
    open(path, "wb").write(blob[: (offs[1] + offs[2]) // 2])
    recs, good, clean = read_records(path)
    assert [r.op for r in recs] == ["insert"] and not clean
    assert good == offs[1]

    # mid-log corruption: flip a byte of record 0's payload -> fatal
    bad = bytearray(blob)
    bad[offs[0] + _HDR.size + 2] ^= 0xFF
    open(path, "wb").write(bytes(bad))
    with pytest.raises(WalCorruptError, match="mid-log"):
        read_records(path)


def test_wal_fsync_policy_validated(tmp_path):
    with pytest.raises(ValueError, match="fsync"):
        api.build(_corpus(n=120)[0], backend="promips-stream", seed=1,
                  wal_dir=str(tmp_path / "w"), wal_fsync="sometimes", **BUILD)


def test_wal_requires_mutable_backend(tmp_path):
    x, _ = _corpus()
    with pytest.raises(ValueError, match="wal_dir"):
        api.build(x, backend="promips", seed=1,
                  wal_dir=str(tmp_path / "w"), **BUILD)
    # and recover() refuses a non-stream snapshot
    s = api.build(x, backend="promips", seed=1, **BUILD)
    s.save(str(tmp_path / "r" / "snapshot"))
    with pytest.raises(ValueError, match="WAL-capable"):
        recover(str(tmp_path / "r"))


# ---------------------------------------------------------------------------
# crash-recovery bit-parity, a crash injected at EVERY record boundary
# ---------------------------------------------------------------------------

def _op_script(rng):
    """(op, args) script covering insert/delete/update/compact, with the
    per-record shadow expansion each op contributes to the WAL."""
    return [
        ("insert", np.arange(400, 420), rng.randn(20, D).astype(np.float32)),
        ("delete", np.arange(0, 30)),
        ("update", np.arange(50, 60), rng.randn(10, D).astype(np.float32)),
        ("compact",),
        ("insert", np.arange(420, 425), rng.randn(5, D).astype(np.float32)),
        ("delete", np.array([400, 410, 422])),
        ("compact",),
        ("update", np.array([50, 421]), rng.randn(2, D).astype(np.float32)),
    ]


def _apply(stream_or_searcher, op):
    kind = op[0]
    if kind == "insert":
        stream_or_searcher.insert(op[1], op[2])
    elif kind == "delete":
        stream_or_searcher.delete(op[1])
    elif kind == "update":
        stream_or_searcher.update(op[1], op[2])
    else:
        stream_or_searcher.compact()


def _shadow_steps(script):
    """Expand the script into per-WAL-record shadow transitions: the shadow
    state after record i must equal recovery from a crash right after
    record i landed. update = its delete half then its insert half;
    compact = begin (freeze+abandon: a state no-op) then commit (the whole
    compaction)."""
    steps = []
    for op in script:
        if op[0] == "update":
            steps.append(("delete", op[1]))
            steps.append(("insert", op[1], op[2]))
        elif op[0] == "compact":
            steps.append(("noop",))
            steps.append(("compact",))
        else:
            steps.append(op)
    return steps


def test_crash_recovery_bit_parity_every_boundary(tmp_path):
    """THE durability property: for a crash at every record boundary
    (including a torn final record), snapshot + WAL replay reconstructs a
    stream whose searches are bit-identical — ids, scores, and every stats
    field — to an uncrashed stream that executed the same logical prefix."""
    x, rng = _corpus(n=300, seed=4)
    q = _queries(rng)
    wd = str(tmp_path / "wal")
    primary = api.build(x, backend="promips-stream", seed=2, wal_dir=wd,
                        delta_capacity=128, **BUILD)
    script = _op_script(rng)
    for op in script:
        _apply(primary, op)
    path = os.path.join(wd, "wal.log")
    blob, offs = _record_boundaries(path)
    steps = _shadow_steps(script)
    assert len(offs) == len(steps) + 1, "script/record accounting drifted"

    # shadow: same logical ops, NO WAL — the uncrashed reference per prefix
    shadow = MutableProMIPS(x, delta_capacity=128, **dict(BUILD, seed=2))
    shadow_states = [_stream_result(shadow, q)]
    for st in steps:
        if st[0] != "noop":
            _apply(shadow, st)
        shadow_states.append(_stream_result(shadow, q))

    for i, off in enumerate(offs):
        open(path, "wb").write(blob[:off])
        if i + 1 < len(offs):  # torn next record on top of a clean prefix
            open(path, "ab").write(blob[off: (off + offs[i + 1]) // 2 + 1])
        rec_searcher = recover(wd, attach=False)
        got = _result_tuple(rec_searcher, q)
        want = shadow_states[i]
        assert np.array_equal(got[0], want[0]), f"ids diverge at boundary {i}"
        assert np.array_equal(got[1], want[1]), f"scores diverge at boundary {i}"
        assert got[2] == want[2], f"stats diverge at boundary {i}"


def _stream_result(stream, q, k=8):
    ids, scores, stats = stream.search(q, k=k)
    sd = stats.to_dict()
    sd.pop("wall_time_s", None)
    return np.asarray(ids), np.asarray(scores), sd


def test_recovery_after_checkpoint_skips_baked_records(tmp_path):
    """Crash between checkpoint-save and WAL truncate must NOT double-apply:
    replay skips records with seq <= the snapshot's wal_seq."""
    x, rng = _corpus()
    q = _queries(rng)
    wd = str(tmp_path / "wal")
    s = api.build(x, backend="promips-stream", seed=1, wal_dir=wd,
                  delta_capacity=64, **BUILD)
    s.insert([500, 501], rng.randn(2, D))
    s.delete([0])
    # checkpoint WITHOUT truncating the log = the torn middle state
    s.save(os.path.join(wd, "snapshot"))
    s.inner.mark_wal_floor()
    s.insert([502], rng.randn(1, D))
    ref = _result_tuple(s, q)
    rec = recover(wd, attach=False)
    got = _result_tuple(rec, q)
    assert np.array_equal(ref[0], got[0])
    assert np.array_equal(ref[1], got[1])
    assert ref[2] == got[2]
    assert rec.inner._wal_seq == s.inner._wal_seq


def test_wal_append_fault_rejects_op_cleanly(tmp_path):
    """A failed WAL append (disk error) must reject the op BEFORE any state
    mutates — acknowledged implies logged."""
    x, rng = _corpus()
    wd = str(tmp_path / "wal")
    s = api.build(x, backend="promips-stream", seed=1, wal_dir=wd,
                  delta_capacity=64, **BUILD)
    s.insert([500], rng.randn(1, D))
    before = s.n
    fault.arm("wal.append", times=1)
    with pytest.raises(FaultInjected):
        s.insert([501], rng.randn(1, D))
    assert s.n == before
    with pytest.raises(KeyError):
        s.delete([501])  # never applied
    s.insert([501], rng.randn(1, D))  # fault exhausted; op logs + applies
    recs, _, _ = read_records(os.path.join(wd, "wal.log"))
    assert [r.seq for r in recs] == [1, 2], "failed append must not burn seq"


# ---------------------------------------------------------------------------
# checksummed atomic snapshots
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def saved_index(tmp_path_factory):
    x, rng = _corpus()
    s = api.build(x, backend="promips", seed=5, **BUILD)
    path = str(tmp_path_factory.mktemp("snap") / "idx")
    s.save(path)
    q = _queries(rng)
    return path, q, _result_tuple(s, q)


def _copy_dir(src, dst):
    import shutil
    shutil.copytree(src, dst)
    return str(dst)


def test_snapshot_manifest_written_and_verifies(saved_index):
    path, q, _ = saved_index
    man = json.load(open(os.path.join(path, "manifest.json")))
    assert set(man["files"]) == {"arrays.npz", "meta.json"}
    assert man["format"] == "repro.api-index"
    assert "commit" in man["provenance"]
    api.load(path)  # verifies + loads


def test_snapshot_corruption_matrix(saved_index, tmp_path):
    path, q, _ = saved_index
    # truncated arrays.npz
    p1 = _copy_dir(path, tmp_path / "trunc")
    f = os.path.join(p1, "arrays.npz")
    open(f, "r+b").truncate(os.path.getsize(f) // 2)
    with pytest.raises(CorruptSnapshotError, match="arrays.npz"):
        api.load(p1)
    # bit-flipped meta.json
    p2 = _copy_dir(path, tmp_path / "flip")
    f = os.path.join(p2, "meta.json")
    b = bytearray(open(f, "rb").read())
    b[len(b) // 2] ^= 0x01
    open(f, "wb").write(bytes(b))
    with pytest.raises(CorruptSnapshotError, match="meta.json"):
        api.load(p2)
    # manifest-listed file missing on disk
    p3 = _copy_dir(path, tmp_path / "missing")
    os.remove(os.path.join(p3, "arrays.npz"))
    with pytest.raises(CorruptSnapshotError, match="missing"):
        api.load(p3)
    # unreadable manifest
    p4 = _copy_dir(path, tmp_path / "badman")
    open(os.path.join(p4, "manifest.json"), "w").write("{not json")
    with pytest.raises(CorruptSnapshotError, match="manifest.json"):
        api.load(p4)


def test_legacy_manifestless_snapshot_loads_with_warning(saved_index, tmp_path):
    path, q, want = saved_index
    p = _copy_dir(path, tmp_path / "legacy")
    os.remove(os.path.join(p, "manifest.json"))
    with pytest.warns(UserWarning, match="UNVERIFIED"):
        s = api.load(p)
    got = _result_tuple(s, q)
    assert np.array_equal(got[0], want[0])


def test_save_is_atomic_under_injected_fault(saved_index, tmp_path):
    """A fault mid-save leaves the PREVIOUS snapshot fully intact."""
    path, q, want = saved_index
    p = _copy_dir(path, tmp_path / "atomic")
    s = api.load(p)
    fault.arm("snapshot.write", after=1, times=1)  # fail on the 2nd file
    with pytest.raises(FaultInjected):
        s.save(p)
    s2 = api.load(p)  # previous snapshot still verifies + loads
    got = _result_tuple(s2, q)
    assert np.array_equal(got[0], want[0])
    assert not [d for d in os.listdir(os.path.dirname(p))
                if d.startswith(".save-tmp")], "temp dir leaked"


# ---------------------------------------------------------------------------
# compaction retry under injected faults
# ---------------------------------------------------------------------------

def _churn(searcher, rng, start=1000, n=120):
    searcher.insert(np.arange(start, start + n),
                    rng.randn(n, D).astype(np.float32))
    searcher.delete(np.arange(start, start + n))


def test_compaction_fail_backoff_retry_success():
    x, rng = _corpus(n=200)
    s = api.build(x, backend="promips-stream", seed=1, delta_capacity=256,
                  auto_compact=True,
                  compaction=CompactionConfig(threshold=0.3, max_retries=3,
                                              backoff_s=0.001), **BUILD)
    fault.arm("compaction.rebuild", times=2)
    _churn(s, rng)  # crosses the churn threshold -> background compaction
    s.flush()
    st = s.maintenance_status()
    assert st["compaction"]["runs"] == 1, "retry must eventually install"
    assert st["compaction"]["failures"] == 2
    assert st["compaction"]["retries"] == 2
    assert not st["compaction"]["error_latched"]
    assert "FaultInjected" in st["compaction"]["last_error"]
    hits, fired = fault.counts("compaction.rebuild")
    assert fired == 2


def test_compaction_retries_exhausted_latches_error():
    x, rng = _corpus(n=200)
    s = api.build(x, backend="promips-stream", seed=1, delta_capacity=256,
                  auto_compact=True,
                  compaction=CompactionConfig(threshold=0.3, max_retries=1,
                                              backoff_s=0.001), **BUILD)
    fault.arm("compaction.rebuild")  # p=1.0, unbounded: every attempt fails
    _churn(s, rng)
    time.sleep(0.05)
    st = s.maintenance_status()
    assert st["compaction"]["error_latched"] or s.inner.compactor.in_flight
    with pytest.raises(RuntimeError, match="compaction failed"):
        s.flush()
    fault.disarm()
    # stream stays fully usable; the next trigger succeeds
    _churn(s, rng, start=2000)
    s.flush()
    assert s.maintenance_status()["compaction"]["runs"] >= 1


# ---------------------------------------------------------------------------
# fault injector semantics
# ---------------------------------------------------------------------------

def test_fault_injector_arming_and_counts():
    fi = FaultInjector()
    with pytest.raises(ValueError, match="unknown fault point"):
        fi.arm("no.such.point")
    fi.arm("wal.append", after=2, times=2)
    fired = [fi.fires("wal.append") for _ in range(6)]
    assert fired == [False, False, True, True, False, False]
    hits, nfired = fi.counts("wal.append")
    assert (hits, nfired) == (6, 2)


def test_fault_injector_seeded_probability_deterministic():
    a = FaultInjector()
    b = FaultInjector()
    a.arm("serve.decode", p=0.3, seed=11)
    b.arm("serve.decode", p=0.3, seed=11)
    fa = [a.fires("serve.decode") for _ in range(50)]
    fb = [b.fires("serve.decode") for _ in range(50)]
    assert fa == fb and any(fa) and not all(fa)


def test_fault_injector_env_spec():
    fi = FaultInjector("wal.append:1.0:2:1,snapshot.write:0.5")
    assert fi.armed("wal.append") and fi.armed("snapshot.write")
    fired = [fi.fires("wal.append") for _ in range(5)]
    assert fired == [False, False, True, False, False]


# ---------------------------------------------------------------------------
# boundary validation (api + engine submit)
# ---------------------------------------------------------------------------

def test_search_rejects_malformed_queries():
    x, rng = _corpus(n=120)
    s = api.build(x, backend="promips", seed=1, **BUILD)
    q = _queries(rng, b=2)
    with pytest.raises(ValueError, match="non-finite"):
        s.search(np.where(np.eye(2, D, dtype=bool), np.nan, q))
    with pytest.raises(ValueError, match="non-finite"):
        s.search(np.full((1, D), np.inf, np.float32))
    with pytest.raises(ValueError, match="dimension"):
        s.search(np.ones((2, D + 3), np.float32))
    with pytest.raises(ValueError, match="\\(B, d\\)"):
        s.search(np.ones((2, 2, D), np.float32))
    with pytest.raises(ValueError, match="floating"):
        s.search(jax.numpy.ones((2, D), jax.numpy.int32))
    # 1-D row and int lists still pass (cast, promoted to a batch of one)
    assert s.search(q[0]).ids.shape == (1, 8 if False else s.guarantee.k)


def test_stream_and_baseline_validation_share_the_boundary():
    x, rng = _corpus(n=120)
    for backend in ("promips-stream", "exact"):
        s = api.build(x, backend=backend, seed=1,
                      **(BUILD if backend != "exact" else {}))
        with pytest.raises(ValueError, match="non-finite"):
            s.search(np.full((1, D), np.nan, np.float32))
        with pytest.raises(ValueError, match="dimension"):
            s.search(np.ones((1, D + 1), np.float32))


# ---------------------------------------------------------------------------
# serve: degradation ladder + deadlines + health
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(small_model, **kw):
    from repro.serve import DecodeEngine
    cfg, params = small_model
    return DecodeEngine(params, cfg, batch_slots=2, max_len=64,
                        logits_mode="promips", **kw)


def test_degradation_ladder_steps_down_and_recovers(small_model):
    from repro.serve import DegradationPolicy
    pol = DegradationPolicy(tiers=(1.0, 0.5, 0.25),
                            recall_floors=(0.95, 0.8, 0.5),
                            queue_high=3, queue_low=1, patience=2, recovery=3)
    eng = _engine(small_model, degradation=pol, max_queue=16)
    assert eng._tier_budgets[0] is None
    assert (eng._tier_budgets[1] or 0) > (eng._tier_budgets[2] or 0) > 0
    rng = np.random.RandomState(0)
    vocab = small_model[0].vocab
    for _ in range(10):
        eng.submit(rng.randint(1, vocab, size=5), max_new_tokens=6)
    assert eng.health()["state"] == "ok"
    seen_tiers = set()
    while eng.queue or eng.active.any():
        eng.step()
        seen_tiers.add(eng.tier)
    assert eng.stepdowns >= 1, "sustained deep queue must step down"
    assert max(seen_tiers) >= 1
    for _ in range(pol.recovery + 2):   # idle calm ticks step back up
        eng.step()
    assert eng.tier == 0 and eng.stepups >= 1
    h = eng.health()
    assert h["state"] == "ok" and h["tier_recall_floor"] == 0.95
    assert set(h) >= {"step_latency_ewma_s", "compaction", "wal_lag",
                      "stepdowns", "stepups", "shed", "deadline_drops"}


def test_tier_budget_reduces_work(small_model):
    """A cheaper tier touches strictly less of the index for the same
    queries — the latency lever the ladder actually pulls."""
    from repro.serve import DegradationPolicy
    pol = DegradationPolicy(tiers=(1.0, 0.25), recall_floors=(1.0, 0.1),
                            queue_high=3, queue_low=1)
    eng = _engine(small_model, degradation=pol)
    rng = np.random.RandomState(1)
    q = rng.randn(3, small_model[0].d_model).astype(np.float32)
    full = eng.index.search(q, k=4, runtime=eng.search_runtime)
    eng.tier = 1
    cheap = eng.index.search(q, k=4, runtime=eng._tier_runtime())
    assert cheap.stats["pages"] < full.stats["pages"]


def test_deadlines_drop_queued_and_terminate_active(small_model):
    eng = _engine(small_model, max_queue=8)
    rng = np.random.RandomState(2)
    vocab = small_model[0].vocab
    # expires while queued (engine never steps until after the deadline)
    r1 = eng.submit(rng.randint(1, vocab, size=4), deadline_s=0.001)
    # expires mid-decode
    r2 = eng.submit(rng.randint(1, vocab, size=4), max_new_tokens=200,
                    deadline_s=0.05)
    time.sleep(0.002)
    eng.run(max_steps=500)
    assert r1.expired and not r1.out_tokens
    assert r2.expired and r2.out_tokens, "partial tokens retained"
    assert eng.deadline_drops == 2
    assert not eng.active.any() and not eng.queue


def test_submit_validates_prompts(small_model):
    eng = _engine(small_model)
    vocab = small_model[0].vocab
    with pytest.raises(ValueError, match="non-empty"):
        eng.submit(np.array([], np.int32))
    with pytest.raises(ValueError, match="integers"):
        eng.submit(np.array([1.5, 2.5]))
    with pytest.raises(ValueError, match="token ids"):
        eng.submit(np.array([0, vocab]))
    with pytest.raises(ValueError, match="token ids"):
        eng.submit(np.array([-1, 2]))
    with pytest.raises(ValueError, match="1-D"):
        eng.submit(np.ones((2, 3), np.int32))


def test_engine_surfaces_compaction_error(small_model):
    """Satellite 1: a latched background-compaction error is visible in
    health() / metrics_snapshot() without waiting for the next join()."""
    eng = _engine(small_model)
    rng = np.random.RandomState(3)
    fault.arm("compaction.rebuild")
    d = small_model[0].d_model
    n0 = eng.index.n
    ids = np.arange(10_000, 10_000 + n0 // 2)
    eng.index.insert(ids, rng.randn(len(ids), d).astype(np.float32))
    eng.index.delete(ids)   # churn past the default threshold
    deadline = time.time() + 5
    while time.time() < deadline:
        h = eng.health()
        if h["compaction"] and h["compaction"]["error_latched"]:
            break
        time.sleep(0.01)
    assert h["compaction"]["error_latched"]
    assert "FaultInjected" in h["compaction"]["last_error"]
    assert eng.metrics_snapshot()["maintenance"]["compaction"]["error_latched"]
    fault.disarm()
    with pytest.raises(RuntimeError):
        eng.join_compaction()   # join still surfaces (and clears) it


def test_serve_decode_fault_point(small_model):
    eng = _engine(small_model)
    fault.arm("serve.decode", times=1)
    eng.submit(np.arange(1, 5))
    with pytest.raises(FaultInjected):
        eng.step()
    eng.run(max_steps=50)   # engine survives; request completes
    assert not eng.queue and not eng.active.any()


# ---------------------------------------------------------------------------
# shared watchdog
# ---------------------------------------------------------------------------

def test_watchdog_is_the_straggler_monitor():
    from repro.distributed.fault import StragglerMonitor
    assert StragglerMonitor is EwmaWatchdog
    wd = EwmaWatchdog(threshold=2.0)
    assert not wd.observe(1.0)      # seed sample never flags
    assert not wd.observe(1.5)
    assert wd.observe(10.0)
    assert wd.events == 1
