"""Chi-square machinery + Conditions A/B (paper Section IV, Theorems 1-2)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly offline
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp
from repro.core.chi2 import chi2_cdf, chi2_ppf, chi2_ppf_host
from repro.core.conditions import (
    compensation_radius, condition_a, condition_b, condition_b_threshold)


@pytest.mark.parametrize("m", [2, 6, 8, 10, 16])
def test_chi2_cdf_matches_scipy(m):
    from scipy.stats import chi2
    xs = np.linspace(0.01, 5 * m, 64)
    ours = np.asarray(chi2_cdf(jnp.asarray(xs, jnp.float32), m))
    ref = chi2.cdf(xs, m)
    np.testing.assert_allclose(ours, ref, atol=2e-5)


@given(p=st.floats(0.05, 0.99), m=st.integers(2, 24))
@settings(max_examples=40, deadline=None)
def test_chi2_ppf_inverts_cdf(p, m):
    x = float(chi2_ppf(jnp.float32(p), m))
    assert abs(float(chi2_cdf(jnp.float32(x), m)) - p) < 1e-3
    assert abs(x - chi2_ppf_host(p, m)) < max(1e-3 * chi2_ppf_host(p, m), 1e-3)


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_condition_a_theorem1(data):
    """Condition A true => the tested point IS a c-AMIP answer (Theorem 1):
    <o_i, q> >= c <o*, q> for any o* with ||o*|| <= ||o_M||."""
    rng = np.random.RandomState(data.draw(st.integers(0, 10_000)))
    d = data.draw(st.integers(2, 16))
    c = data.draw(st.floats(0.1, 0.99))
    x = rng.standard_normal((64, d)).astype(np.float32) * 2
    q = rng.standard_normal(d).astype(np.float32)
    scores = x @ q
    max_l2sq = float((x * x).sum(1).max())
    q_l2sq = float(q @ q)
    best = float(scores.max())
    if bool(condition_a(best, max_l2sq, q_l2sq, c)):
        # the guarantee must hold against the exact optimum
        assert best >= c * scores.max() - 1e-4
        # and indeed against ANY point whose norm is bounded by o_M:
        # ||o*||^2 + ||q||^2 - 2<o*,q> >= 0 always, so <o*,q> <= (max+q)/2
        assert 2 * best / c >= max_l2sq + q_l2sq - 1e-4


def test_condition_b_threshold_equivalence():
    """Psi_m(t) >= p  <=>  t >= Psi_m^{-1}(p): the device-path threshold form
    agrees with the direct CDF form on a grid."""
    m, c, p = 8, 0.9, 0.7
    x_p = chi2_ppf_host(p, m)
    rng = np.random.RandomState(0)
    for _ in range(200):
        proj_d2 = float(rng.gamma(2, 8))
        best_ip = float(rng.standard_normal() * 5)
        max_l2sq, q_l2sq = float(rng.gamma(3, 4)), float(rng.gamma(3, 4))
        a = bool(condition_b(proj_d2, best_ip, max_l2sq, q_l2sq, c, p, m))
        b = bool(condition_b_threshold(proj_d2, best_ip, max_l2sq, q_l2sq, c, x_p))
        assert a == b


def test_compensation_radius_formula():
    """r' = sqrt(x_p (||o_M||^2 + ||q||^2 - 2<o_max,q>/c)), clipped at 0."""
    m, p, c = 6, 0.5, 0.9
    x_p = chi2_ppf_host(p, m)
    r = float(compensation_radius(1.0, 10.0, 5.0, c, x_p))
    assert np.isclose(r ** 2, x_p * (10 + 5 - 2 / c), rtol=1e-5)
    assert float(compensation_radius(100.0, 1.0, 1.0, c, x_p)) == 0.0


def test_lemma2_chi_square_ratio():
    """dis^2(P(o),P(q)) / dis^2(o,q) ~ chi2(m) (Lemma 2): empirical moments.

    Ratios of points under a SHARED projection are correlated, so moments
    are averaged over independent projection draws."""
    from repro.core.projections import make_projection, project
    rng = np.random.RandomState(1)
    d, m, n = 64, 8, 400
    ratios = []
    for seed in range(20):
        o = rng.standard_normal((n, d)).astype(np.float32)
        q = rng.standard_normal(d).astype(np.float32)
        a = make_projection(d, m, seed=seed)
        ratios.append(((project(o, a) - project(q[None], a)) ** 2).sum(1) /
                      np.maximum(((o - q) ** 2).sum(1), 1e-12))
    ratio = np.concatenate(ratios)
    assert abs(ratio.mean() - m) < 0.5          # E[chi2(m)] = m
    assert abs(ratio.var() - 2 * m) < 4.0       # Var = 2m
