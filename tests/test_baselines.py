"""Baseline methods (paper §VIII-A1): sanity accuracy + page accounting."""
import numpy as np
import pytest

from repro.baselines import ExactMIPS, H2ALSH, PQBased, RangeLSH
from repro.baselines.exact import exact_topk
from repro.core import overall_ratio, recall_at_k


@pytest.fixture(scope="module")
def corpus(mf_corpus):
    x, q = mf_corpus
    eids, escores = exact_topk(x, q[:10], 10)
    return x, q[:10], eids, escores


def test_exact(corpus):
    x, q, eids, escores = corpus
    m = ExactMIPS().build(x)
    ids, scores, st = m.search(q[0], 10)
    assert recall_at_k(ids, eids[0]) == 1.0
    assert st["pages"] == m.n_pages


@pytest.mark.parametrize("cls,kw,min_ratio", [
    (H2ALSH, {}, 0.85), (RangeLSH, {}, 0.55), (PQBased, dict(n_cells=16), 0.85)])
def test_baseline_quality(corpus, cls, kw, min_ratio):
    x, q, eids, escores = corpus
    m = cls(**kw).build(x)
    ratios, pages = [], []
    for i in range(10):
        ids, scores, st = m.search(q[i], 10)
        ratios.append(overall_ratio(scores, escores[i]))
        pages.append(st["pages"])
        assert st["pages"] > 0
    assert np.mean(ratios) >= min_ratio, np.mean(ratios)
    assert m.index_bytes > 0 and m.build_seconds >= 0
    # all baselines probe fewer pages than a full scan would by definition
    full = ExactMIPS().build(x).n_pages
    assert np.mean(pages) <= full * 2  # (index pages may add a small overhead)
