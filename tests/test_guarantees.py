"""Statistical recall-vs-p0 regression suite (Theorem 2 end-to-end).

The paper's value is its *probability-guaranteed* search: with no budget
truncation, P[the returned o_i has <o_i, q> >= c * <o_i*, q>] >= p0
(Theorem 2, driven by x_p = Psi_m^{-1}(p0)). This suite pins that contract
empirically over a seeded (c, p0) grid — every knob derived through
`GuaranteeConfig.derive` exactly as the facade derives it — for the three
search paths a perf PR could quietly break:

  host          paper-faithful sequential `HostSearcher` (Algorithms 2+3)
  fused         the unified runtime's default fused verification (eager
                host-orchestrated driver; budgets None = no truncation)
  sharded-fused `sharded_search` under shard_map — the in-graph fused
                driver on every shard + the all-gather top-k merge (shard
                count = jax.device_count(): 1 in the single-device tier,
                8 under scripts/ci.sh's multi-device tier)

The assertion is a one-sided binomial bound: empirical success rate
>= p0 - 3 * sqrt(p0 (1-p0) / n_queries).  A z=3 tolerance keeps the false-
alarm rate ~0.1% per cell if the true rate were exactly p0; in practice the
untruncated search succeeds on ~100% of queries, so any failure here means
a change actually voided the guarantee (truncation, a broken radius, a
mis-derived x_p), not noise.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import GuaranteeConfig
from repro.baselines.exact import exact_topk
from repro.core import ProMIPS, RuntimeConfig, runtime_search
from repro.core.sharded import (build_sharded, device_put_sharded_index,
                                sharded_search)
from repro.data.synthetic import mf_factors
from repro.launch.mesh import make_mesh_compat

K = 10
N = 4000
GRID = [(0.8, 0.5), (0.9, 0.5), (0.8, 0.8), (0.9, 0.8)]


def _tolerance(p0: float, n_queries: int) -> float:
    return 3.0 * float(np.sqrt(p0 * (1.0 - p0) / n_queries))


def _success_rate(scores, exact_scores, c: float) -> float:
    """Fraction of queries whose ENTIRE top-k meets the c-approximation:
    <o_i, q> >= c * <o_i*, q> at every rank i (ranks whose exact score is
    non-positive are vacuously satisfied — the ratio bound is about large
    inner products). Scores are exact inner products on every backend
    (`runtime._rescore` / host rescore), so this measures the guarantee,
    not score estimation error."""
    s = np.asarray(scores, np.float64)
    e = np.asarray(exact_scores, np.float64)
    ok = (s >= c * e - 1e-5) | (e <= 0.0)
    return float(np.mean(ok.all(axis=1)))


@pytest.fixture(scope="module")
def corpus():
    x = mf_factors(N, 48, 12, decay=0.5, seed=0, norm_tail=0.3)
    q = mf_factors(256, 48, 12, decay=0.5, seed=1)
    _, escores = exact_topk(x, q, K)
    return x, q, escores


@pytest.fixture(scope="module")
def built(corpus):
    """One index per derived m (m depends only on n); per grid point the
    (c, p0)-dependent statics — meta.c / meta.p / meta.x_p — are stamped in
    from `GuaranteeConfig.derive`, which is exactly what a rebuild at that
    (c, p0) computes (the arrays are geometry only: projection, layout,
    norms)."""
    x, _, _ = corpus
    m = GuaranteeConfig(c=0.9, p0=0.5, k=K).derive(N).m
    pm = ProMIPS.build(x, m=m, c=0.9, p=0.5, norm_strata=4, seed=0)
    n_shards = max(jax.device_count(), 1)
    sh = build_sharded(x, n_shards, m=m, c=0.9, p=0.5, norm_strata=4)
    mesh = make_mesh_compat((n_shards,), ("model",))
    shd = device_put_sharded_index(sh, mesh)
    return pm, shd, mesh


def _meta_for(meta, cfg: GuaranteeConfig):
    plan = cfg.derive(N)
    assert plan.budget is None and plan.budget2 is None  # no truncation
    assert plan.m == meta.m
    return dataclasses.replace(meta, c=cfg.c, p=cfg.p0, x_p=plan.x_p)


@pytest.mark.parametrize("c,p0", GRID)
def test_recall_floor_host(built, corpus, c, p0):
    x, q, escores = corpus
    pm, _, _ = built
    n_q = 64  # sequential path: fewer queries, wider (still z=3) tolerance
    scores = np.stack([np.asarray(pm.search_host(q[i], k=K, c=c, p=p0)[1])
                       for i in range(n_q)])
    rate = _success_rate(scores, escores[:n_q], c)
    assert rate >= p0 - _tolerance(p0, n_q), (rate, c, p0)


@pytest.mark.parametrize("c,p0", GRID)
def test_recall_floor_fused(built, corpus, c, p0):
    x, q, escores = corpus
    pm, _, _ = built
    meta = _meta_for(pm.meta, GuaranteeConfig(c=c, p0=p0, k=K))
    _, scores, stats = runtime_search(pm.arrays, meta,
                                      jnp.asarray(q, jnp.float32),
                                      RuntimeConfig(k=K))
    assert not np.asarray(stats.exhausted).any()  # None budget never truncates
    rate = _success_rate(scores, escores, c)
    assert rate >= p0 - _tolerance(p0, len(q)), (rate, c, p0)


@pytest.mark.parametrize("c,p0", GRID)
def test_recall_floor_sharded_fused(built, corpus, c, p0):
    x, q, escores = corpus
    _, shd, mesh = built
    meta = _meta_for(shd.meta, GuaranteeConfig(c=c, p0=p0, k=K))
    shd_cp = shd._replace(meta=meta)
    _, scores, _ = sharded_search(
        shd_cp, q, K, mesh,
        runtime=RuntimeConfig(mode="two_phase", verification="fused"))
    rate = _success_rate(scores, escores, c)
    assert rate >= p0 - _tolerance(p0, len(q)), (rate, c, p0)


@pytest.mark.parametrize("c,p0", GRID)
def test_recall_floor_fused_prefilter(built, corpus, c, p0):
    """The quantized-sketch prefilter (DESIGN.md §13) at the shipped
    eps=0.1 keeps the empirical Theorem-2 floor over the whole grid —
    fewer pages may NOT buy lower recall than p0 - 3*sigma."""
    x, q, escores = corpus
    pm, _, _ = built
    meta = _meta_for(pm.meta, GuaranteeConfig(c=c, p0=p0, k=K))
    cfg = RuntimeConfig(k=K, prefilter=True, prefilter_eps=0.1)
    _, scores, stats = runtime_search(pm.arrays, meta,
                                      jnp.asarray(q, jnp.float32), cfg)
    assert not np.asarray(stats.exhausted).any()
    rate = _success_rate(scores, escores, c)
    assert rate >= p0 - _tolerance(p0, len(q)), (rate, c, p0)
    # and the prefilter actually engages: strictly fewer pages than off
    _, _, st_off = runtime_search(pm.arrays, meta,
                                  jnp.asarray(q, jnp.float32),
                                  RuntimeConfig(k=K))
    assert (int(np.sum(np.asarray(stats.pages)))
            < int(np.sum(np.asarray(st_off.pages)))), (c, p0)


def test_prefilter_pages_monotone_in_eps(built, corpus):
    """Pages read are monotone non-decreasing in eps (a looser bound prunes
    less), with recall already pinned by the grid test above."""
    pm, _, _ = built
    x, q, _ = corpus
    qd = jnp.asarray(q[:64], jnp.float32)
    pages = []
    for eps in (0.05, 0.1, 0.3, 1.0):
        _, _, stats = runtime_search(
            pm.arrays, pm.meta, qd,
            RuntimeConfig(k=K, prefilter=True, prefilter_eps=eps))
        pages.append(int(np.sum(np.asarray(stats.pages))))
    assert pages == sorted(pages), pages


def test_grid_is_monotone_in_p0(built, corpus):
    """Sanity on the derivation itself: a higher p0 derives a larger x_p
    (wider radii), so the expected page work is monotone — the static
    threshold really is what drives the guarantee."""
    pm, _, _ = built
    pages = {}
    for c, p0 in GRID:
        meta = _meta_for(pm.meta, GuaranteeConfig(c=c, p0=p0, k=K))
        x, q, _ = corpus
        _, _, stats = runtime_search(pm.arrays, meta,
                                     jnp.asarray(q[:64], jnp.float32),
                                     RuntimeConfig(k=K))
        pages[(c, p0)] = float(np.mean(np.asarray(stats.pages)))
    for c in (0.8, 0.9):
        assert pages[(c, 0.8)] >= pages[(c, 0.5)], pages
