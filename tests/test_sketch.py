"""Quantized sketch prefilter (PR 6, DESIGN.md §13).

Covers the shared PQ machinery (`core/sketch.py`, now also the
implementation under `baselines/pq.py`), the build-time block sketch
invariants (the Cauchy-Schwarz error radius must DOMINATE every valid
row's distance — the soundness of the prefilter bound), the Pallas
sketch-scoring kernel vs the jnp oracle, prefilter-on parity across the
three fused drivers (eager host / in-graph jit / batched), losslessness
at eps=1, and sketch persistence through api save/load.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import ProMIPS, RuntimeConfig, runtime_search
from repro.core.sketch import (build_block_sketch, pick_subspaces, pq_assign,
                               pq_decode, pq_train)

K = 10


@pytest.fixture(scope="module")
def built(mf_corpus):
    x, q = mf_corpus
    pm = ProMIPS.build(x, m=8, c=0.9, p=0.5, norm_strata=4, page_bytes=2048)
    return x, np.asarray(q, np.float32), pm


def _assert_same(out_a, out_b, label):
    ids_a, scores_a, _ = out_a
    ids_b, scores_b, _ = out_b
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b),
                                  err_msg=f"{label}: ids")
    np.testing.assert_array_equal(np.asarray(scores_a), np.asarray(scores_b),
                                  err_msg=f"{label}: scores")


# ---------------------------------------------------------------------------
# PQ helpers (shared with baselines/pq.py)
# ---------------------------------------------------------------------------

def test_pick_subspaces_largest_divisor():
    assert pick_subspaces(128, 16) == 16
    assert pick_subspaces(48, 16) == 16
    assert pick_subspaces(50, 16) == 10
    assert pick_subspaces(7, 16) == 7     # prime: only 1 and itself divide
    assert pick_subspaces(13, 4) == 1


def test_pq_round_trip(rng):
    """Codes are in range, decode inverts assign's codeword lookup, and a
    re-assignment of the decoded vectors is a fixed point (each decoded
    vector IS its own nearest codeword)."""
    x = rng.randn(400, 24).astype(np.float32)
    cb = pq_train(x, 4, 16, seed=3)
    assert cb.shape == (4, 16, 6)
    codes = pq_assign(x, cb)
    assert codes.shape == (400, 4) and codes.dtype == np.int32
    assert codes.min() >= 0 and codes.max() < 16
    dec = pq_decode(cb, codes)
    assert dec.shape == x.shape
    np.testing.assert_array_equal(pq_assign(dec, cb), codes)
    # decoding is the concatenation of the assigned codewords
    np.testing.assert_array_equal(dec[:, :6], cb[0][codes[:, 0]])


def test_pq_error_decreases_with_centroids(rng):
    """Mean reconstruction error is monotone non-increasing in the codebook
    size and beats the trivial zero-code (the padding codeword)."""
    x = rng.randn(600, 32).astype(np.float32)
    errs = []
    for k in (2, 8, 32, 128):
        cb = pq_train(x, 4, k, seed=0)
        dec = pq_decode(cb, pq_assign(x, cb))
        errs.append(float(np.linalg.norm(x - dec, axis=1).mean()))
    assert all(a >= b for a, b in zip(errs, errs[1:])), errs
    assert errs[-1] < np.linalg.norm(x, axis=1).mean()


def test_pqbased_baseline_uses_shared_pq(mf_corpus):
    """`baselines/pq.py` round-trips through the shared helpers: its stored
    codes re-derive from its own codebooks, and decoding reconstructs the
    QNF residuals better than the zero vector."""
    from repro.baselines.pq import PQBased
    from repro.core.idistance import kmeans_np

    x, q = mf_corpus
    b = PQBased(n_subspaces=4, n_centroids=64, seed=0).build(x[:1200])
    assert b.codes.shape == (1200, 4)
    # replicate the build's residuals (kmeans_np is deterministic in seed)
    coarse, assign = kmeans_np(b.xq, 64, iters=10, seed=b.seed)
    np.testing.assert_array_equal(coarse, b.coarse)
    resid = b.xq - b.coarse[assign]
    np.testing.assert_array_equal(
        pq_assign(resid, b.codebooks).astype(np.uint8), b.codes)
    dec = pq_decode(b.codebooks, b.codes.astype(np.int32))
    assert (np.linalg.norm(resid - dec, axis=1).mean()
            < np.linalg.norm(resid, axis=1).mean())
    ids, scores, stats = b.search(q[0], k=K)
    assert ids.shape == (K,) and stats["pages"] > 0


# ---------------------------------------------------------------------------
# block sketch build invariants
# ---------------------------------------------------------------------------

def test_block_sketch_error_radius_dominates(built):
    """sk_err[b] >= ||o_r - mu~_b|| for EVERY valid row r of block b — the
    inequality the whole prefilter bound stands on — and padded rows /
    fully-padded blocks contribute nothing."""
    x, _, pm = built
    arr, meta = pm.index.arrays, pm.meta
    xs = np.asarray(arr.x).reshape(meta.n_blocks, meta.page_rows, meta.d)
    vb = (np.asarray(arr.ids) >= 0).reshape(meta.n_blocks, meta.page_rows)
    mu_hat = np.asarray(arr.sk_mu)
    dist = np.sqrt(((xs - mu_hat[:, None, :]) ** 2).sum(-1))
    assert np.all(np.where(vb, dist, 0.0)
                  <= np.asarray(arr.sk_err)[:, None] + 1e-4)
    assert meta.sk_subspaces == pick_subspaces(meta.d, 16)
    assert np.asarray(arr.sk_codes).shape == (meta.n_blocks,
                                              meta.sk_subspaces)
    # decoded centroids really are the decode of the persisted codes
    np.testing.assert_allclose(
        pq_decode(np.asarray(arr.sk_codebooks), np.asarray(arr.sk_codes)),
        mu_hat, rtol=1e-6, atol=1e-6)


def test_block_sketch_rebuild_is_deterministic(built):
    x, _, pm = built
    arr, meta = pm.index.arrays, pm.meta
    mu, cb, codes, err = build_block_sketch(
        np.asarray(arr.x), np.asarray(arr.ids), meta.page_rows,
        meta.sk_subspaces, meta.sk_codewords, seed=0)
    np.testing.assert_array_equal(mu, np.asarray(arr.sk_mu))
    np.testing.assert_array_equal(codes, np.asarray(arr.sk_codes))
    np.testing.assert_array_equal(err, np.asarray(arr.sk_err))


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

def test_sketch_kernel_matches_ref(built):
    """Pallas sketch scorer (interpret mode) vs the decoded-centroid sgemm
    oracle: same sum, different association — tight allclose."""
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.block_mips import sketch_scores

    x, q, pm = built
    arr = pm.arrays
    want = np.asarray(ref.sketch_scores_ref(jnp.asarray(q), arr.sk_mu))
    got = np.asarray(sketch_scores(jnp.asarray(q), arr.sk_codebooks,
                                   arr.sk_codes, interpret=True))
    assert got.shape == want.shape == (q.shape[0], pm.meta.n_blocks)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# prefilter semantics
# ---------------------------------------------------------------------------

def test_prefilter_lossless_at_eps_one(built):
    """eps=1 keeps the hard Cauchy-Schwarz bracket: pruned blocks provably
    hold no top-k row, so ids AND scores are bit-identical to prefilter-off
    for every verification backend."""
    x, q, pm = built
    base = pm.search(q, k=K)
    for verification in ("fused", "batched", "scan"):
        out = pm.search(q, k=K, verification=verification,
                        prefilter=True, prefilter_eps=1.0)
        _assert_same(out, base, f"eps=1-{verification}")


def test_prefilter_three_driver_parity(built):
    """prefilter on at a pruning eps: eager host-orchestrated fused,
    in-graph fused (under jit), and the batched graph agree bit-for-bit on
    ids, scores, pages and candidates."""
    import jax

    x, q, pm = built
    cfg = RuntimeConfig(k=K, prefilter=True, prefilter_eps=0.3)
    out_e = runtime_search(pm.arrays, pm.meta, q, cfg)
    traced = jax.jit(lambda arrays: runtime_search(arrays, pm.meta, q, cfg))
    out_t = traced(pm.arrays)
    out_b = runtime_search(pm.arrays, pm.meta, q,
                           dataclasses.replace(cfg, verification="batched"))
    _assert_same(out_t, out_e, "jit-fused-vs-eager-fused")
    _assert_same(out_t, out_b, "jit-fused-vs-batched")
    for field in ("pages", "candidates", "exhausted"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out_t[2], field)),
            np.asarray(getattr(out_e[2], field)), err_msg=f"stat {field}")


def test_prefilter_prunes_pages_and_keeps_recall(built):
    """A pruning eps reads strictly fewer pages than prefilter-off while
    recall vs exact stays high (the §13 calibration, small-corpus scale)."""
    x, q, pm = built
    off = pm.search(q, k=K)
    on = pm.search(q, k=K, prefilter=True, prefilter_eps=0.3)
    assert (int(np.sum(np.asarray(on[2].pages)))
            < int(np.sum(np.asarray(off[2].pages))))
    exact = np.argsort(-(x @ q.T), axis=0, kind="stable")[:K].T
    hits = np.mean([len(set(map(int, a)) & set(map(int, e))) / K
                    for a, e in zip(np.asarray(on[0]), exact)])
    assert hits >= 0.9


def test_prefilter_requires_sketch_and_two_phase(built):
    x, q, pm = built
    meta_old = dataclasses.replace(pm.meta, sk_subspaces=0, sk_codewords=0)
    with pytest.raises(ValueError, match="no sketch"):
        runtime_search(pm.arrays, meta_old, q,
                       RuntimeConfig(k=K, prefilter=True))
    with pytest.raises(ValueError, match="two_phase"):
        runtime_search(pm.arrays, pm.meta, q,
                       RuntimeConfig(k=K, prefilter=True, mode="progressive"))
    with pytest.raises(ValueError, match="prefilter_eps"):
        RuntimeConfig(k=K, prefilter=True, prefilter_eps=0.0)
    with pytest.raises(ValueError, match="prefilter_eps"):
        RuntimeConfig(k=K, prefilter=True, prefilter_eps=1.5)


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def test_sketch_survives_save_load(tmp_path, mf_corpus):
    """api save -> load round-trips the sketch arrays bit-identically and a
    prefilter-on search after load matches the pre-save one."""
    from repro import api

    x, q = mf_corpus
    s = api.build(x[:2000], backend="promips",
                  guarantee=api.GuaranteeConfig(c=0.9, p0=0.6, k=K),
                  seed=0, prefilter=True, prefilter_eps=0.3)
    assert type(s).capabilities.prefilter
    before = s.search(q[:8], k=K)
    loaded = api.load(s.save(str(tmp_path / "sk")))
    a0, a1 = s.pm.index.arrays, loaded.pm.index.arrays
    for field in ("sk_mu", "sk_codebooks", "sk_codes", "sk_err"):
        np.testing.assert_array_equal(np.asarray(getattr(a0, field)),
                                      np.asarray(getattr(a1, field)),
                                      err_msg=field)
    assert loaded.pm.meta.sk_subspaces == s.pm.meta.sk_subspaces
    after = loaded.search(q[:8], k=K)
    np.testing.assert_array_equal(before.ids, after.ids)
    np.testing.assert_array_equal(before.scores, after.scores)
