"""H2-ALSH (Huang et al., KDD'18) benchmark implementation.

Structure-faithful NumPy version: homocentric-hypersphere norm partitions
(geometric norm ranges with ratio c0), the error-free QNF asymmetric
transform per partition (append sqrt(M_j^2 - ||x||^2); query scaled), and a
QALSH-style E2LSH candidate search inside each partition. Partitions are
visited in descending max-norm order with the M_j * ||q|| upper-bound early
stop — the method's signature trick.

Page accounting matches ProMIPS's model: candidate fetches touch 4 KB pages
of the partition-ordered data layout; every LSH table lookup touches one
index page per probed bucket.
"""
from __future__ import annotations

import time

import numpy as np


class H2ALSH:
    name = "h2-alsh"

    def __init__(self, c0: float = 2.0, n_tables: int = 16, w: float = 4.0,
                 multiprobe: int = 1, page_bytes: int = 4096, seed: int = 0):
        self.c0, self.n_tables, self.w = c0, n_tables, w
        self.multiprobe = multiprobe
        self.page_bytes, self.seed = page_bytes, seed

    def build(self, x: np.ndarray):
        t0 = time.time()
        x = np.ascontiguousarray(x, np.float32)
        n, d = x.shape
        self.d = d
        self.page_rows = max(1, self.page_bytes // (4 * d))
        rng = np.random.RandomState(self.seed)
        norms = np.linalg.norm(x, axis=1)
        order = np.argsort(-norms, kind="stable")
        m_max = norms[order[0]] if n else 1.0

        # geometric norm ranges: (M/c0^{j+1}, M/c0^j]
        bounds = []
        hi = m_max
        while True:
            lo = hi / self.c0
            bounds.append((hi, lo))
            if lo < max(1e-6 * m_max, 1e-12) or len(bounds) > 40:
                break
            hi = lo
        self.parts = []
        base = 0
        ptr = 0
        self.perm = order
        self.x = x[order]
        self.norms = norms[order]
        for hi, lo in bounds:
            end = ptr
            while end < n and self.norms[end] > lo - 1e-12:
                end += 1
            if end > ptr:
                rows = np.arange(ptr, end)
                m_j = self.norms[ptr]
                aug = np.sqrt(np.maximum(m_j ** 2 - self.norms[rows] ** 2, 0.0))
                xq = np.concatenate([self.x[rows], aug[:, None]], axis=1)  # QNF
                a = rng.standard_normal((d + 1, self.n_tables)).astype(np.float32)
                b = rng.rand(self.n_tables).astype(np.float32) * self.w
                codes = np.floor((xq @ a + b) / (self.w * m_j)).astype(np.int64)
                tables = []
                for t in range(self.n_tables):
                    buckets: dict[int, np.ndarray] = {}
                    for key in np.unique(codes[:, t]):
                        buckets[int(key)] = rows[codes[:, t] == key]
                    tables.append(buckets)
                self.parts.append(dict(rows=rows, m=m_j, a=a, b=b, tables=tables))
            ptr = end
            if ptr >= n:
                break
        self.index_bytes = sum(
            p["a"].nbytes + 8 * len(p["rows"]) * self.n_tables for p in self.parts
        )
        self.build_seconds = time.time() - t0
        return self

    def search(self, q: np.ndarray, k: int = 10):
        q = np.asarray(q, np.float32)
        qn = np.linalg.norm(q)
        top_s = np.full(k, -np.inf)
        top_i = np.full(k, -1, np.int64)
        pages, cand = 0, 0
        resident: set[int] = set()
        for part in self.parts:  # descending max norm
            if part["m"] * qn <= top_s[k - 1]:  # upper-bound early stop
                break
            qa = np.concatenate([q * part["m"], [0.0]])
            keys = np.floor((qa @ part["a"] + part["b"]) / (self.w * part["m"])).astype(np.int64)
            cand_rows: list[np.ndarray] = []
            for t, buckets in enumerate(part["tables"]):
                pages += 1  # bucket lookup = one index page
                for dk in range(-self.multiprobe, self.multiprobe + 1):
                    hit = buckets.get(int(keys[t]) + dk)
                    if hit is not None:
                        cand_rows.append(hit)
            if not cand_rows:
                continue
            rows = np.unique(np.concatenate(cand_rows))
            for pg in np.unique(rows // self.page_rows):
                if pg not in resident:
                    resident.add(int(pg))
                    pages += 1
            scores = self.x[rows] @ q
            cand += len(rows)
            merged_s = np.concatenate([top_s, scores])
            merged_i = np.concatenate([top_i, self.perm[rows]])
            sel = np.argsort(-merged_s, kind="stable")[:k]
            top_s, top_i = merged_s[sel], merged_i[sel]
        return top_i, top_s, {"pages": pages, "candidates": cand}
