"""PQ-based baseline (paper §VIII-A1): H2-ALSH's asymmetric QNF transform to
reduce MIPS -> NN, then an IVF-PQ pipeline in the transformed space — coarse
inverted lists, product quantisation (16 subspaces x 256 centroids, 16
probed cells, per the paper's setting), ADC lookup-table scan of the probed
lists, exact re-rank of the survivors by true inner product.

Page model: PQ codes of a probed list stream sequentially (code pages);
re-ranked candidates touch their data pages.
"""
from __future__ import annotations

import time

import numpy as np

from ..core.idistance import kmeans_np
from ..core.sketch import pq_assign, pq_train


class PQBased:
    name = "pq-based"

    def __init__(self, n_subspaces: int = 16, n_centroids: int = 256,
                 n_cells: int = 64, n_probe: int = 16, rerank: int = 256,
                 page_bytes: int = 4096, seed: int = 0):
        self.m_sub, self.ksub = n_subspaces, n_centroids
        self.n_cells, self.n_probe, self.rerank = n_cells, n_probe, rerank
        self.page_bytes, self.seed = page_bytes, seed

    def build(self, x: np.ndarray):
        t0 = time.time()
        x = np.ascontiguousarray(x, np.float32)
        n, d = x.shape
        self.page_rows = max(1, self.page_bytes // (4 * d))
        norms = np.linalg.norm(x, axis=1)
        self.m_max = float(norms.max()) if n else 1.0
        aug = np.sqrt(np.maximum(self.m_max ** 2 - norms ** 2, 0.0))
        xq = np.concatenate([x, aug[:, None]], axis=1)  # QNF -> NN space
        dq = d + 1
        pad = (-dq) % self.m_sub
        if pad:
            xq = np.concatenate([xq, np.zeros((n, pad), np.float32)], axis=1)
        self.dq = dq + pad
        self.sub_d = self.dq // self.m_sub

        cells = min(self.n_cells, n)
        self.coarse, assign = kmeans_np(xq, cells, iters=10, seed=self.seed)
        resid = xq - self.coarse[assign]
        rng = np.random.RandomState(self.seed + 1)
        train = resid[rng.choice(n, size=min(n, 4000), replace=False)]
        self.codebooks = pq_train(train, self.m_sub, self.ksub, iters=8,
                                  seed=self.seed)
        codes = pq_assign(resid, self.codebooks).astype(np.uint8)
        self.lists = [np.nonzero(assign == c)[0] for c in range(cells)]
        self.codes = codes
        self.x = x
        self.xq = xq
        self.index_bytes = (self.coarse.nbytes + self.codebooks.nbytes +
                            codes.nbytes + 8 * n)
        self.build_seconds = time.time() - t0
        return self

    def search(self, q: np.ndarray, k: int = 10):
        q = np.asarray(q, np.float32)
        qa = np.concatenate([q, np.zeros(self.dq - len(q), np.float32)])
        d_cell = ((self.coarse - qa) ** 2).sum(1)
        probe = np.argsort(d_cell, kind="stable")[: self.n_probe]
        pages, cand = 0, 0
        all_rows, all_adc = [], []
        for c in probe:
            rows = self.lists[int(c)]
            if len(rows) == 0:
                continue
            resid_q = qa - self.coarse[c]
            lut = np.zeros((self.m_sub, self.ksub), np.float32)
            for s in range(self.m_sub):
                sl = slice(s * self.sub_d, (s + 1) * self.sub_d)
                lut[s] = ((self.codebooks[s] - resid_q[sl]) ** 2).sum(1)
            adc = lut[np.arange(self.m_sub)[None, :], self.codes[rows]].sum(1)
            all_rows.append(rows)
            all_adc.append(adc)
            cand += len(rows)
            code_page_rows = max(1, self.page_bytes // self.m_sub)
            pages += -(-len(rows) // code_page_rows)  # code pages stream
        if not all_rows:
            return np.full(k, -1), np.full(k, -np.inf), {"pages": pages, "candidates": 0}
        rows = np.concatenate(all_rows)
        adc = np.concatenate(all_adc)
        keep = rows[np.argsort(adc, kind="stable")[: self.rerank]]
        resident = set()
        for pg in np.unique(keep // self.page_rows):
            resident.add(int(pg))
            pages += 1
        scores = self.x[keep] @ q
        sel = np.argsort(-scores, kind="stable")[:k]
        ids = keep[sel]
        out_s = scores[sel]
        if len(ids) < k:
            ids = np.pad(ids, (0, k - len(ids)), constant_values=-1)
            out_s = np.pad(out_s, (0, k - len(out_s)), constant_values=-np.inf)
        return ids, out_s, {"pages": pages, "candidates": cand}
