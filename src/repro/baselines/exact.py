"""Exact MIPS oracle: full corpus scan (numpy for benchmarks, jnp/Pallas for
device use). Ground truth for overall-ratio / recall and the page-access
upper bound (a linear scan touches every page once)."""
from __future__ import annotations

import numpy as np


class ExactMIPS:
    name = "exact"

    def __init__(self, page_bytes: int = 4096):
        self.page_bytes = page_bytes

    def build(self, x: np.ndarray):
        self.x = np.ascontiguousarray(x, np.float32)
        n, d = x.shape
        self.page_rows = max(1, self.page_bytes // (4 * d))
        self.n_pages = -(-n // self.page_rows)
        self.index_bytes = 0  # no index
        self.build_seconds = 0.0
        return self

    def search(self, q: np.ndarray, k: int = 10):
        scores = self.x @ q
        idx = np.argpartition(-scores, min(k, len(scores) - 1))[:k]
        idx = idx[np.argsort(-scores[idx], kind="stable")]
        return idx, scores[idx], {"pages": self.n_pages, "candidates": len(self.x)}


def exact_topk(x: np.ndarray, queries: np.ndarray, k: int):
    """(ids (B,k), scores (B,k)) for a query batch — shared test helper."""
    scores = queries @ x.T  # (B, n)
    idx = np.argsort(-scores, axis=1, kind="stable")[:, :k]
    return idx, np.take_along_axis(scores, idx, axis=1)
