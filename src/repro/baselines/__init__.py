from .exact import ExactMIPS, exact_topk
from .h2_alsh import H2ALSH
from .pq import PQBased
from .range_lsh import RangeLSH

__all__ = ["ExactMIPS", "exact_topk", "H2ALSH", "RangeLSH", "PQBased"]
