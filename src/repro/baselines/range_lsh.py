"""Norm-Ranging LSH (Yan et al., NeurIPS'18) benchmark implementation.

Splits the dataset into equal-size subsets by norm rank; each subset gets a
Simple-LSH symmetric transform (normalise by the subset max norm, append
sqrt(1 - ||x||^2/M_i^2)) and SimHash signatures (16-bit codes in the paper's
setting). The query probes subsets in descending upper-bound order
(M_i * ||q||), ranking candidates by Hamming distance — the single-table
multi-probe strategy the paper credits for its low page counts.
"""
from __future__ import annotations

import time

import numpy as np


class RangeLSH:
    name = "range-lsh"

    def __init__(self, n_subsets: int = 32, code_bits: int = 16,
                 probe_radius: int = 4, page_bytes: int = 4096, seed: int = 0):
        self.n_subsets, self.code_bits = n_subsets, code_bits
        self.probe_radius = probe_radius
        self.page_bytes, self.seed = page_bytes, seed

    def build(self, x: np.ndarray):
        t0 = time.time()
        x = np.ascontiguousarray(x, np.float32)
        n, d = x.shape
        self.page_rows = max(1, self.page_bytes // (4 * d))
        rng = np.random.RandomState(self.seed)
        norms = np.linalg.norm(x, axis=1)
        order = np.argsort(-norms, kind="stable")  # descending norm layout
        self.x, self.perm, self.norms = x[order], order, norms[order]
        self.a = rng.standard_normal((d + 1, self.code_bits)).astype(np.float32)
        splits = np.array_split(np.arange(n), self.n_subsets)
        self.subsets = []
        for rows in splits:
            if len(rows) == 0:
                continue
            m_i = self.norms[rows[0]]
            xn = self.x[rows] / max(m_i, 1e-12)
            aug = np.sqrt(np.maximum(1.0 - (xn * xn).sum(1), 0.0))
            xh = np.concatenate([xn, aug[:, None]], axis=1)  # Simple-LSH
            codes = ((xh @ self.a) >= 0).astype(np.uint32)
            packed = (codes << np.arange(self.code_bits, dtype=np.uint32)).sum(1)
            self.subsets.append(dict(rows=rows, m=m_i, codes=packed.astype(np.uint32)))
        self.index_bytes = self.a.nbytes + sum(4 * len(s["rows"]) for s in self.subsets)
        self.build_seconds = time.time() - t0
        return self

    def search(self, q: np.ndarray, k: int = 10):
        q = np.asarray(q, np.float32)
        qn = np.linalg.norm(q)
        qh = np.concatenate([q / max(qn, 1e-12), [0.0]])
        qcode_bits = (qh @ self.a) >= 0
        qcode = (qcode_bits.astype(np.uint32) <<
                 np.arange(self.code_bits, dtype=np.uint32)).sum()
        top_s = np.full(k, -np.inf)
        top_i = np.full(k, -1, np.int64)
        pages, cand = 0, 0
        resident: set[int] = set()
        for sub in self.subsets:  # descending max-norm order
            if sub["m"] * qn <= top_s[k - 1]:
                break
            pages += 1  # signature scan of the subset = one index page
            ham = np.zeros(len(sub["rows"]), np.int32)
            xor = sub["codes"] ^ np.uint32(qcode)
            for b in range(self.code_bits):
                ham += ((xor >> np.uint32(b)) & 1).astype(np.int32)
            # hamming-ranked probing: radius plus a top-fraction floor
            n_take = max(int(np.sum(ham <= self.probe_radius)), max(16, len(ham) // 16))
            rows = sub["rows"][np.argsort(ham, kind="stable")[:n_take]]
            if len(rows) == 0:
                continue
            for pg in np.unique(rows // self.page_rows):
                if pg not in resident:
                    resident.add(int(pg))
                    pages += 1
            scores = self.x[rows] @ q
            cand += len(rows)
            merged_s = np.concatenate([top_s, scores])
            merged_i = np.concatenate([top_i, self.perm[rows]])
            sel = np.argsort(-merged_s, kind="stable")[:k]
            top_s, top_i = merged_s[sel], merged_i[sel]
        return top_i, top_s, {"pages": pages, "candidates": cand}
