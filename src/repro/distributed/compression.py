"""Gradient compression for cross-pod sync.

Two composable pieces:

1. ``int8_psum(tree, axis_name)`` — an explicit quantize -> integer
   all-reduce -> dequantize collective for use under shard_map: each tensor
   is scaled per-leaf to int8, summed in int32 (no overflow for <= 2^23
   ranks), and rescaled. 4x fewer bytes on the wire than f32 psum.

2. ``ErrorFeedback`` — 1-bit/8-bit error-feedback quantization of the grad
   tree applied before the optimizer; the residual is carried in the train
   state so compression error does not bias the trajectory (Seide et al.).

The train loop enables (2) via config; (1) is the wire format the pod-axis
sync uses when the trainer runs its gradient reduction under shard_map
(tests/test_distributed.py exercises it on 8 host devices).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def _quant_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_psum(tree, axis_name: str):
    """Quantized psum for use inside shard_map: int8 payload, int32 sum."""
    def one(x):
        x32 = x.astype(jnp.float32)
        q, scale = _quant_int8(x32)
        # max-scale across ranks so dequantization is consistent
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale
    return jax.tree.map(one, tree)


class ErrorFeedback(NamedTuple):
    residual: dict


def error_feedback_init(params) -> ErrorFeedback:
    return ErrorFeedback(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_grads(grads, ef: ErrorFeedback):
    """int8 quantize-dequantize with residual carry (error feedback)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quant_int8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq
    out = jax.tree.map(one, grads, ef.residual)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 2 and not hasattr(t, "_fields")
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    return new_g, ErrorFeedback(residual=new_r)
