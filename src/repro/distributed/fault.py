"""Fault tolerance & straggler mitigation (host-side runtime policy).

- StragglerMonitor: EWMA of step latency; a step slower than
  `threshold x` the EWMA flags a straggler event. The trainer's policy is
  deadline-based *data skip*: the step's batch indices are consumed (the
  stream is stateless in `step`, so every healthy worker advances
  identically) and the checkpoint cadence tightens until latency recovers.
  The implementation is `repro.robust.EwmaWatchdog` — ONE shared EWMA
  detector for the trainer and the serve engine's degradation ladder
  (DESIGN.md §16); this name is the trainer-facing alias.
- restart_plan: on resume, recompute the exact data position from the
  restored step — no data is replayed or skipped (determinism comes from
  TokenStream.batch_at(step)).
- ElasticPolicy: decides the mesh from the *visible* device count; with the
  mesh-agnostic checkpoints (distributed/checkpoint.py) a job restarted on
  fewer/more hosts re-shards the same logical state (tested 8 -> 4).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..robust.watchdog import EwmaWatchdog

StragglerMonitor = EwmaWatchdog


def restart_plan(restored_step: int, total_steps: int):
    """Steps still to run after a restore; data position == step index."""
    return range(restored_step, total_steps)


@dataclass(frozen=True)
class ElasticPolicy:
    """Choose a mesh shape for the devices actually alive."""
    model_parallel: int = 16

    def mesh_shape(self, n_devices: int):
        mp = self.model_parallel
        while mp > 1 and n_devices % mp:
            mp //= 2
        return (n_devices // mp, mp)  # (data, model)
