"""Logical -> physical sharding rules (DESIGN.md §7).

Parameters are sharded by *name-path rules* applied to the trailing
dimensions (leading scan-stack axes stay unsharded); every rule checks
divisibility against the mesh and falls back to replication, so one rule set
serves every (arch × mesh) cell. Inputs/caches get family-aware specs from
``batch_specs`` / ``cache_specs``.

Data-parallel axes: ("pod", "data") when the mesh has a pod axis, else
("data",). Tensor/expert axes: "model".
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


def _spec_for(path: str, shape, mesh: Mesh) -> P:
    """Trailing-dims PartitionSpec for one parameter."""
    model_ok = lambda d: _fits(shape[d], mesh, "model")
    nd = len(shape)

    def pad(*trailing):
        return P(*([None] * (nd - len(trailing)) + list(trailing)))

    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    if name == "embed":
        return pad("model", None) if model_ok(-2) else pad(None, None)
    if name == "unembed":
        return pad(None, "model") if model_ok(-1) else pad(None, None)
    if name in ("wq", "wk", "wv", "w_gates", "w_up", "w_gate", "in_proj", "w_if"):
        if parent == "moe":
            # MoE experts (…, E, d, ff): shard experts if divisible, else ff
            if _fits(shape[-3], mesh, "model"):
                return pad("model", None, None)
            return pad(None, None, "model") if model_ok(-1) else pad(None, None, None)
        return pad(None, "model") if model_ok(-1) else pad(None, None)
    if name in ("wo", "w_down", "out_proj"):
        if parent == "moe":  # MoE (…, E, ff, d)
            if _fits(shape[-3], mesh, "model"):
                return pad("model", None, None)
            return pad(None, "model", None) if _fits(shape[-2], mesh, "model") else pad(None, None, None)
        return pad("model", None) if model_ok(-2) else pad(None, None)
    if name == "router":
        return pad(None, None)
    return P(*([None] * nd))  # norms, gates, biases, conv, frontend


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_specs(params_shape: Any, mesh: Mesh):
    """PartitionSpec pytree for a params (shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), leaf.shape, mesh), params_shape
    )


def param_shardings(params_shape: Any, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh))


def zero1_specs(params_shape: Any, mesh: Mesh):
    """Optimizer-state / grad-accumulator specs (ZeRO-1 partitioning).

    The 'data' axis is appended to the dim that is ALREADY model-sharded
    (P(..., ("model","data"))): the param<->moment reshard is then a
    same-dim slice / all-gather with a compatible device order, which GSPMD
    executes as a cheap subgroup collective. Putting 'data' on a *different*
    dim triggers GSPMD's replicate-then-repartition last resort (~33 GB f32
    transients on qwen3-32b — EXPERIMENTS.md §Perf iter 1). Params with no
    model-sharded dim (norms, biases — tiny) stay replicated."""
    base = param_specs(params_shape, mesh)
    dsz = axis_size(mesh, "data")

    def upgrade(leaf, spec):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (dim, pt) in enumerate(zip(leaf.shape, parts)):
            if pt == "model" and dim % (axis_size(mesh, "model") * dsz) == 0:
                parts[i] = ("model", "data")
                return P(*parts)
        # no extendable model dim (e.g. MoE expert-sharded stacks): shard the
        # largest free dim over data — cross-dim reshard, but measured cheap
        # when the model-sharded dim is untouched (see §Perf iter 1 notes)
        best, best_size = None, 0
        for i, (dim, pt) in enumerate(zip(leaf.shape, parts)):
            if pt is None and dim % dsz == 0 and dim > best_size:
                best, best_size = i, dim
        if best is not None and leaf.size >= 1 << 20:
            parts[best] = "data"
        return P(*parts)

    return jax.tree.map(upgrade, params_shape, base)


# ---------------------------------------------------------------------------
# input / cache specs per shape cell
# ---------------------------------------------------------------------------

def batch_specs(cfg, shape, mesh: Mesh):
    """Specs for the train/prefill batch dict."""
    dp = dp_axes(mesh)
    bdim = dp if _fits(shape.global_batch, mesh, dp) else None
    spec = {
        "tokens": P(bdim, None),
        "labels": P(bdim, None),
    }
    if cfg.frontend == "vision":
        spec["patches"] = P(bdim, None, None)
    if cfg.frontend == "audio":
        spec["frames"] = P(bdim, None, None)
    return spec


def cache_specs(cfg, shape, mesh: Mesh):
    """Specs for the decode cache.

    KV model-axis placement preference: kv_heads > head_dim > sequence.
    Head/dim sharding keeps the flash-decode block scan fully local (scores
    psum only); sequence sharding makes GSPMD reshard every scanned block
    (measured collective blow-up — EXPERIMENTS.md §Perf iter 2). Sequence
    sharding remains the fallback (h2o-danube: kh=8, dh=120) and the
    long-context path for unshardable batch (long_500k, B=1) where it is
    paired with the context-parallel merge.
    """
    dp = dp_axes(mesh)
    b = shape.global_batch
    bdim = dp if _fits(b, mesh, dp) else None
    kh, dh = cfg.n_kv_heads, cfg.head_dim_
    if bdim is None:
        # batch unshardable (long_500k, B=1): context parallelism — shard
        # sequence over data, heads/dim over model when divisible
        hd = "model" if _fits(kh, mesh, "model") else (
            "model" if _fits(dh, mesh, "model") else None)
        if hd and _fits(kh, mesh, "model"):
            kv = P(None, None, "data", "model", None)
        elif hd:
            kv = P(None, None, "data", None, "model")
        else:
            kv = P(None, None, ("data", "model"), None, None)
        seq_axes = "data"
    elif _fits(kh, mesh, "model"):
        kv = P(None, bdim, None, "model", None)
        seq_axes = None
    elif _fits(dh, mesh, "model"):
        kv = P(None, bdim, None, None, "model")
        seq_axes = None
    else:
        kv = P(None, bdim, "model", None, None)
        seq_axes = "model"
    specs = {"len": P(bdim)}
    if cfg.block_pattern in ("attn", "encdec"):
        specs["k"] = kv
        specs["v"] = kv
    if cfg.block_pattern == "encdec":
        specs["xk"] = P(None, bdim, None, None, None)
        specs["xv"] = P(None, bdim, None, None, None)
        specs["enc_len"] = P(bdim)
    if cfg.block_pattern == "xlstm_7_1":
        # C:(G,7,B,H,P,P) n:(G,7,B,H,P) m:(G,7,B,H); H tiny -> shard P
        pm = "model" if _fits(cfg.d_model // cfg.n_heads, mesh, "model") else None
        specs["mlstm_c"] = P(None, None, bdim, None, pm, None)
        specs["mlstm_n"] = P(None, None, bdim, None, pm)
        specs["mlstm_m"] = P(None, None, bdim, None)
        specs["slstm"] = tuple(P(None, bdim, None, pm) for _ in range(4))
    if cfg.block_pattern == "zamba2":
        inner = cfg.ssm.expand * cfg.d_model
        h = inner // cfg.ssm.head_dim
        hm = "model" if _fits(h, mesh, "model") else None
        specs["mamba_h"] = P(None, None, bdim, hm, None, None)
        specs["mamba_conv"] = P(None, None, bdim, None, None)
        if cfg.n_layers % cfg.shared_attn_every:
            specs["tail_h"] = P(None, bdim, hm, None, None)
            specs["tail_conv"] = P(None, bdim, None, None)
        # shared attention caches: (n_groups, B, S, KH, dh) — same rank and
        # rule as the per-layer kv caches (leading axis = group, unsharded)
        specs["shared_k"] = kv
        specs["shared_v"] = kv
    return specs


def decode_token_spec(cfg, shape, mesh: Mesh):
    dp = dp_axes(mesh)
    bdim = dp if _fits(shape.global_batch, mesh, dp) else None
    return P(bdim, None)


def logits_spec(cfg, mesh: Mesh):
    return P(None, "model") if _fits(cfg.vocab_padded, mesh, "model") else P(None, None)
