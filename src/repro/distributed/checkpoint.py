"""Mesh-agnostic checkpointing: atomic step directories, resumable restore,
elastic re-shard on load (save under one mesh, restore under another).

Layout:
  <dir>/step_<N>/manifest.json   — tree structure + dtypes + shapes
  <dir>/step_<N>/arrays.npz      — flat leaves (host-gathered)
  <dir>/step_<N>/.complete       — commit marker (atomicity)

Host-gather keeps the implementation dependency-free (no orbax offline);
restore takes a target pytree of shardings and `jax.device_put`s each leaf,
so reload works under any mesh shape — the elasticity test shrinks 8 -> 4
devices. Async mode runs the serialisation on a worker thread so the step
loop is not blocked (fault tolerance: the marker file commits the step).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, blocking: bool = True):
    """Atomically save a pytree under step_<N>."""
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": l for i, l in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, ".complete"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, ".complete")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `target`; `shardings` (same pytree) puts
    each leaf on device with its sharding — works under a different mesh
    than the one that saved (elastic restart)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    if not os.path.exists(os.path.join(path, ".complete")):
        raise FileNotFoundError(f"incomplete checkpoint at {path}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(target)
    loaded = [data[f"leaf_{i}"] for i in range(len(leaves))]
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
        loaded = [jax.device_put(l, s) for l, s in zip(loaded, shard_leaves)]
    else:
        loaded = [jax.numpy.asarray(l) for l in loaded]
    return jax.tree_util.tree_unflatten(treedef, loaded)
