"""Training step with microbatched gradient accumulation, remat, optional
error-feedback gradient compression, and AdamW (ZeRO-1-shardable moments).

`make_train_step(cfg, train_cfg)` returns a pure `(state, batch) -> (state,
metrics)` suitable for jax.jit with in/out shardings; the microbatch loop is
a lax.scan so only one microbatch of activations is ever live (this is what
lets qwen3-32b train_4k fit v5e HBM — see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..distributed.compression import ErrorFeedback, compress_grads, error_feedback_init
from ..models import transformer as model_lib
from .optimizer import AdamWState, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    microbatches: int = 1
    remat: str = "full"            # none | full | dots
    compress_grads: bool = False   # error-feedback int8 (cross-pod wire fmt)
    max_grad_norm: float = 1.0
    weight_decay: float = 0.1


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Optional[ErrorFeedback]
    step: jax.Array


def init_state(params, tcfg: TrainCfg) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw_init(params),
        ef=error_feedback_init(params) if tcfg.compress_grads else None,
        step=jnp.zeros((), jnp.int32),
    )


def _split_microbatches(batch, n: int, mb_shardings=None):
    """(B, ...) -> (n, B/n, ...) for every leaf.

    Without the explicit constraint GSPMD moves the data-parallel sharding of
    the original batch axis onto the OUTER (scan) axis of the reshape,
    leaving every microbatch batch-replicated — a ~16x activation blow-up
    (EXPERIMENTS.md §Perf iter 1). `mb_shardings` pins the inner batch axis.
    """
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    out = jax.tree.map(split, batch)
    if mb_shardings is not None:
        out = jax.tree.map(jax.lax.with_sharding_constraint, out, mb_shardings)
    return out


def make_train_step(cfg, tcfg: TrainCfg, *, acc_shardings=None, mb_shardings=None,
                    param_shardings=None):
    """acc_shardings: optional pytree of NamedShardings (ZeRO layout) for the
    f32 microbatch gradient accumulator AND the optimizer math: params are
    sliced into this layout before the AdamW update (free: replicated->shard)
    so every optimizer op is local, and only the final bf16 params are
    all-gathered back to `param_shardings`. Without this GSPMD resolves the
    mixed-sharding elementwise ops by full f32 replication (~33 GB/tensor on
    qwen3-32b). mb_shardings: per-microbatch batch shardings (see
    _split_microbatches). All three are EXPERIMENTS.md §Perf iteration 1."""
    lr = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)

    def loss_for(params, mb):
        loss, metrics = model_lib.loss_fn(params, cfg, mb, remat=tcfg.remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def constrain(tree):
        if acc_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, acc_shardings)

    def train_step(state: TrainState, batch):
        if tcfg.microbatches > 1:
            mbs = _split_microbatches(batch, tcfg.microbatches, mb_shardings)

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = grad_fn(state.params, mb)
                # reduce into the ZeRO layout: constraining g BEFORE the add
                # turns the backward's data-axis all-reduce into a
                # reduce-scatter and keeps the += fully local
                g = constrain(jax.tree.map(lambda gi: gi.astype(jnp.float32), g))
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, loss_acc + loss), None

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (g_sum, loss_sum), _ = model_lib._scan(acc_body, (zeros, jnp.float32(0.0)), mbs)
            grads = constrain(jax.tree.map(lambda g: g / tcfg.microbatches, g_sum))
            loss = loss_sum / tcfg.microbatches
        else:
            (loss, _), grads = grad_fn(state.params, batch)
            grads = constrain(jax.tree.map(lambda g: g.astype(jnp.float32), grads))

        ef = state.ef
        if tcfg.compress_grads:
            grads, ef = compress_grads(grads, ef)

        params_in = state.params
        if acc_shardings is not None:
            # slice params into the ZeRO layout (local), update there
            params_in = jax.tree.map(jax.lax.with_sharding_constraint,
                                     state.params, acc_shardings)
        params, opt, gnorm = adamw_update(
            grads, state.opt, params_in, lr=lr,
            weight_decay=tcfg.weight_decay, max_grad_norm=tcfg.max_grad_norm,
        )
        if acc_shardings is not None and param_shardings is not None:
            # all-gather the bf16 result back to the compute layout
            params = jax.tree.map(jax.lax.with_sharding_constraint,
                                  params, param_shardings)
        new_state = TrainState(params=params, opt=opt, ef=ef, step=state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr(opt.count)}

    return train_step
