"""AdamW + LR schedules in pure JAX (no optax in this container).

Moments can carry their own (ZeRO-1) shardings — the trainer passes
`zero1_specs` so each data-parallel rank owns a slice of the optimizer
state; XLA inserts the reduce-scatter/all-gather pair automatically from the
sharding mismatch between grads and moments.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: jax.Array | dict
    nu: jax.Array | dict
    count: jax.Array


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros), count=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(
    grads, state: AdamWState, params,
    *, lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1, max_grad_norm: float = 1.0,
):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    count = state.count + 1
    lr_t = lr(count) if callable(lr) else lr

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / (1 - b1 ** count.astype(jnp.float32))
        nu_hat = nu / (1 - b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + eps)
        decay = weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr_t * (step + decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    return new_p, AdamWState(mu=new_mu, nu=new_nu, count=count), gnorm
