"""Pallas TPU kernel: flash-decode attention (one query token, long KV cache).

Used by `serve_step` for the decode_32k / long_500k cells and by zamba2's
shared attention block at 524k context. GQA layout: queries are grouped per
KV head — q (B, KH, G, dh) attends K/V (B, S, KH, dh).

Grid (B, KH, S/bS) with the sequence axis innermost; online softmax state
(running max m, normalizer l) and the output accumulator live in the
revisited output block plus two VMEM scratch tiles, so the KV cache streams
HBM->VMEM exactly once — the kernel is memory-bound by design and its
roofline is the HBM term (S*KH*dh*2 bytes/token).

Length masking comes from a per-batch `cache_len` scalar so one compiled
kernel serves ragged batches (continuous batching in serve/engine.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, block_s: int, scale: float):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)        # (G, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)     # (bS, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)     # (bS, dh)
    cache_len = len_ref[0, 0]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (G, bS)
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < cache_len, scores, NEG_INF)

    m_prev = m_ref[:, :1]                       # (G, 1)
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                 # (G, bS)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[:, :1] = m_new
    l_ref[:, :1] = l_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cache_len: jax.Array,
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """One-token GQA attention against a long KV cache.

    q: (B, KH, G, dh); k, v: (B, S, KH, dh); cache_len: (B,) int32 — valid
    prefix length per sequence. Returns (B, KH, G, dh).
    """
    b, kh, g, dh = q.shape
    s = k.shape[1]
    scale = 1.0 / (dh ** 0.5)
    block_s = min(block_s, s)
    sp = -(-s // block_s) * block_s
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    lens = cache_len.astype(jnp.int32).reshape(b, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, scale=scale),
        grid=(b, kh, sp // block_s),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, hi, si: (bi, 0)),
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda bi, hi, si: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q, k, v)
    return out
