"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute with ``interpret=True`` (the body
runs as traced jnp — bit-exact semantics, validated against ref.py); on a
TPU backend the same calls lower to Mosaic. ``use_pallas=False`` routes to
the pure-jnp oracle, which is what the dry-run lowers (compact HLO; the
kernels are the TPU production path — see DESIGN.md §5).

For the search hot path `mips_score` also accepts ``use_pallas=None``
(backend-aware default): Pallas on TPU, the jnp oracle elsewhere —
interpret mode is a correctness vehicle, an order of magnitude slower than
the oracle on CPU, so production callers should not pay for it off-TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .binary_probe import binary_probe_lb as _binary_probe_pallas
from .block_mips import MAX_K as BLOCK_MIPS_MAX_K
from .block_mips import block_mips as _block_mips_pallas
from .block_mips import sketch_scores as _sketch_scores_pallas
from .decode_attention import decode_attention as _decode_attention_pallas
from .mips_topk import mips_score as _mips_score_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(use_pallas: Optional[bool]) -> bool:
    return (jax.default_backend() == "tpu") if use_pallas is None else use_pallas


def mips_score(x, q, valid, *, use_pallas: Optional[bool] = None, **block_kwargs):
    if not _resolve(use_pallas):
        return ref.mips_score_ref(x, q, valid)
    return _mips_score_pallas(x, q, valid, interpret=_interpret(), **block_kwargs)


def block_mips(x, valid, q, slots, sel, init_scores, init_rows, c_half, *,
               k: int, page_rows: int, dense: bool = False,
               use_pallas: Optional[bool] = None):
    """Fused block-sparse verification round (the two-phase hot path).

    Walks ``slots`` pages of ``x`` in place and returns (top_scores (B, k),
    top_rows (B, k), cnt (B, NS), pages (B,), cand (B,)) — see
    `block_mips.block_mips`.  Backend-aware default like `mips_score`;
    ``k > BLOCK_MIPS_MAX_K`` (streaming over-fetch) always takes the oracle,
    whose VMEM-free merge has no k cap.
    """
    if not _resolve(use_pallas) or k > BLOCK_MIPS_MAX_K:
        return ref.block_mips_ref(x, valid, q, slots, sel, init_scores,
                                  init_rows, c_half, k=k, page_rows=page_rows,
                                  dense=dense)
    return _block_mips_pallas(x, valid, q, slots, sel, init_scores, init_rows,
                              c_half, k=k, page_rows=page_rows,
                              interpret=_interpret())


def sketch_scores(q, sk_mu, codebooks, codes, *,
                  use_pallas: Optional[bool] = None):
    """Estimated block scores for the verification prefilter: (B, NB) with
    est[b, n] = <q_b, decoded block centroid n>.

    Backend-aware like `mips_score`: on TPU the Pallas kernel scores the
    VMEM-resident PQ codes through a per-query LUT (the codebooks + codes
    are ~65x smaller than the decoded centroids, so they stay resident); the
    oracle is one GEMM over the decoded ``sk_mu``, which XLA CPU executes
    two orders of magnitude faster than gather-based LUT accumulation. The
    two paths sum identical subspace products in different orders, so they
    agree to float tolerance rather than bitwise (the prefilter consumes
    est through an eps-scaled error band, which dominates that slack).
    """
    if not _resolve(use_pallas):
        return ref.sketch_scores_ref(q, sk_mu)
    return _sketch_scores_pallas(q, codebooks, codes, interpret=_interpret())


def block_mips_cached(scores_full, valid, slots, sel, init_scores, init_rows,
                      c_half, *, k: int, page_rows: int):
    """Oracle-only compensation round over a cached (B, n_pad) score matrix
    (see `ref.block_mips_cached_ref`). The fused driver uses it when the
    previous round already scored the whole corpus in place — zero new dot
    products; on TPU the kernel streams pages instead, so there is no
    Pallas variant."""
    return ref.block_mips_cached_ref(scores_full, valid, slots, sel,
                                     init_scores, init_rows, c_half,
                                     k=k, page_rows=page_rows)


def mips_topk(x, q, valid, k: int, *, use_pallas: Optional[bool] = None,
              page_rows: int = 32, **block_kwargs):
    """Fused verification scan + top-k: returns (scores (B,k), rows (B,k)).

    Backend-aware default (``use_pallas=None`` => Pallas on TPU, jnp oracle
    elsewhere — previously this defaulted to True, silently putting off-TPU
    callers on interpret mode while `mips_score` did not). On the Pallas
    path the scan is routed through the fused `block_mips` kernel: the
    corpus is walked ``page_rows`` rows at a time with a streaming top-k,
    so no (R, B) score matrix is materialized. ``page_rows`` is kept small
    because the kernel's rank-select holds (B, k+page_rows)^2 comparison
    cubes in VMEM. On the fused route rows with fewer than k valid
    candidates come back as -1 with -inf scores; `mips_score` ``block_*``
    kwargs are score-matrix tile sizes, so passing any routes through the
    score+`lax.top_k` pair instead (there they keep their meaning —
    empty slots are then NEG_INF with arbitrary rows, as before this PR).
    """
    if _resolve(use_pallas) and k <= BLOCK_MIPS_MAX_K and not block_kwargs:
        r, d = x.shape
        b = q.shape[0]
        rp = -(-r // page_rows) * page_rows
        xpad = jnp.pad(x, ((0, rp - r), (0, 0)))
        vpad = jnp.pad(valid.astype(jnp.int32), (0, rp - r))
        n_blocks = rp // page_rows
        slots = jnp.arange(n_blocks, dtype=jnp.int32)
        sel = jnp.ones((b, n_blocks), jnp.int32)
        init_s = jnp.full((b, k), -jnp.inf, jnp.float32)
        init_r = jnp.full((b, k), -1, jnp.int32)
        # c_half above any score => cnt never trips the Condition-A stop and
        # every selected page stays live: a plain full-corpus top-k scan.
        c_half = jnp.full((b,), jnp.finfo(jnp.float32).max)
        top, rows, _, _, _ = _block_mips_pallas(
            xpad, vpad, q, slots, sel, init_s, init_r, c_half,
            k=k, page_rows=page_rows, interpret=_interpret())
        return top, rows
    scores = mips_score(x, q, valid, use_pallas=use_pallas, **block_kwargs)  # (R, B)
    top, idx = jax.lax.top_k(scores.T, k)  # (B, k)
    return top, idx


def binary_probe_lb(codes, q_code, q_proj, *, use_pallas: bool = True, **block_kwargs):
    if not use_pallas:
        return ref.binary_probe_lb_ref(codes, q_code, q_proj)
    return _binary_probe_pallas(codes, q_code, q_proj, interpret=_interpret(), **block_kwargs)


def decode_attention(q, k, v, cache_len, *, use_pallas: bool = True, **block_kwargs):
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, cache_len)
    return _decode_attention_pallas(q, k, v, cache_len, interpret=_interpret(), **block_kwargs)
