"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute with ``interpret=True`` (the body
runs as traced jnp — bit-exact semantics, validated against ref.py); on a
TPU backend the same calls lower to Mosaic. ``use_pallas=False`` routes to
the pure-jnp oracle, which is what the dry-run lowers (compact HLO; the
kernels are the TPU production path — see DESIGN.md §5).

For the search hot path `mips_score` also accepts ``use_pallas=None``
(backend-aware default): Pallas on TPU, the jnp oracle elsewhere —
interpret mode is a correctness vehicle, an order of magnitude slower than
the oracle on CPU, so production callers should not pay for it off-TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .binary_probe import binary_probe_lb as _binary_probe_pallas
from .decode_attention import decode_attention as _decode_attention_pallas
from .mips_topk import mips_score as _mips_score_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def mips_score(x, q, valid, *, use_pallas: Optional[bool] = None, **block_kwargs):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.mips_score_ref(x, q, valid)
    return _mips_score_pallas(x, q, valid, interpret=_interpret(), **block_kwargs)


def mips_topk(x, q, valid, k: int, *, use_pallas: bool = True, **block_kwargs):
    """Fused verification scan + top-k: returns (scores (B,k), rows (B,k))."""
    scores = mips_score(x, q, valid, use_pallas=use_pallas, **block_kwargs)  # (R, B)
    top, idx = jax.lax.top_k(scores.T, k)  # (B, k)
    return top, idx


def binary_probe_lb(codes, q_code, q_proj, *, use_pallas: bool = True, **block_kwargs):
    if not use_pallas:
        return ref.binary_probe_lb_ref(codes, q_code, q_proj)
    return _binary_probe_pallas(codes, q_code, q_proj, interpret=_interpret(), **block_kwargs)


def decode_attention(q, k, v, cache_len, *, use_pallas: bool = True, **block_kwargs):
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, cache_len)
    return _decode_attention_pallas(q, k, v, cache_len, interpret=_interpret(), **block_kwargs)
