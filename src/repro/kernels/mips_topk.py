"""Pallas TPU kernel: ProMIPS candidate verification scan (the search hot spot).

Computes inner products between candidate rows and a query batch with fused
validity masking — ``scores[r, b] = <x[r], q[b]>`` or -inf for padding rows —
as a VMEM-tiled, output-stationary matmul: grid (rows/bR, batch/bB, d/bD)
with the contraction dimension innermost, accumulating in the f32 output
block (revisited across the d grid axis), MXU-shaped tiles (multiples of
8x128 lanes; bD a multiple of 128).

>90% of a ProMIPS query's FLOPs are this scan (beta*n*d per query — paper
SectionVII); the same kernel serves the exact-MIPS baseline (full corpus scan)
and the approximate-logits path in `serve/`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(x_ref, q_ref, valid_ref, o_ref, *, n_d_tiles: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)   # (bR, bD)
    q = q_ref[...].astype(jnp.float32)   # (bB, bD)
    o_ref[...] += jax.lax.dot_general(
        x, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_d_tiles - 1)
    def _mask():
        valid = valid_ref[...] > 0  # (bR, 1)
        o_ref[...] = jnp.where(valid, o_ref[...], NEG_INF)


@functools.partial(jax.jit, static_argnames=("block_r", "block_b", "block_d", "interpret"))
def mips_score(
    x: jax.Array,
    q: jax.Array,
    valid: jax.Array,
    *,
    block_r: int = 256,
    block_b: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """scores = x @ q.T with -inf on invalid rows.

    x: (R, D) candidate rows; q: (B, D) queries; valid: (R,) bool/int.
    R, B, D are padded up to tile multiples internally. Returns (R, B) f32.
    """
    r, d = x.shape
    b = q.shape[0]
    block_r = min(block_r, max(8, r))
    block_b = min(block_b, max(8, b))
    block_d = min(block_d, max(128, 128))
    rp = -(-r // block_r) * block_r
    bp = -(-b // block_b) * block_b
    dp = -(-d // block_d) * block_d
    xpad = jnp.pad(x, ((0, rp - r), (0, dp - d)))
    qpad = jnp.pad(q, ((0, bp - b), (0, dp - d)))
    vpad = jnp.pad(valid.astype(jnp.int32), (0, rp - r)).reshape(rp, 1)
    n_d_tiles = dp // block_d

    out = pl.pallas_call(
        functools.partial(_kernel, n_d_tiles=n_d_tiles),
        grid=(rp // block_r, bp // block_b, n_d_tiles),
        in_specs=[
            pl.BlockSpec((block_r, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_b, block_d), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_r, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_b), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, bp), jnp.float32),
        interpret=interpret,
    )(xpad, qpad, vpad)
    return out[:r, :b]
