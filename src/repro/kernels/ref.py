"""Pure-jnp oracles for every Pallas kernel (the ground truth the shape/dtype
sweep tests assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mips_score_ref(x: jax.Array, q: jax.Array, valid: jax.Array) -> jax.Array:
    """scores = x @ q.T, -inf on invalid rows. x:(R,D) q:(B,D) valid:(R,)."""
    scores = x.astype(jnp.float32) @ q.astype(jnp.float32).T
    return jnp.where(valid.astype(bool)[:, None], scores, NEG_INF)


def binary_probe_lb_ref(codes: jax.Array, q_code: jax.Array, q_proj: jax.Array) -> jax.Array:
    """Theorem-3 group lower bounds. codes:(G,) q_code:() q_proj:(m,)."""
    m = q_proj.shape[0]
    shifts = jnp.arange(m, dtype=jnp.uint32)
    bits = (((codes[:, None] ^ q_code) >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    return bits @ jnp.abs(q_proj).astype(jnp.float32) / jnp.sqrt(jnp.float32(m))


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, cache_len: jax.Array) -> jax.Array:
    """Naive softmax decode attention. q:(B,KH,G,dh) k,v:(B,S,KH,dh) len:(B,)."""
    b, kh, g, dh = q.shape
    s = k.shape[1]
    scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, None, None, :] < cache_len[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
