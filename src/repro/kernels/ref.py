"""Pure-jnp oracles for every Pallas kernel (the ground truth the shape/dtype
sweep tests assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mips_score_ref(x: jax.Array, q: jax.Array, valid: jax.Array) -> jax.Array:
    """scores = x @ q.T, -inf on invalid rows. x:(R,D) q:(B,D) valid:(R,)."""
    scores = x.astype(jnp.float32) @ q.astype(jnp.float32).T
    return jnp.where(valid.astype(bool)[:, None], scores, NEG_INF)


def block_mips_ref(x, valid, q, slots, sel, init_scores, init_rows, c_half,
                   *, k: int, page_rows: int, dense: bool = False):
    """Oracle for `block_mips.block_mips`: one fused verification round.

    Same contract (see the kernel docstring); this is also the production
    path off-TPU, so it is written to touch the minimum of full-width
    arrays — one (B, R) score matrix, the >=-threshold test and the live
    row mask — instead of the old batched path's seven (DESIGN.md §10).
    ``dense=True`` promises ``slots == arange(n_blocks)`` so the row gather
    is skipped and ``x`` is scored in place.
    """
    n_slots = sel.shape[1]
    if dense:
        xt, rvalid = x, valid.astype(bool)
        rows_flat = jnp.arange(n_slots * page_rows, dtype=jnp.int32)
    else:
        rows_flat = (slots.astype(jnp.int32)[:, None] * page_rows
                     + jnp.arange(page_rows, dtype=jnp.int32)).reshape(-1)
        # page-granular gather (4-KB contiguous slices) — markedly cheaper
        # on CPU than a row gather, and the access the TPU kernel's per-page
        # DMA performs anyway
        xt = jnp.take(x.reshape(-1, page_rows, x.shape[1]), slots,
                      axis=0).reshape(-1, x.shape[1])
        rvalid = jnp.take(valid.reshape(-1, page_rows), slots,
                          axis=0).reshape(-1).astype(bool)
    # (R, d) @ (d, B) then transpose — the same orientation as
    # `mips_score_ref` (the batched backend's kernel), which the CPU GEMM
    # executes measurably faster than (B, d) @ (d, R) at R >> B; per-element
    # dots are the identical reduction, so results are unchanged
    scores = (xt.astype(jnp.float32)
              @ q.astype(jnp.float32).T).T                   # (B, R)
    return _verify_core(scores, rvalid, sel, init_scores, init_rows, c_half,
                        rows_flat, k=k, page_rows=page_rows)


def block_mips_cached_ref(scores_full, valid, slots, sel, init_scores,
                          init_rows, c_half, *, k: int, page_rows: int):
    """Compensation-round oracle over CACHED scores: when the previous round
    scored the whole corpus in place (dense tile), this round's slots are a
    subset of already-computed dot products — slice them out of the
    (B, n_pad) matrix instead of gathering rows and re-running the matmul.
    Bit-identical accounting to `block_mips_ref` over the same slots (the
    scores themselves come from the identical full-matrix matmul)."""
    rows_flat = (slots.astype(jnp.int32)[:, None] * page_rows
                 + jnp.arange(page_rows, dtype=jnp.int32)).reshape(-1)
    scores = jnp.take(scores_full, rows_flat, axis=1)        # (B, R)
    rvalid = jnp.take(valid.reshape(-1, page_rows), slots,
                      axis=0).reshape(-1).astype(bool)
    return _verify_core(scores, rvalid, sel, init_scores, init_rows, c_half,
                        rows_flat, k=k, page_rows=page_rows)


def _verify_core(scores, rvalid, sel, init_scores, init_rows, c_half,
                 rows_flat, *, k: int, page_rows: int):
    """Shared Condition-A accounting + streaming-equivalent top-k merge over
    a (B, R) score tile (see `block_mips_ref`)."""
    b, r = scores.shape
    n_slots = r // page_rows
    sel = sel.astype(bool)
    ge = (scores >= c_half[:, None]) & rvalid[None, :]       # (B, R)
    cnt = (ge.reshape(b, n_slots, page_rows).sum(axis=2).astype(jnp.int32)
           * sel.astype(jnp.int32))                          # (B, NS)
    n0 = jnp.sum(init_scores >= c_half[:, None], axis=1)     # carried-in hits
    # f32 running sum: exact (total hits << 2^24) and much cheaper than the
    # int32 scan XLA CPU emits for integer cumsum
    ex_cum = (jnp.cumsum(cnt.astype(jnp.float32), axis=1)
              - cnt).astype(jnp.int32)                       # exclusive cumsum
    live = sel & ((n0[:, None] + ex_cum) < k)                # ~done_before
    pages = jnp.sum(live.astype(jnp.int32), axis=1)
    vcnt = rvalid.reshape(n_slots, page_rows).sum(axis=1).astype(jnp.int32)
    cand = jnp.sum(live.astype(jnp.int32) * vcnt[None, :], axis=1)

    row_live = (live[:, :, None] & rvalid.reshape(1, n_slots, page_rows))
    masked = jnp.where(row_live.reshape(b, -1), scores, -jnp.inf)  # (B, R)
    tile_s, idx = jax.lax.top_k(masked, min(k, masked.shape[1]))
    tile_r = jnp.where(tile_s > -jnp.inf,
                       jnp.take(rows_flat, idx), -1).astype(jnp.int32)
    # Merge with the carried top-k: concat carried-first + top_k reproduces
    # the "ties to the lower index, carried entries first" rule, so the
    # result is bit-identical to one top_k over [carried, all tile rows].
    merged_s = jnp.concatenate([init_scores, tile_s], axis=1)
    merged_r = jnp.concatenate([init_rows.astype(jnp.int32), tile_r], axis=1)
    top_s, pos = jax.lax.top_k(merged_s, k)
    top_r = jnp.take_along_axis(merged_r, pos, axis=1)
    return top_s, top_r, cnt, pages, cand


def sketch_scores_ref(q: jax.Array, sk_mu: jax.Array) -> jax.Array:
    """Oracle for `block_mips.sketch_scores`: estimated block scores from the
    DECODED sketch centroids. q:(B,D) sk_mu:(NB,D) -> (B,NB).

    One GEMM over the decoded centroids — on CPU this beats the per-subspace
    LUT gathers the Pallas kernel performs by two orders of magnitude (XLA
    CPU lowers the (B, NB) gather accumulation to scalar loads). The kernel
    computes the same per-entry dot product as sum of subspace LUT entries;
    results agree to float-associativity tolerance, not bitwise.
    """
    return q.astype(jnp.float32) @ sk_mu.astype(jnp.float32).T


def binary_probe_lb_ref(codes: jax.Array, q_code: jax.Array, q_proj: jax.Array) -> jax.Array:
    """Theorem-3 group lower bounds. codes:(G,) q_code:() q_proj:(m,)."""
    m = q_proj.shape[0]
    shifts = jnp.arange(m, dtype=jnp.uint32)
    bits = (((codes[:, None] ^ q_code) >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    return bits @ jnp.abs(q_proj).astype(jnp.float32) / jnp.sqrt(jnp.float32(m))


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, cache_len: jax.Array) -> jax.Array:
    """Naive softmax decode attention. q:(B,KH,G,dh) k,v:(B,S,KH,dh) len:(B,)."""
    b, kh, g, dh = q.shape
    s = k.shape[1]
    scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, None, None, :] < cache_len[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
