"""Pallas TPU kernel: fused block-sparse ProMIPS verification.

The two-phase runtime's old "batched" backend gathers the union of every
query's selected blocks into one dense (R, d) tile (`jnp.take`), scores it,
then rebuilds the sequential Condition-A semantics from a (B, R) score
matrix plus five same-shape boolean intermediates (DESIGN.md §10 has the
traffic math).  This kernel removes ALL of that: the grid walks the selected
blocks of ``x`` **in place** in the paged layout — a scalar-prefetched slot
list steers each grid step's DMA straight at one 4-KB page of ``x`` in HBM,
so no gathered tile and no (B, R) intermediates ever exist.  Per step it

  1. scores one page against the whole query batch (one small MXU matmul),
  2. emits that slot's per-query >=-threshold hit count (``cnt``),
  3. updates the carried per-query hit total ``h`` (VMEM scratch) — a block
     is *live* iff the query selected it and ``h < k`` (the exact
     sequential-scan Condition-A stop: "at least k rows scoring >=
     threshold in earlier blocks" <=> "running k-th best >= threshold"),
  4. accumulates the logical page / candidate counts for live blocks, and
  5. merges the page's live rows into a per-query streaming top-k via a
     rank-select (stable descending order, ties to the lower index — the
     same rule as `jax.lax.top_k` and `search_common.topk_merge`, so the
     streamed result is bit-identical to one global top-k).

Grid steps run in layout (ascending block) order, which both preserves the
sequential-scan semantics and matches the coalesced HBM read pattern the
iDistance layout was designed for.

Shapes: one page per grid step, so the x block is (page_rows, d).  On a
real TPU, d should be a multiple of 128 lanes for full-speed tiles (the
compiler pads otherwise); the rank-select holds a (B, k + page_rows)^2
comparison cube in VMEM, so ``k`` is capped at `MAX_K` (= 128) —
`ops.block_mips` falls back to the jnp oracle beyond that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Streaming-top-k merge cube is (B, k+page_rows, k+page_rows) in VMEM; cap k
# so it stays well under the ~16 MB budget (see ops.block_mips fallback).
MAX_K = 128


def _rank_topk(comb_s, comb_r, k: int):
    """Stable descending top-k of ``comb_s`` (B, J) with ties to the lower
    index — bit-compatible with `jax.lax.top_k` — via a rank-select that
    needs no sort primitive (Mosaic-friendly: compares + one-hot sums)."""
    j = comb_s.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, (j, j), 0)   # j' (compared-to)
    row = jax.lax.broadcasted_iota(jnp.int32, (j, j), 1)   # j  (ranked elem)
    gt = comb_s[:, :, None] < comb_s[:, None, :]           # s[j'] > s[j]
    tie = (comb_s[:, :, None] == comb_s[:, None, :]) & (col < row)[None]
    rank = jnp.sum((gt | tie).astype(jnp.int32), axis=2)   # (B, J), a perm
    slot = jax.lax.broadcasted_iota(jnp.int32, (j, k), 1)[None]
    hit = rank[:, :, None] == slot                          # (B, J, k)
    top_s = jnp.sum(jnp.where(hit, comb_s[:, :, None], 0.0), axis=1)
    top_r = jnp.sum(jnp.where(hit, comb_r[:, :, None], 0), axis=1)
    return top_s, top_r


def _kernel(slots_ref, x_ref, valid_ref, q_ref, sel_ref, chalf_ref,
            inits_ref, initr_ref,
            tops_ref, topr_ref, cnt_ref, pages_ref, cand_ref,
            h_ref, *, k: int, page_rows: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        tops_ref[...] = inits_ref[...]
        topr_ref[...] = initr_ref[...]
        h_ref[...] = jnp.sum(
            (inits_ref[...] >= chalf_ref[...]).astype(jnp.int32),
            axis=1, keepdims=True)
        pages_ref[...] = jnp.zeros_like(pages_ref)
        cand_ref[...] = jnp.zeros_like(cand_ref)

    x = x_ref[...].astype(jnp.float32)                     # (P, d) — one page
    q = q_ref[...].astype(jnp.float32)                     # (B, d)
    scores = jax.lax.dot_general(                          # (P, B)
        x, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    valid = valid_ref[...] > 0                             # (P, 1)
    sel = sel_ref[...] > 0                                 # (B, 1)
    c_half = chalf_ref[...]                                # (B, 1)
    h = h_ref[...]                                         # (B, 1)

    # Per-slot >=-threshold hit count (in SELECTED blocks; the carried h is
    # n0 + the running cumsum, so "h < k" is exactly ~done_before).
    ge = (scores >= c_half[:, 0][None, :]) & valid         # (P, B)
    cnt = (jnp.sum(ge.astype(jnp.int32), axis=0)[:, None]
           * sel.astype(jnp.int32))                        # (B, 1)
    cnt_ref[...] = cnt

    live = sel & (h < k)                                   # (B, 1)
    pages_ref[...] += live.astype(jnp.int32)
    n_valid = jnp.sum(valid.astype(jnp.int32))
    cand_ref[...] += live.astype(jnp.int32) * n_valid
    h_ref[...] = h + cnt

    # Streaming top-k over this page's live rows.
    rowid = (slots_ref[i] * page_rows
             + jax.lax.broadcasted_iota(jnp.int32, (page_rows, 1), 0))
    mask = valid & live[:, 0][None, :]                     # (P, B)
    masked = jnp.where(mask, scores, -jnp.inf)
    rows = jnp.where(mask, rowid, -1)                      # (P, B) bcast rowid
    comb_s = jnp.concatenate([tops_ref[...], masked.T], axis=1)  # (B, k+P)
    comb_r = jnp.concatenate([topr_ref[...], rows.T], axis=1)
    top_s, top_r = _rank_topk(comb_s, comb_r, k)
    tops_ref[...] = top_s
    topr_ref[...] = top_r


# Sketch-scoring grid walks the code table this many blocks per step; the
# per-step working set (codes tile + LUT + one-hot expansion) stays well
# inside VMEM at B = 64, K = 256.
SKETCH_TILE = 512


def _sketch_kernel(codes_ref, lut_ref, est_ref, *, n_codewords: int):
    """One grid step of asymmetric LUT scoring: est[b, t] = sum_s
    lut[b, s, codes[t, s]]. The gather is expressed as a one-hot matmul so
    it lowers to MXU dot_generals (Mosaic has no vector-gather primitive)."""
    codes = codes_ref[...]                                 # (T, M)
    lut = lut_ref[...]                                     # (B, M, K)
    t, m = codes.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (t, n_codewords), 1)
    est = jnp.zeros((lut.shape[0], t), jnp.float32)
    for s in range(m):
        onehot = (codes[:, s][:, None] == iota).astype(jnp.float32)  # (T, K)
        est = est + jax.lax.dot_general(                   # (B, T)
            lut[:, s, :], onehot, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    est_ref[...] = est


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def sketch_scores(
    q: jax.Array,
    codebooks: jax.Array,
    codes: jax.Array,
    *,
    interpret: bool = False,
    tile: int = SKETCH_TILE,
):
    """Estimated block scores from the VMEM-resident PQ sketch.

    q: (B, d); codebooks: (M, K, d/M); codes: (NB, M) int — returns
    (B, NB) float32 est with est[b, n] = <q_b, decode(codes[n])>, computed
    asymmetrically: a per-query LUT of subspace dot products
    (lut[b, s, k] = <q_b[s], codebook[s, k]>) built once outside the grid,
    then accumulated per code. Numerically this sums the same subspace
    products as `ref.sketch_scores_ref`'s decoded-centroid GEMM in a
    different order — parity holds to float tolerance, not bitwise.
    """
    b, d = q.shape
    m, kcb, sub_d = codebooks.shape
    assert d == m * sub_d, (d, m, sub_d)
    nb = codes.shape[0]
    lut = jnp.einsum("bms,mks->bmk", q.reshape(b, m, sub_d).astype(jnp.float32),
                     codebooks.astype(jnp.float32))        # (B, M, K)
    nb_pad = -(-nb // tile) * tile
    codes_p = jnp.pad(codes.astype(jnp.int32), ((0, nb_pad - nb), (0, 0)))
    est = pl.pallas_call(
        functools.partial(_sketch_kernel, n_codewords=kcb),
        grid=(nb_pad // tile,),
        in_specs=[
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
            pl.BlockSpec((b, m, kcb), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, nb_pad), jnp.float32),
        interpret=interpret,
    )(codes_p, lut)
    return est[:, :nb]


@functools.partial(jax.jit, static_argnames=("k", "page_rows", "interpret"))
def block_mips(
    x: jax.Array,
    valid: jax.Array,
    q: jax.Array,
    slots: jax.Array,
    sel: jax.Array,
    init_scores: jax.Array,
    init_rows: jax.Array,
    c_half: jax.Array,
    *,
    k: int,
    page_rows: int,
    interpret: bool = False,
):
    """Fused block-sparse verification round over the paged layout.

    x: (n_pad, d) rows in paged layout; valid: (n_pad,) bool/int (id >= 0);
    q: (B, d); slots: (NS,) int32 block ids to walk, ascending layout order
    (padding slots allowed — their ``sel`` column must be False);
    sel: (B, NS) per-query selection; init_scores/init_rows: (B, k) carried
    top-k, descending (-inf / -1 empties); c_half: (B,) Condition-A
    thresholds.

    Returns (top_scores (B, k), top_rows (B, k) i32, cnt (B, NS) i32,
    pages (B,) i32, cand (B,) i32).  Semantics are exactly one
    `search_device._verify_batched` round restricted to ``slots`` — the
    parity contract `ref.block_mips_ref` pins down.
    """
    assert k <= MAX_K, f"block_mips supports k <= {MAX_K}, got {k}"
    n_slots = slots.shape[0]
    b = q.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_slots,),
        in_specs=[
            pl.BlockSpec((page_rows, x.shape[1]), lambda i, s: (s[i], 0)),
            pl.BlockSpec((page_rows, 1), lambda i, s: (s[i], 0)),
            pl.BlockSpec((b, q.shape[1]), lambda i, s: (0, 0)),
            pl.BlockSpec((b, 1), lambda i, s: (0, i)),
            pl.BlockSpec((b, 1), lambda i, s: (0, 0)),
            pl.BlockSpec((b, k), lambda i, s: (0, 0)),
            pl.BlockSpec((b, k), lambda i, s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda i, s: (0, 0)),
            pl.BlockSpec((b, k), lambda i, s: (0, 0)),
            pl.BlockSpec((b, 1), lambda i, s: (0, i)),
            pl.BlockSpec((b, 1), lambda i, s: (0, 0)),
            pl.BlockSpec((b, 1), lambda i, s: (0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((b, 1), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, k=k, page_rows=page_rows),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, n_slots), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(slots.astype(jnp.int32),
      x,
      valid.astype(jnp.int32).reshape(-1, 1),
      q,
      sel.astype(jnp.int32),
      c_half.astype(jnp.float32).reshape(-1, 1),
      init_scores.astype(jnp.float32),
      init_rows.astype(jnp.int32))
    top_s, top_r, cnt, pages, cand = out
    return top_s, top_r, cnt, pages[:, 0], cand[:, 0]
