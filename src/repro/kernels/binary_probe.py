"""Pallas TPU kernel: Quick-Probe group lower bounds (paper Theorem 3).

For every sign-code group g:  LB_g = (1/sqrt(m)) * sum_i bit_i(code_g ^ code_q) * |P_i(q)|.

The group table has up to 2^m entries; the kernel tiles it over the grid and
evaluates the XOR + per-bit weighted accumulation entirely in VMEM. The bit
loop is a static unroll (m <= 30) of shift/AND/FMA — VPU-friendly, no MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(codes_ref, qcode_ref, qabs_ref, o_ref, *, m: int):
    codes = codes_ref[...]          # (bG, 1) uint32
    qcode = qcode_ref[0, 0]         # scalar uint32
    x = codes ^ qcode
    acc = jnp.zeros(codes.shape, jnp.float32)
    for i in range(m):              # static unroll, m <= 30
        bit = ((x >> jnp.uint32(i)) & jnp.uint32(1)).astype(jnp.float32)
        acc += bit * qabs_ref[0, i]
    o_ref[...] = acc * (1.0 / (m ** 0.5))


@functools.partial(jax.jit, static_argnames=("block_g", "interpret"))
def binary_probe_lb(
    codes: jax.Array,
    q_code: jax.Array,
    q_proj: jax.Array,
    *,
    block_g: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Theorem-3 lower bounds for all groups. codes: (G,) uint32,
    q_code: scalar uint32, q_proj: (m,) f32. Returns (G,) f32."""
    g = codes.shape[0]
    m = q_proj.shape[0]
    block_g = min(block_g, max(8, g))
    gp = -(-g // block_g) * block_g
    cpad = jnp.pad(codes, (0, gp - g)).reshape(gp, 1)
    qabs = jnp.abs(q_proj).astype(jnp.float32).reshape(1, m)
    qc = q_code.astype(jnp.uint32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, m=m),
        grid=(gp // block_g,),
        in_specs=[
            pl.BlockSpec((block_g, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_g, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, 1), jnp.float32),
        interpret=interpret,
    )(cpad, qc, qabs)
    return out[:g, 0]
