"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1), no FFN (d_ff=0).
[arXiv:2405.04517; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    block_pattern="xlstm_7_1",
    source="arXiv:2405.04517",
)
