"""internvl2-2b — InternViT patch-embed stub + InternLM2 LM backbone.
[arXiv:2404.16821; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553,
    frontend="vision", frontend_len=256,
    source="arXiv:2404.16821",
)
