"""Architecture registry: ``get_config("<id>")`` accepts dashed or
underscored ids (``--arch moonshot-v1-16b-a3b``)."""
from __future__ import annotations

import importlib

from .base import SHAPES, SHAPES_BY_NAME, ArchConfig, MoECfg, ShapeCfg, SSMCfg

_MODULES = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "qwen3-32b": "qwen3_32b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "internvl2-2b": "internvl2_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "whisper-base": "whisper_base",
    "zamba2-1.2b": "zamba2_1_2b",
}

ARCH_IDS = tuple(_MODULES)


def _canon(name: str) -> str:
    n = name.strip().lower()
    for arch_id, mod in _MODULES.items():
        if n in (arch_id, mod, arch_id.replace("-", "_").replace(".", "_")):
            return arch_id
    raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")


def get_config(name: str) -> ArchConfig:
    arch_id = _canon(name)
    module = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return module.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {arch_id: get_config(arch_id) for arch_id in _MODULES}


__all__ = [
    "ArchConfig", "MoECfg", "SSMCfg", "ShapeCfg",
    "SHAPES", "SHAPES_BY_NAME", "ARCH_IDS",
    "get_config", "all_configs",
]
