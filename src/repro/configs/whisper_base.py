"""whisper-base — encoder-decoder, conv audio frontend (stub: precomputed
frame embeddings). [arXiv:2212.04356; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865,
    block_pattern="encdec", enc_layers=6,
    frontend="audio", frontend_len=1500,
    source="arXiv:2212.04356",
)
