"""zamba2-1.2b — Mamba2 backbone + shared attention block every 6 layers,
ssm_state=64. [arXiv:2411.15242; hf]"""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    ssm=SSMCfg(state_dim=64, conv_dim=4, expand=2, head_dim=64),
    block_pattern="zamba2", shared_attn_every=6,
    source="arXiv:2411.15242",
)
