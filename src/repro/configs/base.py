"""Architecture + run configuration (assigned-architecture pool).

Every assigned architecture is one `ArchConfig` in its own module; the
registry resolves ``--arch <id>`` (dashes or underscores). `reduced()`
returns the family-faithful small config the CPU smoke tests instantiate;
the full config is exercised abstractly by the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    n_shared: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    state_dim: int = 64
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    attn: str = "full"              # full | swa
    window: int = 4096
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    block_pattern: str = "attn"     # attn | xlstm_7_1 | zamba2 | encdec
    shared_attn_every: int = 6      # zamba2 shared-block period
    enc_layers: int = 0             # whisper encoder depth
    frontend: str = "none"          # none | audio | vision (stubs)
    frontend_len: int = 0           # precomputed frames / patches
    source: str = ""                # provenance note

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded so the vocab dim shards over any mesh axis
        up to 32 (MaxText-style padding; pad logits masked in the loss)."""
        return -(-self.vocab // 512) * 512

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (see DESIGN.md §6)."""
        return self.family in ("ssm", "hybrid") or self.attn == "swa"

    @property
    def is_encdec(self) -> bool:
        return self.block_pattern == "encdec"

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_padded
        h, kh, dh = self.n_heads, self.n_kv_heads, self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * h * dh + 2 * d * kh * dh + h * dh * d
        per_layer = 0
        if self.block_pattern == "attn":
            mlp = 3 * d * ff
            if self.moe:
                mlp = self.moe.n_experts * 3 * d * ff + d * self.moe.n_experts
                mlp += self.moe.n_shared * 3 * d * ff
            per_layer = attn + mlp + 2 * d
            total = emb + self.n_layers * per_layer
        elif self.block_pattern == "xlstm_7_1":
            mlstm = 2 * d * 2 * d + 3 * d * d + d * 2 * h + d * d
            slstm = d * 4 * d + h * self.head_dim_ ** 2 * 4 + d * d
            n_s = self.n_layers // 8
            total = emb + (self.n_layers - n_s) * mlstm + n_s * slstm
        elif self.block_pattern == "zamba2":
            inner = self.ssm.expand * d
            mamba = d * (2 * inner + 2 * self.ssm.state_dim + inner // self.ssm.head_dim) + inner * d
            shared = attn + 3 * d * ff
            total = emb + self.n_layers * mamba + shared
        elif self.block_pattern == "encdec":
            mlp = 3 * d * ff
            total = emb + (self.enc_layers + self.n_layers) * (attn + mlp) + self.n_layers * attn
        else:
            total = emb + self.n_layers * (attn + 3 * d * ff)
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_like = self.param_count() - self.n_layers * (
            self.moe.n_experts * 3 * d * ff
        )
        return int(dense_like + self.n_layers * self.moe.top_k * 3 * d * ff)

    def reduced(self) -> "ArchConfig":
        """Family-faithful small config for CPU smoke tests."""
        def shrink(v, cap):
            return min(v, cap)

        kw = dict(
            n_layers=shrink(self.n_layers, 4 if self.block_pattern != "xlstm_7_1" else 8),
            d_model=shrink(self.d_model, 128),
            n_heads=shrink(self.n_heads, 4),
            n_kv_heads=shrink(self.n_kv_heads, 2 if self.n_kv_heads < self.n_heads else 4),
            d_ff=shrink(self.d_ff, 256) if self.d_ff else 0,
            vocab=shrink(self.vocab, 512),
            head_dim=32 if self.head_dim else 0,
            window=shrink(self.window, 32),
            enc_layers=shrink(self.enc_layers, 2),
            frontend_len=shrink(self.frontend_len, 8),
            shared_attn_every=min(self.shared_attn_every, 2),
        )
        if self.moe:
            kw["moe"] = MoECfg(n_experts=8, top_k=min(self.moe.top_k, 2),
                               n_shared=min(self.moe.n_shared, 1))
        if self.ssm:
            kw["ssm"] = SSMCfg(state_dim=16, conv_dim=4, expand=2, head_dim=32)
        if kw["n_kv_heads"] > kw["n_heads"]:
            kw["n_kv_heads"] = kw["n_heads"]
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCfg:
    """One input-shape cell (assigned per architecture)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeCfg, ...] = (
    ShapeCfg("train_4k", 4096, 256, "train"),
    ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    ShapeCfg("decode_32k", 32768, 128, "decode"),
    ShapeCfg("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
