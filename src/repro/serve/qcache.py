"""LRU hot-query result cache for the decode-time c-AMIP search.

Recsys / multi-class-prediction traffic (the paper's §I use cases) is
Zipfian: a small set of hot queries dominates. A repeated prompt drives the
decode loop through bit-identical hidden states, so the two-phase search it
triggers is pure recomputation — ScaNN-style serving systems win exactly
this workload with a result cache in front of the index. `HotQueryCache`
memoizes `(ids, scores)` rows of the decode search keyed on a QUANTIZED
fingerprint of the hidden state:

  fingerprint = float16(h).tobytes()

float16 is the quantizer: bit-identical hidden rows always collide (the hot
path), while the 10-bit mantissa absorbs sub-quantum numeric wobble without
aliasing genuinely different queries — two hiddens that differ anywhere by
more than one f16 ulp get distinct keys. A hit therefore returns the result
of a query whose hidden state matches to f16 precision; on COLD traffic
(all misses) the cache is bit-invisible, which is the correctness contract
tests/test_serve.py pins (cache-on == cache-off token streams).

Entries are invalidated wholesale on any index mutation (`clear()` from
engine.update()/delete()): a cached row may name a tombstoned id or miss a
fresher delta row, and the engine's correctness story ("retired vocab ids
are never decoded again") must survive the cache. The engine also keys
entries by degradation tier, so a result computed at full budget is never
replayed as evidence of a degraded tier's quality (and vice versa).

Counters (hits/misses/evictions) are kept locally and mirrored into the
`serve.cache_*` metrics by the engine when ``obs=True``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional, Tuple

import numpy as np

__all__ = ["HotQueryCache"]


class HotQueryCache:
    """Bounded LRU mapping fingerprint -> (ids, scores) result rows.

    capacity <= 0 builds a permanently-empty cache (every get() misses,
    put() is a no-op) so callers can keep one unconditional code path.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._entries: "OrderedDict[Hashable, Tuple[np.ndarray, np.ndarray]]" \
            = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def fingerprint(row: np.ndarray) -> bytes:
        """Quantized key of one hidden-state row (see module docstring)."""
        return np.ascontiguousarray(row, np.float16).tobytes()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: Hashable) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        hit = self._entries.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key: Hashable, ids: np.ndarray, scores: np.ndarray) -> None:
        if self.capacity <= 0:
            return
        # defensive copies: the engine reuses/overwrites result buffers
        self._entries[key] = (np.array(ids, np.int64),
                              np.array(scores, np.float32))
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        """Drop every entry (index mutated); counters are preserved —
        invalidation is not an eviction."""
        self._entries.clear()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"size": len(self._entries), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate}
