from .engine import DecodeEngine, DegradationPolicy, Request
from .loadgen import Arrival, LoadgenConfig, generate, run_load
from .qcache import HotQueryCache

__all__ = ["DecodeEngine", "DegradationPolicy", "Request", "HotQueryCache",
           "Arrival", "LoadgenConfig", "generate", "run_load"]
