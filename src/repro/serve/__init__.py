from .engine import DecodeEngine, DegradationPolicy, Request

__all__ = ["DecodeEngine", "DegradationPolicy", "Request"]
