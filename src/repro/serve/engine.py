"""Batched decode engine with slot-based continuous batching and
ProMIPS-accelerated approximate top-k logits.

The decode-time logit computation argmax_v <h, E_v> over the output
embedding IS a MIPS problem (paper §I's multi-class prediction use case);
`logits_mode="promips"` replaces the dense h @ E^T scan with the device-mode
c-k-AMIP search over an index built on the embedding rows — probability-
guaranteed approximate greedy decoding whose page/FLOP savings mirror the
paper's Fig. 7/8. `logits_mode="exact"` is the baseline.

Continuous batching: fixed B slots; finished sequences free their slot and
a queued request is admitted with a single-request prefill scattered into
the batch cache at the slot index.

The embedding index is any MUTABLE `repro.api.Searcher` (DESIGN.md §9) —
the engine is no longer hard-wired to one stream type. By default it builds
the `promips-stream` backend over the embedding rows; pass ``index=`` to
inject any registered backend whose `capabilities.supports_mutation` is set
(e.g. ``backend="sharded"`` for a range-routed multi-shard embedding).
`update(ids, rows)` / `delete(ids)` track output-embedding weight refreshes
and vocabulary retirements mid-traffic — updated rows land in the delta
segment (scored exactly), stale rows are tombstoned, and background
compaction folds the churn back into the immutable base off the decode path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import api
from ..core.runtime import RuntimeConfig
from ..models import transformer as model_lib
from ..obs import metrics as _metrics


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    slot: int = -1
    # lifecycle timestamps (time.perf_counter seconds; 0.0 = not yet reached)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_done: float = 0.0


class DecodeEngine:
    def __init__(self, params, cfg, *, batch_slots: Optional[int] = None,
                 max_len: int = 512,
                 logits_mode: str = "exact", promips_kwargs: Optional[dict] = None,
                 promips_budget: Optional[int] = None, eos_id: int = 0,
                 search_runtime: Optional[RuntimeConfig] = None,
                 index: Optional[api.Searcher] = None,
                 obs: bool = False, max_queue: Optional[int] = None):
        if index is not None:
            # validated before any allocation: any MUTABLE Searcher works,
            # gated by capability rather than by concrete stream type
            if logits_mode != "promips":
                raise ValueError(
                    "index= requires logits_mode='promips' (exact mode has "
                    "no logit index; the given searcher would be ignored)")
            if not index.capabilities.supports_mutation:
                raise ValueError(
                    f"engine index backend {index.name!r} must support "
                    "mutation (capabilities.supports_mutation=True)")
            if promips_kwargs:
                raise ValueError(
                    "promips_kwargs only tunes the default-built index; "
                    "with index= they would be silently ignored — configure "
                    "the injected searcher at its own build() instead")
        if batch_slots is None:
            # tuned default keyed on the logit-index shape (vocab, d_model);
            # hand-picked fallback is 4 when the tuning cache has no entry
            from ..tune import cache as _tune_cache
            batch_slots = int(_tune_cache.resolved(
                "serve", cfg.vocab, cfg.d_model)["decode_batch_slots"])
        self.params, self.cfg = params, cfg
        self.b, self.max_len = batch_slots, max_len
        self.logits_mode = logits_mode
        self.eos_id = eos_id
        # serve-path telemetry (DESIGN.md §14): counters/histograms in the
        # repro.obs.metrics registry, one `if self.obs` check when disabled.
        # max_queue bounds admission backlog; submits past it are SHED.
        self.obs = bool(obs)
        self.max_queue = max_queue
        self.cache = model_lib.init_cache(cfg, batch_slots, max_len,
                                          params["embed"].dtype)
        self.active = np.zeros(batch_slots, bool)
        self.requests: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.steps = 0
        self.pages = 0
        self._decode = jax.jit(
            lambda p, c, t: model_lib.decode_step(p, cfg, c, t))
        self._decode_hidden = jax.jit(
            lambda p, c, t: model_lib.decode_step(p, cfg, c, t, return_hidden=True))
        if logits_mode == "promips":
            if index is not None:
                self.index = index
            else:
                emb = np.asarray(params["embed"], np.float32)[: cfg.vocab]
                kw = dict(m=8, c=0.9, p=0.9, norm_strata=4, seed=0)
                kw.update(promips_kwargs or {})
                guarantee = api.GuaranteeConfig(c=kw.pop("c"), p0=kw.pop("p"))
                # streaming index: row id == vocab id; update()/delete()
                # absorb weight refreshes, auto-compaction off the decode path
                self.index = api.build(emb, backend="promips-stream",
                                       guarantee=guarantee, auto_compact=True,
                                       seed=kw.pop("seed"), **kw)
            self._retired = np.zeros(cfg.vocab, bool)
            # decode-step batch goes through the unified two-phase runtime
            # (batched verification over the B slots by default): at
            # decode-shaped batches (B <= slots, k=4) the single batched
            # graph measures faster per step than either fused driver on
            # the CPU oracle (~6.3 ms vs 7.0 in-graph / 14 host-orchestrated
            # at B=4, n=4096 — tiny batches leave no union for the pow2
            # bucketing to shrink). "fused" is a first-class option here
            # since PR 5 (`core/search_graph.py` makes it trace-safe;
            # tests/test_serve.py pins token-identical decoding) — pass
            # ``search_runtime=RuntimeConfig(verification="fused", ...)``
            # to select it, e.g. on TPU where the kernel's page-skipping
            # DMA walk changes the economics. A user-supplied RuntimeConfig
            # is taken as-is (only k is stamped in), matching
            # sharded_search's contract — ``promips_budget`` applies to the
            # default config only.
            if search_runtime is None:
                search_runtime = RuntimeConfig(
                    mode="two_phase", verification="batched",
                    norm_adaptive=True, cs_prune=True, budget=promips_budget)
            self.search_runtime = dataclasses.replace(search_runtime, k=4)

    # -- embedding mutation (streaming index, DESIGN.md §8) ------------------
    def update(self, ids, rows) -> None:
        """Refresh output-embedding rows mid-traffic (e.g. a trainer pushed
        new weights for some vocab ids). The model's embed table is patched
        in place; in promips mode the refreshed rows move to the index's
        delta segment and are scored exactly from the next decode step."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if (ids < 0).any() or (ids >= self.cfg.vocab).any():
            raise ValueError("update ids must be valid vocab ids")
        d_emb = self.params["embed"].shape[-1]
        if rows.shape != (len(ids), d_emb):
            raise ValueError(f"rows must be ({len(ids)}, {d_emb}), "
                             f"got {rows.shape}")
        if self.logits_mode == "promips":
            # index first: it validates aliveness, so a rejected refresh
            # (e.g. of a retired id) leaves the embed table untouched
            self.index.update(ids, rows)
        self.params = dict(self.params)
        self.params["embed"] = self.params["embed"].at[ids].set(
            rows.astype(self.params["embed"].dtype))

    def delete(self, ids) -> None:
        """Retire vocab ids from decoding: tombstoned in the embedding index,
        so approximate greedy search can never emit them again (promips mode
        only — exact mode has no index to mask)."""
        if self.logits_mode != "promips":
            raise ValueError("delete() requires logits_mode='promips'")
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        self.index.delete(ids)
        self._retired[ids] = True  # admission prefill masks these too
        if self.obs:
            _metrics.counter("serve.tombstones").inc(len(ids))

    def join_compaction(self, timeout: Optional[float] = None) -> None:
        if self.logits_mode == "promips":
            self.index.flush(timeout)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, prompt: np.ndarray,
               max_new_tokens: int = 16) -> Optional[Request]:
        """Enqueue a request. Returns None (request SHED) when ``max_queue``
        is set and the admission backlog is already at the cap — the caller
        decides whether to retry; nothing is buffered."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.obs:
                _metrics.counter("serve.requests_shed").inc()
            return None
        req = Request(prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, out_tokens=[],
                      t_submit=time.perf_counter())
        self.queue.append(req)
        if self.obs:
            _metrics.counter("serve.requests_submitted").inc()
        return req

    def _admit(self):
        for slot in range(self.b):
            if self.active[slot] or not self.queue:
                continue
            req = self.queue.pop(0)
            req.slot = slot
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            if self.cfg.frontend == "vision":
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.frontend_len, self.cfg.d_model),
                    self.params["embed"].dtype)
            if self.cfg.frontend == "audio":
                batch["frames"] = jnp.zeros(
                    (1, self.cfg.frontend_len, self.cfg.d_model),
                    self.params["embed"].dtype)
            cache1, logits = model_lib.prefill(self.params, self.cfg, batch,
                                               self.max_len)

            def insert(full, one):
                if one.ndim == 0:
                    return full
                for ax in range(one.ndim):
                    if full.shape[ax] == self.b and one.shape[ax] == 1:
                        idx = [slice(None)] * one.ndim
                        idx[ax] = slice(slot, slot + 1)
                        return full.at[tuple(idx)].set(one.astype(full.dtype))
                return full

            self.cache = jax.tree.map(insert, self.cache, cache1)
            lg = np.array(logits[0], np.float32)  # copy: jax buffers are RO
            lg[self.cfg.vocab:] = -np.inf  # logits cover vocab_padded rows;
            # the argmax must only land on a real vocab id
            if self.logits_mode == "promips":
                # retired vocab ids are tombstoned in the index; keep the
                # dense prefill argmax consistent with the decode path
                lg[: self.cfg.vocab][self._retired] = -np.inf
            req.out_tokens.append(int(np.argmax(lg)))
            req.t_admit = time.perf_counter()
            if self.obs:
                _metrics.histogram("serve.queue_wait_us").observe(
                    (req.t_admit - req.t_submit) * 1e6)
            self.active[slot] = True
            self.requests[slot] = req

    # -- main loop -----------------------------------------------------------
    def step(self) -> bool:
        """One engine step: admit, decode one token for all active slots."""
        t0 = time.perf_counter() if self.obs else 0.0
        self._admit()
        if not self.active.any():
            if self.obs:
                _metrics.gauge("serve.slot_occupancy").set(0.0)
                _metrics.gauge("serve.queue_depth").set(len(self.queue))
            return False
        tokens = np.zeros((self.b, 1), np.int32)
        for slot in range(self.b):
            if self.active[slot]:
                tokens[slot, 0] = self.requests[slot].out_tokens[-1]
        if self.logits_mode == "promips":
            hidden, self.cache = self._decode_hidden(
                self.params, self.cache, jnp.asarray(tokens))
            res = self.index.search(hidden, k=self.search_runtime.k,
                                    runtime=self.search_runtime)
            self.pages += res.stats["pages"]
            if self.obs:
                _metrics.counter("serve.pages").inc(res.stats["pages"])
            nxt = res.ids[:, 0]
            # a slot starved by a finite promips_budget (stats.exhausted)
            # returns id -1; end that sequence instead of decoding token -1
            nxt = np.where(nxt >= 0, nxt, self.eos_id)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens))
            lg = np.array(logits, np.float32)
            lg[..., self.cfg.vocab:] = -np.inf  # mask vocab_padded tail
            nxt = np.argmax(lg, axis=-1)
            self.pages += self.cfg.vocab_padded * self.cfg.d_model * 4 // 4096 \
                * int(self.active.sum()) // max(self.b, 1)
        self.steps += 1
        for slot in range(self.b):
            if not self.active[slot]:
                continue
            req = self.requests[slot]
            req.out_tokens.append(int(nxt[slot]))
            if (len(req.out_tokens) >= req.max_new_tokens
                    or int(nxt[slot]) == self.eos_id):
                self.active[slot] = False
                self.requests[slot] = None
                req.t_done = time.perf_counter()
                if self.obs:
                    _metrics.counter("serve.requests_completed").inc()
                    _metrics.histogram("serve.request_us").observe(
                        (req.t_done - req.t_submit) * 1e6)
        if self.obs:
            _metrics.counter("serve.decode_steps").inc()
            _metrics.histogram("serve.step_us").observe(
                (time.perf_counter() - t0) * 1e6)
            _metrics.gauge("serve.slot_occupancy").set(
                float(self.active.sum()) / max(self.b, 1))
            _metrics.gauge("serve.queue_depth").set(len(self.queue))
        return True

    def run(self, max_steps: int = 10_000):
        while (self.queue or self.active.any()) and self.steps < max_steps:
            self.step()

    # -- telemetry -----------------------------------------------------------
    def metrics_snapshot(self) -> dict:
        """Engine-state view plus every live ``serve.*`` registry entry
        (counters as ints, gauges as floats, histograms as their summary
        dicts). Cheap enough to poll per scrape; with ``obs=False`` only the
        engine-state keys are populated."""
        snap = {"steps": self.steps, "pages": self.pages,
                "queue_depth": len(self.queue),
                "active_slots": int(self.active.sum())}
        snap.update({name: val for name, val in _metrics.snapshot().items()
                     if name.startswith("serve.")})
        return snap
