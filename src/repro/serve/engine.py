"""Batched decode engine with slot-based continuous batching and
ProMIPS-accelerated approximate top-k logits.

The decode-time logit computation argmax_v <h, E_v> over the output
embedding IS a MIPS problem (paper §I's multi-class prediction use case);
`logits_mode="promips"` replaces the dense h @ E^T scan with the device-mode
c-k-AMIP search over an index built on the embedding rows — probability-
guaranteed approximate greedy decoding whose page/FLOP savings mirror the
paper's Fig. 7/8. `logits_mode="exact"` is the baseline.

Continuous batching (DESIGN.md §17): fixed B slots, refilled from the
admission queue on EVERY step. All requests admitted in one step are
prefilled together — one `model_lib.prefill` call per distinct prompt
length, scattered into the batch cache at their slot indices along the
batch axis (located once per cache leaf by an `eval_shape` probe, so the
scatter never guesses which axis is the batch). The decode-time search runs
only over the ACTIVE slots (inactive rows are compacted out before the
index is queried, so their stale hidden states cost zero pages), and a
`HotQueryCache` — an LRU of (ids, scores) rows keyed on a quantized
hidden-state fingerprint (serve/qcache.py) — short-circuits the two-phase
search entirely for repeated/hot queries. Batch width, cache capacity and
per-step refill limit resolve from the autotuner's shape-keyed cache
(tune/space.py "serve" section) when not given explicitly.

The embedding index is any MUTABLE `repro.api.Searcher` (DESIGN.md §9) —
the engine is no longer hard-wired to one stream type. By default it builds
the `promips-stream` backend over the embedding rows; pass ``index=`` to
inject any registered backend whose `capabilities.supports_mutation` is set
(e.g. ``backend="sharded"`` for a range-routed multi-shard embedding).
`update(ids, rows)` / `delete(ids)` track output-embedding weight refreshes
and vocabulary retirements mid-traffic — updated rows land in the delta
segment (scored exactly), stale rows are tombstoned, and background
compaction folds the churn back into the immutable base off the decode path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import api
from ..core.runtime import RuntimeConfig
from ..models import transformer as model_lib
from ..obs import metrics as _metrics
from ..robust.faultpoints import fault
from ..robust.watchdog import EwmaWatchdog


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    slot: int = -1
    # lifecycle timestamps (time.perf_counter seconds; 0.0 = not yet reached)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_done: float = 0.0
    # absolute perf_counter deadline; None is the ONLY no-deadline sentinel
    # (0.0 is a real, already-passed deadline — it expires at admission)
    deadline: Optional[float] = None
    expired: bool = False             # dropped/terminated past its deadline


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Serve-path degradation ladder (DESIGN.md §16).

    Under sustained overload the engine steps DOWN through ``tiers`` —
    each entry is a verification block budget for the decode-time search
    (``None``/1.0 = the configured full-quality runtime; an int is an
    absolute block count; a float in (0, 1) is a fraction of the index's
    selected-block ceiling, resolved at engine init) — trading recall for
    latency BEFORE the queue cap sheds requests outright. When the queue
    drains, it steps back UP one tier at a time.

    Overload = queue depth ≥ ``queue_high``, or a step slower than
    ``latency_factor`` × the EWMA of recent steps (the shared
    `robust.EwmaWatchdog` — same detector the distributed trainer uses for
    stragglers), sustained for ``patience`` consecutive steps. Recovery =
    queue depth ≤ ``queue_low`` for ``recovery`` consecutive steps
    (hysteresis: the two thresholds and the longer recovery streak stop the
    ladder from oscillating at the boundary).

    ``recall_floors`` is the DECLARED minimum recall@k per tier, measured
    against the exact oracle by `benchmarks --robust` and guarded by
    scripts/ci.sh — the ladder's quality contract, not a runtime check.
    """

    tiers: tuple = (1.0, 0.5, 0.25)
    recall_floors: tuple = (0.95, 0.85, 0.6)
    queue_high: int = 8
    queue_low: int = 2
    latency_factor: float = 2.5
    alpha: float = 0.2                 # EWMA smoothing for step latency
    patience: int = 3                  # overloaded steps before step-down
    recovery: int = 8                  # calm steps before step-up

    def __post_init__(self):
        if not self.tiers:
            raise ValueError("DegradationPolicy.tiers must be non-empty")
        if len(self.recall_floors) != len(self.tiers):
            raise ValueError("recall_floors must declare one floor per tier")
        if self.queue_low >= self.queue_high:
            raise ValueError("queue_low must be < queue_high (hysteresis)")


class DecodeEngine:
    def __init__(self, params, cfg, *, batch_slots: Optional[int] = None,
                 max_len: int = 512,
                 logits_mode: str = "exact", promips_kwargs: Optional[dict] = None,
                 promips_budget: Optional[int] = None, eos_id: int = 0,
                 search_runtime: Optional[RuntimeConfig] = None,
                 index: Optional[api.Searcher] = None,
                 obs: bool = False, max_queue: Optional[int] = None,
                 degradation: Optional[DegradationPolicy] = None,
                 default_deadline_s: Optional[float] = None,
                 result_cache: Optional[int] = None,
                 max_refill: Optional[int] = None):
        if index is not None:
            # validated before any allocation: any MUTABLE Searcher works,
            # gated by capability rather than by concrete stream type
            if logits_mode != "promips":
                raise ValueError(
                    "index= requires logits_mode='promips' (exact mode has "
                    "no logit index; the given searcher would be ignored)")
            if not index.capabilities.supports_mutation:
                raise ValueError(
                    f"engine index backend {index.name!r} must support "
                    "mutation (capabilities.supports_mutation=True)")
            if promips_kwargs:
                raise ValueError(
                    "promips_kwargs only tunes the default-built index; "
                    "with index= they would be silently ignored — configure "
                    "the injected searcher at its own build() instead")
        if batch_slots is None or result_cache is None or max_refill is None:
            # tuned defaults keyed on the logit-index shape (vocab, d_model);
            # hand-picked fallbacks (tune/space.py HAND_PICKED["serve"])
            # apply when the tuning cache has no entry. Explicit kwargs win.
            from ..tune import cache as _tune_cache
            tuned = _tune_cache.resolved("serve", cfg.vocab, cfg.d_model)
            if batch_slots is None:
                batch_slots = int(tuned["decode_batch_slots"])
            if result_cache is None:
                result_cache = int(tuned["result_cache_size"])
            if max_refill is None:
                max_refill = tuned["max_refill_per_step"]
        self.params, self.cfg = params, cfg
        self.b, self.max_len = batch_slots, max_len
        if max_refill is not None and int(max_refill) < 1:
            raise ValueError(f"max_refill must be >= 1 or None (= all free "
                             f"slots), got {max_refill!r}")
        self.max_refill = None if max_refill is None else int(max_refill)
        self.logits_mode = logits_mode
        self.eos_id = eos_id
        # serve-path telemetry (DESIGN.md §14): counters/histograms in the
        # repro.obs.metrics registry, one `if self.obs` check when disabled.
        # max_queue bounds admission backlog; submits past it are SHED.
        self.obs = bool(obs)
        self.max_queue = max_queue
        self.cache = model_lib.init_cache(cfg, batch_slots, max_len,
                                          params["embed"].dtype)
        # per-leaf batch axis of the decode cache, located structurally: the
        # one axis whose extent tracks the batch size between two eval_shape
        # probes (no guessing "the axis that happens to equal B", which
        # breaks when n_layers or kv_len collide with the slot count)
        probe = [jax.eval_shape(lambda b=b: model_lib.init_cache(
            cfg, b, max_len, params["embed"].dtype)) for b in (1, 2)]
        self._batch_axes = jax.tree.map(
            lambda a, c: next((ax for ax in range(len(a.shape))
                               if a.shape[ax] != c.shape[ax]), None),
            probe[0], probe[1])
        self.active = np.zeros(batch_slots, bool)
        self.requests: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.steps = 0
        self.pages = 0
        self.searched_rows = 0          # hidden rows actually sent to the
        self.prefill_calls = 0          # index (active, cache-miss only)
        # degradation ladder + deadlines (DESIGN.md §16)
        self.policy = degradation
        self.default_deadline_s = default_deadline_s
        self.tier = 0
        self.stepdowns = 0
        self.stepups = 0
        self.shed = 0
        self.deadline_drops = 0
        self._watch = EwmaWatchdog(
            threshold=degradation.latency_factor if degradation else 2.5,
            alpha=degradation.alpha if degradation else 0.2)
        self._over_streak = 0
        self._calm_streak = 0
        self._tier_cache: dict = {}
        self._decode = jax.jit(
            lambda p, c, t: model_lib.decode_step(p, cfg, c, t))
        self._decode_hidden = jax.jit(
            lambda p, c, t: model_lib.decode_step(p, cfg, c, t, return_hidden=True))
        self.qcache = None
        if logits_mode == "promips":
            if index is not None:
                self.index = index
            else:
                emb = np.asarray(params["embed"], np.float32)[: cfg.vocab]
                kw = dict(m=8, c=0.9, p=0.9, norm_strata=4, seed=0)
                kw.update(promips_kwargs or {})
                guarantee = api.GuaranteeConfig(c=kw.pop("c"), p0=kw.pop("p"))
                # streaming index: row id == vocab id; update()/delete()
                # absorb weight refreshes, auto-compaction off the decode path
                self.index = api.build(emb, backend="promips-stream",
                                       guarantee=guarantee, auto_compact=True,
                                       seed=kw.pop("seed"), **kw)
            self._retired = np.zeros(cfg.vocab, bool)
            # decode-step batch goes through the unified two-phase runtime
            # (batched verification over the B slots by default): at
            # decode-shaped batches (B <= slots, k=4) the single batched
            # graph measures faster per step than either fused driver on
            # the CPU oracle (~6.3 ms vs 7.0 in-graph / 14 host-orchestrated
            # at B=4, n=4096 — tiny batches leave no union for the pow2
            # bucketing to shrink). "fused" is a first-class option here
            # since PR 5 (`core/search_graph.py` makes it trace-safe;
            # tests/test_serve.py pins token-identical decoding) — pass
            # ``search_runtime=RuntimeConfig(verification="fused", ...)``
            # to select it, e.g. on TPU where the kernel's page-skipping
            # DMA walk changes the economics. A user-supplied RuntimeConfig
            # is taken as-is (only k is stamped in), matching
            # sharded_search's contract — ``promips_budget`` applies to the
            # default config only.
            if search_runtime is None:
                search_runtime = RuntimeConfig(
                    mode="two_phase", verification="batched",
                    norm_adaptive=True, cs_prune=True, budget=promips_budget)
            self.search_runtime = dataclasses.replace(search_runtime, k=4)
            # LRU hot-query result cache (serve/qcache.py): capacity 0
            # disables; entries keyed (tier, f16-fingerprint) so a result
            # computed at one budget tier is never replayed at another
            from .qcache import HotQueryCache
            self.qcache = HotQueryCache(int(result_cache))
        self._tier_budgets = (self._resolve_tier_budgets()
                              if degradation is not None else (None,))

    # -- degradation ladder (DESIGN.md §16) ----------------------------------
    def _resolve_tier_budgets(self) -> tuple:
        """Map the policy's tier entries onto absolute block budgets: None /
        1.0 = the configured runtime, int = absolute, float in (0, 1) = a
        fraction of the index's block count (resolved here, once)."""
        blocks = None
        inner = getattr(getattr(self, "index", None), "inner", None)
        if inner is not None:
            if hasattr(inner, "meta"):
                blocks = int(inner.meta.n_blocks)
            elif hasattr(inner, "shards"):
                blocks = min(int(s.meta.n_blocks) for s in inner.shards)
        out = []
        for t in self.policy.tiers:
            if t is None or (isinstance(t, float) and t >= 1.0):
                out.append(None)
            elif isinstance(t, float):
                out.append(max(1, round(blocks * t)) if blocks else None)
            else:
                out.append(max(1, int(t)))
        return tuple(out)

    def _tier_runtime(self) -> RuntimeConfig:
        """The decode-search runtime for the CURRENT tier (cached per tier —
        at most len(tiers) distinct compiled budgets over the engine's life)."""
        b = self._tier_budgets[self.tier]
        if b is None:
            return self.search_runtime
        rt = self._tier_cache.get(self.tier)
        if rt is None:
            rt = dataclasses.replace(self.search_runtime, budget=b, budget2=b)
            self._tier_cache[self.tier] = rt
        return rt

    def _ladder_tick(self, step_seconds: Optional[float]) -> None:
        """One hysteresis update: overload (deep queue OR a straggler step)
        must persist for ``patience`` steps to step down; calm (shallow
        queue) must persist for ``recovery`` steps to step up. ``None``
        step_seconds = an idle tick (no latency signal)."""
        p = self.policy
        if p is None:
            return
        slow = (self._watch.observe(step_seconds)
                if step_seconds is not None else False)
        depth = len(self.queue)
        if depth >= p.queue_high or slow:
            self._over_streak += 1
            self._calm_streak = 0
        elif depth <= p.queue_low:
            self._calm_streak += 1
            self._over_streak = 0
        else:                       # hysteresis band: hold the current tier
            self._over_streak = 0
        if (self._over_streak >= p.patience
                and self.tier < len(self._tier_budgets) - 1):
            self.tier += 1
            self.stepdowns += 1
            self._over_streak = 0
            if self.obs:
                _metrics.counter("serve.tier_stepdowns").inc()
        elif self._calm_streak >= p.recovery and self.tier > 0:
            self.tier -= 1
            self.stepups += 1
            self._calm_streak = 0
            if self.obs:
                _metrics.counter("serve.tier_stepups").inc()
        if self.obs:
            _metrics.gauge("serve.degradation_tier").set(self.tier)
            _metrics.gauge("serve.step_latency_ewma").set(self._watch.ewma)

    # -- embedding mutation (streaming index, DESIGN.md §8) ------------------
    def update(self, ids, rows) -> None:
        """Refresh output-embedding rows mid-traffic (e.g. a trainer pushed
        new weights for some vocab ids). The model's embed table is patched
        in place; in promips mode the refreshed rows move to the index's
        delta segment and are scored exactly from the next decode step."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if (ids < 0).any() or (ids >= self.cfg.vocab).any():
            raise ValueError("update ids must be valid vocab ids")
        d_emb = self.params["embed"].shape[-1]
        if rows.shape != (len(ids), d_emb):
            raise ValueError(f"rows must be ({len(ids)}, {d_emb}), "
                             f"got {rows.shape}")
        if self.logits_mode == "promips":
            # index first: it validates aliveness, so a rejected refresh
            # (e.g. of a retired id) leaves the embed table untouched
            self.index.update(ids, rows)
            # cached results may predate the refreshed rows — drop them all
            self.qcache.clear()
        self.params = dict(self.params)
        self.params["embed"] = self.params["embed"].at[ids].set(
            rows.astype(self.params["embed"].dtype))

    def delete(self, ids) -> None:
        """Retire vocab ids from decoding: tombstoned in the embedding index,
        so approximate greedy search can never emit them again (promips mode
        only — exact mode has no index to mask)."""
        if self.logits_mode != "promips":
            raise ValueError("delete() requires logits_mode='promips'")
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        self.index.delete(ids)
        self._retired[ids] = True  # admission prefill masks these too
        # a cached result row may still name a retired id; invalidate so
        # "never decoded again" survives the cache
        self.qcache.clear()
        if self.obs:
            _metrics.counter("serve.tombstones").inc(len(ids))

    def join_compaction(self, timeout: Optional[float] = None) -> None:
        if self.logits_mode == "promips":
            self.index.flush(timeout)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               deadline_s: Optional[float] = None) -> Optional[Request]:
        """Enqueue a request. Returns None (request SHED) when ``max_queue``
        is set and the admission backlog is already at the cap — the caller
        decides whether to retry; nothing is buffered.

        Malformed prompts (non-integer, wrong rank, out-of-vocab or negative
        token ids, empty) are rejected with a ValueError at this boundary —
        a bad token id would otherwise index the embed table out of range
        inside the jit'd prefill.

        ``deadline_s`` (seconds from now; defaults to the engine's
        ``default_deadline_s``) bounds the request's useful life: expired
        requests are dropped at admission, and an active sequence past its
        deadline is terminated at the next step (``req.expired`` set, the
        tokens decoded so far retained).
        """
        prompt = self._validate_prompt(prompt)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.shed += 1
            if self.obs:
                _metrics.counter("serve.requests_shed").inc()
            return None
        now = time.perf_counter()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        # None is the only no-deadline sentinel: deadline_s=0.0 means
        # "already expired" (dropped at admission, deadline_drops counted),
        # not "no deadline"
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      out_tokens=[], t_submit=now,
                      deadline=(now + deadline_s if deadline_s is not None
                                else None))
        self.queue.append(req)
        if self.obs:
            _metrics.counter("serve.requests_submitted").inc()
        return req

    def _validate_prompt(self, prompt) -> np.ndarray:
        arr = np.asarray(prompt)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError(f"prompt must be a non-empty 1-D token array, "
                             f"got shape {arr.shape}")
        if not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(f"prompt tokens must be integers, got dtype "
                             f"{arr.dtype}")
        if int(arr.min()) < 0 or int(arr.max()) >= self.cfg.vocab:
            raise ValueError(
                f"prompt token ids must be in [0, {self.cfg.vocab}), got "
                f"range [{int(arr.min())}, {int(arr.max())}]")
        return arr.astype(np.int32)

    def _expire(self, req: Request) -> None:
        req.expired = True
        req.t_done = time.perf_counter()
        self.deadline_drops += 1
        if self.obs:
            _metrics.counter("serve.deadline_expired").inc()

    def _admit(self):
        """Refill free slots from the queue (continuous batching): pop up to
        ``max_refill`` live requests (expired ones are dropped at this
        boundary — admitting them would burn a prefill + decode steps on an
        answer nobody is waiting for), then prefill all of them TOGETHER —
        one `model_lib.prefill` call per distinct prompt length, each
        group's cache rows scattered into the batch cache at their slot
        indices along the probe-located batch axis."""
        admitted: List[Request] = []
        free = [s for s in range(self.b) if not self.active[s]]
        limit = len(free) if self.max_refill is None else \
            min(len(free), self.max_refill)
        for slot in free[:limit]:
            req = None
            while self.queue:
                cand = self.queue.pop(0)
                if (cand.deadline is not None
                        and time.perf_counter() > cand.deadline):
                    self._expire(cand)   # dead on arrival
                    continue
                req = cand
                break
            if req is None:
                break
            req.slot = slot
            admitted.append(req)
        if not admitted:
            return
        by_len: dict = {}
        for req in admitted:
            by_len.setdefault(len(req.prompt), []).append(req)
        for group in by_len.values():
            self._prefill_group(group)

    def _prefill_group(self, group: List[Request]) -> None:
        """One batched prefill over same-length prompts; scatter each row
        into its request's slot."""
        g = len(group)
        tokens = np.stack([r.prompt for r in group])
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (g, self.cfg.frontend_len, self.cfg.d_model),
                self.params["embed"].dtype)
        if self.cfg.frontend == "audio":
            batch["frames"] = jnp.zeros(
                (g, self.cfg.frontend_len, self.cfg.d_model),
                self.params["embed"].dtype)
        cache_g, logits = model_lib.prefill(self.params, self.cfg, batch,
                                            self.max_len)
        self.prefill_calls += 1
        slots = jnp.asarray(np.array([r.slot for r in group], np.int32))

        def insert(full, one, ax):
            if ax is None:        # leaf has no batch axis (static scalar)
                return full
            idx = [slice(None)] * len(full.shape)
            idx[ax] = slots       # one advanced index keeps its axis slot
            return full.at[tuple(idx)].set(one.astype(full.dtype))

        self.cache = jax.tree.map(insert, self.cache, cache_g,
                                  self._batch_axes)
        lg = np.array(logits, np.float32)  # copy: jax buffers are RO
        lg[:, self.cfg.vocab:] = -np.inf   # logits cover vocab_padded rows;
        # the argmax must only land on a real vocab id
        if self.logits_mode == "promips":
            # retired vocab ids are tombstoned in the index; keep the
            # dense prefill argmax consistent with the decode path
            lg[:, : self.cfg.vocab][:, self._retired] = -np.inf
        now = time.perf_counter()
        for i, req in enumerate(group):
            req.out_tokens.append(int(np.argmax(lg[i])))
            req.t_admit = now
            if self.obs:
                _metrics.histogram("serve.queue_wait_us").observe(
                    (req.t_admit - req.t_submit) * 1e6)
            self.active[req.slot] = True
            self.requests[req.slot] = req

    # -- main loop -----------------------------------------------------------
    def _promips_next_tokens(self, hidden) -> np.ndarray:
        """Decode-search over the ACTIVE slots only, with the hot-query
        cache in front of the index.

        Inactive slots carry stale last-tokens whose hidden rows are junk —
        searching them (the pre-§17 behavior) inflated `self.pages`, the
        `serve.pages` counter and every per-query page figure a serve
        benchmark would report. Active rows are compacted out of the batch
        before the index is queried, so pages are attributed ONLY to slots
        that decoded a real token; per-query results are unchanged by the
        compaction because the batched verification backend is bit-identical
        to the per-query scan (DESIGN.md §4).

        Cache-hit rows skip the two-phase search entirely; misses are
        searched as one compacted sub-batch and their (ids, scores) rows
        inserted under the (tier, fingerprint) key."""
        rt = self._tier_runtime()
        active_idx = np.flatnonzero(self.active)
        nxt = np.full(self.b, self.eos_id, np.int64)
        cache_on = self.qcache.capacity > 0
        miss_rows: List[int] = []
        if cache_on:
            h_np = np.asarray(hidden, np.float32)
            keys = {}
            for s in active_idx:
                key = (self.tier, self.qcache.fingerprint(h_np[s]))
                keys[s] = key
                hit = self.qcache.get(key)
                if hit is None:
                    miss_rows.append(int(s))
                else:
                    nxt[s] = hit[0][0]
            if self.obs:
                _metrics.counter("serve.cache_hits").inc(
                    len(active_idx) - len(miss_rows))
                _metrics.counter("serve.cache_misses").inc(len(miss_rows))
        else:
            miss_rows = [int(s) for s in active_idx]
        if miss_rows:
            # compact to the searched rows on device (all-active full-width
            # batches skip the gather: the common full-load fast path)
            if len(miss_rows) == self.b:
                queries = hidden
            else:
                queries = jnp.take(hidden, jnp.asarray(miss_rows), axis=0)
            res = self.index.search(queries, k=rt.k, runtime=rt)
            self.pages += res.stats["pages"]
            self.searched_rows += len(miss_rows)
            if self.obs:
                _metrics.counter("serve.pages").inc(res.stats["pages"])
            ev0 = self.qcache.evictions
            for i, s in enumerate(miss_rows):
                nxt[s] = res.ids[i, 0]
                if cache_on:
                    self.qcache.put(keys[s], res.ids[i], res.scores[i])
            if self.obs and self.qcache.evictions > ev0:
                _metrics.counter("serve.cache_evictions").inc(
                    self.qcache.evictions - ev0)
        # a slot starved by a finite promips_budget (stats.exhausted)
        # returns id -1; end that sequence instead of decoding token -1
        return np.where(nxt >= 0, nxt, self.eos_id)

    def step(self) -> bool:
        """One engine step: admit, decode one token for all active slots.
        Every step feeds the degradation ladder (when a policy is set): step
        wall time into the shared EWMA watchdog, queue depth into the
        overload/calm hysteresis."""
        t0 = time.perf_counter()
        fault.at("serve.decode")
        self._admit()
        if not self.active.any():
            if self.obs:
                _metrics.gauge("serve.slot_occupancy").set(0.0)
                _metrics.gauge("serve.queue_depth").set(len(self.queue))
            self._ladder_tick(None)   # idle: queue signal only
            return False
        tokens = np.zeros((self.b, 1), np.int32)
        for slot in range(self.b):
            if self.active[slot]:
                tokens[slot, 0] = self.requests[slot].out_tokens[-1]
        if self.logits_mode == "promips":
            hidden, self.cache = self._decode_hidden(
                self.params, self.cache, jnp.asarray(tokens))
            nxt = self._promips_next_tokens(hidden)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens))
            lg = np.array(logits, np.float32)
            lg[..., self.cfg.vocab:] = -np.inf  # mask vocab_padded tail
            nxt = np.argmax(lg, axis=-1)
            self.pages += self.cfg.vocab_padded * self.cfg.d_model * 4 // 4096 \
                * int(self.active.sum()) // max(self.b, 1)
        self.steps += 1
        now = time.perf_counter()
        for slot in range(self.b):
            if not self.active[slot]:
                continue
            req = self.requests[slot]
            req.out_tokens.append(int(nxt[slot]))
            # contract: max_new_tokens counts DECODED tokens, i.e. tokens
            # emitted after the prefill argmax (out_tokens[0]). The old
            # `len(out_tokens) >= max_new_tokens` check silently handed a
            # request asking for N new tokens only N-1 decode steps.
            done = (len(req.out_tokens) - 1 >= req.max_new_tokens
                    or int(nxt[slot]) == self.eos_id)
            past_deadline = req.deadline is not None and now > req.deadline
            if done or past_deadline:
                self.active[slot] = False
                self.requests[slot] = None
                if past_deadline and not done:
                    self._expire(req)   # partial tokens retained
                else:
                    req.t_done = now
                    if self.obs:
                        _metrics.counter("serve.requests_completed").inc()
                        _metrics.histogram("serve.request_us").observe(
                            (req.t_done - req.t_submit) * 1e6)
        dt = time.perf_counter() - t0
        if self.obs:
            _metrics.counter("serve.decode_steps").inc()
            _metrics.histogram("serve.step_us").observe(dt * 1e6)
            _metrics.gauge("serve.slot_occupancy").set(
                float(self.active.sum()) / max(self.b, 1))
            _metrics.gauge("serve.queue_depth").set(len(self.queue))
        self._ladder_tick(dt)
        return True

    def run(self, max_steps: int = 10_000):
        while (self.queue or self.active.any()) and self.steps < max_steps:
            self.step()

    # -- telemetry -----------------------------------------------------------
    def _maintenance(self) -> Optional[dict]:
        """Index maintenance health (compaction + WAL), None in exact mode
        or for backends without the hook."""
        idx = getattr(self, "index", None)
        if idx is None or not hasattr(idx, "maintenance_status"):
            return None
        return idx.maintenance_status()

    def health(self) -> dict:
        """Liveness/degradation view for an external health check:

          state     "ok" (full quality) | "degraded" (ladder below tier 0)
                    | "shedding" (admission backlog at the cap — submits
                    are being rejected right now)
          plus the current tier + its declared recall floor, queue/slot
          occupancy, the step-latency EWMA, deadline/shed totals, and the
          index's compaction + WAL status (a latched background compaction
          error surfaces HERE, not only on the next join()).
        """
        shedding = (self.max_queue is not None
                    and len(self.queue) >= self.max_queue)
        maint = self._maintenance()
        return {
            "state": ("shedding" if shedding
                      else "degraded" if self.tier > 0 else "ok"),
            "tier": self.tier,
            "tier_budget": (self._tier_budgets[self.tier]
                            if self.policy is not None else None),
            "tier_recall_floor": (self.policy.recall_floors[self.tier]
                                  if self.policy is not None else None),
            "queue_depth": len(self.queue),
            "active_slots": int(self.active.sum()),
            "step_latency_ewma_s": self._watch.ewma,
            "stepdowns": self.stepdowns,
            "stepups": self.stepups,
            "shed": self.shed,
            "deadline_drops": self.deadline_drops,
            "compaction": maint["compaction"] if maint else None,
            "wal_lag": maint["wal_lag"] if maint else 0,
        }

    def metrics_snapshot(self) -> dict:
        """Engine-state view plus every live ``serve.*`` registry entry
        (counters as ints, gauges as floats, histograms as their summary
        dicts). Cheap enough to poll per scrape; with ``obs=False`` only the
        engine-state keys are populated. The index's maintenance status
        rides along so a latched background-compaction error is visible on
        every scrape."""
        snap = {"steps": self.steps, "pages": self.pages,
                "searched_rows": self.searched_rows,
                "prefill_calls": self.prefill_calls,
                "queue_depth": len(self.queue),
                "active_slots": int(self.active.sum()),
                "tier": self.tier,
                "result_cache": (self.qcache.stats()
                                 if self.qcache is not None else None),
                "maintenance": self._maintenance()}
        snap.update({name: val for name, val in _metrics.snapshot().items()
                     if name.startswith("serve.")})
        return snap
