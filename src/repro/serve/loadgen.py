"""Open-loop Zipfian SLA load generator for the serve frontend (§17).

Models the traffic shape the paper's §I use cases actually see in
production (recsys retrieval, multi-class prediction): request POPULARITY
is Zipfian — a small pool of hot prompts dominates, which is exactly what
the engine's hot-query result cache monetizes — while ARRIVALS are an
open-loop Poisson process at a configured rate, optionally ramping up so a
benchmark can drive the engine through its degradation ladder and
admission-shedding regimes on purpose.

Open loop means arrivals are scheduled on the wall clock, independent of
completions: a slow engine does not throttle the generator, it grows the
queue — the only regime in which queue-wait, deadline-expiry, shedding and
tier occupancy are meaningful numbers (a closed loop self-limits and hides
all four).

Protocol:

  1. `generate(cfg, vocab)` draws a DETERMINISTIC schedule from the seed:
     a pool of `pool_size` distinct prompts (lengths uniform in
     `prompt_lens`), one `Arrival` per request with its wall-clock offset
     (exponential inter-arrival gaps at the — possibly ramping — rate),
     Zipf(`zipf_s`)-distributed pool pick, `max_new_tokens` draw and
     deadline draw from `deadline_mix`.
  2. `run_load(engine, arrivals)` replays the schedule against a live
     `DecodeEngine`: submits every arrival whose time has come, steps the
     engine otherwise, records which tier each step ran at, and returns a
     summary: p50/p99 request latency and queue wait, completed-queries/s,
     shed/expired fractions, per-tier step occupancy and the engine's
     result-cache stats.

The schedule is deterministic given (config, vocab); the REPLAY is wall-
clock real time, so summary numbers are measurements, not simulations.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from .engine import DecodeEngine, Request

__all__ = ["LoadgenConfig", "Arrival", "generate", "run_load", "zipf_probs"]


@dataclasses.dataclass(frozen=True)
class LoadgenConfig:
    """Knobs of one load-generation run (all draws seeded)."""

    rate_qps: float = 50.0            # open-loop arrival rate, requests/s
    n_requests: int = 64
    zipf_s: float = 1.1               # pool-popularity exponent (>= 0;
                                      # 0 = uniform, larger = hotter head)
    pool_size: int = 32               # distinct prompts in the pool
    prompt_lens: Tuple[int, int] = (4, 12)      # inclusive uniform range
    max_new_tokens_choices: Tuple[int, ...] = (4, 8, 16)
    # (deadline_s | None, weight) pairs; None = no deadline. Weights are
    # normalized, so ((None, 3), (0.25, 1)) = 75% / 25%.
    deadline_mix: Tuple[Tuple[Optional[float], float], ...] = ((None, 1.0),)
    ramp: float = 1.0                 # final/initial rate ratio (> 1 ramps
                                      # the arrival rate up over the run)
    seed: int = 0

    def __post_init__(self):
        if self.rate_qps <= 0 or self.n_requests < 1 or self.pool_size < 1:
            raise ValueError("rate_qps, n_requests, pool_size must be "
                             "positive")
        if self.zipf_s < 0 or self.ramp <= 0:
            raise ValueError("zipf_s must be >= 0 and ramp > 0")
        lo, hi = self.prompt_lens
        if not 1 <= lo <= hi:
            raise ValueError(f"prompt_lens must satisfy 1 <= lo <= hi, "
                             f"got {self.prompt_lens}")
        if not self.max_new_tokens_choices or not self.deadline_mix:
            raise ValueError("max_new_tokens_choices and deadline_mix must "
                             "be non-empty")


@dataclasses.dataclass
class Arrival:
    """One scheduled request, annotated in place by `run_load`."""

    t: float                          # wall-clock offset from run start (s)
    pool_id: int                      # which pool prompt (Zipf rank order)
    prompt: np.ndarray
    max_new_tokens: int
    deadline_s: Optional[float]
    request: Optional[Request] = None  # None until submitted or if SHED
    shed: bool = False


def zipf_probs(pool_size: int, s: float) -> np.ndarray:
    """Normalized Zipf pmf over ranks 1..pool_size: p_i ∝ i^-s."""
    w = np.arange(1, pool_size + 1, dtype=np.float64) ** -float(s)
    return w / w.sum()


def generate(cfg: LoadgenConfig, vocab: int) -> List[Arrival]:
    """Draw the deterministic arrival schedule (see module docstring)."""
    rng = np.random.RandomState(cfg.seed)
    lo, hi = cfg.prompt_lens
    pool = [rng.randint(1, vocab, size=rng.randint(lo, hi + 1))
            .astype(np.int32) for _ in range(cfg.pool_size)]
    probs = zipf_probs(cfg.pool_size, cfg.zipf_s)
    dl_vals = [d for d, _ in cfg.deadline_mix]
    dl_w = np.asarray([w for _, w in cfg.deadline_mix], np.float64)
    dl_w = dl_w / dl_w.sum()
    arrivals: List[Arrival] = []
    t = 0.0
    n = cfg.n_requests
    for i in range(n):
        # linear rate ramp across the run; gap ~ Exp(rate_i)
        frac = i / max(n - 1, 1)
        rate = cfg.rate_qps * (1.0 + (cfg.ramp - 1.0) * frac)
        t += float(rng.exponential(1.0 / rate))
        pid = int(rng.choice(cfg.pool_size, p=probs))
        arrivals.append(Arrival(
            t=t, pool_id=pid, prompt=pool[pid],
            max_new_tokens=int(rng.choice(cfg.max_new_tokens_choices)),
            deadline_s=dl_vals[int(rng.choice(len(dl_vals), p=dl_w))]))
    return arrivals


def _pctl(xs: List[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


def run_load(engine: DecodeEngine, arrivals: List[Arrival], *,
             max_wall_s: float = 300.0) -> dict:
    """Replay ``arrivals`` open-loop against ``engine``; returns the
    summary dict (and annotates each Arrival with its Request / shed flag).

    The loop submits every due arrival, then steps the engine if it has
    work; between the last submit and the next arrival it sleeps in short
    slices instead of busy-spinning. ``max_wall_s`` is a hard safety stop
    for a wedged engine — a truncated run still summarizes what completed.
    """
    t0 = time.perf_counter()
    i, n = 0, len(arrivals)
    tier_steps: dict = {}
    while True:
        now = time.perf_counter() - t0
        while i < n and arrivals[i].t <= now:
            a = arrivals[i]
            req = engine.submit(a.prompt, max_new_tokens=a.max_new_tokens,
                                deadline_s=a.deadline_s)
            a.request, a.shed = req, req is None
            i += 1
        if engine.queue or engine.active.any():
            engine.step()
            tier_steps[engine.tier] = tier_steps.get(engine.tier, 0) + 1
        elif i < n:
            gap = arrivals[i].t - (time.perf_counter() - t0)
            if gap > 0:
                time.sleep(min(gap, 0.005))
        else:
            break
        if time.perf_counter() - t0 > max_wall_s:
            break
    wall_s = time.perf_counter() - t0
    return summarize(engine, arrivals, wall_s, tier_steps)


def summarize(engine: DecodeEngine, arrivals: List[Arrival], wall_s: float,
              tier_steps: dict) -> dict:
    """Aggregate one replay into the BENCH_serve-shaped summary record."""
    submitted = [a for a in arrivals if a.request is not None]
    completed = [a for a in submitted
                 if a.request.t_done > 0 and not a.request.expired]
    expired = [a for a in submitted if a.request.expired]
    shed = sum(a.shed for a in arrivals)
    lat = [a.request.t_done - a.request.t_submit for a in completed]
    wait = [a.request.t_admit - a.request.t_submit for a in submitted
            if a.request.t_admit > 0]
    total_steps = sum(tier_steps.values())
    n = len(arrivals)
    out = {
        "requests": n,
        "wall_s": wall_s,
        "completed": len(completed),
        "queries_per_s": len(completed) / wall_s if wall_s > 0 else 0.0,
        "decoded_tokens": int(sum(len(a.request.out_tokens) - 1
                                  for a in completed)),
        "latency_p50_s": _pctl(lat, 50), "latency_p99_s": _pctl(lat, 99),
        "queue_wait_p50_s": _pctl(wait, 50),
        "queue_wait_p99_s": _pctl(wait, 99),
        "shed_frac": shed / n,
        "expired_frac": len(expired) / n,
        "stepdowns": engine.stepdowns, "stepups": engine.stepups,
        "max_tier": max(tier_steps) if tier_steps else 0,
        "tier_occupancy": {str(t): c / total_steps
                           for t, c in sorted(tier_steps.items())}
        if total_steps else {},
        "final_state": engine.health()["state"],
    }
    if engine.qcache is not None:
        out["cache"] = engine.qcache.stats()
    return out
