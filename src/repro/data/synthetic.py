"""Deterministic synthetic data.

Two families:
1. Vector corpora for ProMIPS (shape-matched proxies of the paper's four
   datasets — Netflix/Yahoo PureSVD MF factors, P53 wide biology vectors,
   Sift descriptors). MF-style generators produce realistic low-effective-
   rank structure and long-tail norms (the regime the paper's conditions
   and our norm-adaptive extensions are sensitive to).
2. Token streams for LM training — stateless, seeded by (seed, step, host)
   so restarts and straggler data-skips are deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def mf_factors(n: int, d: int, rank: int, *, decay: float = 0.3, seed: int = 0,
               norm_tail: float = 0.0) -> np.ndarray:
    """PureSVD-style latent factors: U diag(s) V with decaying spectrum.
    ``norm_tail`` > 0 adds a lognormal per-point scale (long-tail norms)."""
    rng = np.random.RandomState(seed)
    u = rng.standard_normal((n, rank))
    v = rng.standard_normal((rank, d))
    spec = np.exp(-decay * np.arange(rank))
    x = (u * spec) @ v
    if norm_tail > 0:
        x *= rng.lognormal(0.0, norm_tail, size=(n, 1))
    return x.astype(np.float32)


# Paper Table III proxies (scaled_* hold the CPU-budget sizes used by the
# benchmark harness; full sizes recorded for the report).
DATASETS = {
    "netflix": dict(n=17770, d=300, rank=32, decay=0.15, norm_tail=0.3, scaled_n=17770),
    "yahoo": dict(n=624961, d=300, rank=32, decay=0.15, norm_tail=0.3, scaled_n=100000),
    "p53": dict(n=31420, d=5408, rank=64, decay=0.08, norm_tail=0.2, scaled_n=8000),
    "sift": dict(n=11164866, d=128, rank=48, decay=0.05, norm_tail=0.15, scaled_n=200000),
}


def paper_dataset(name: str, *, scaled: bool = True, seed: int = 0):
    cfg = DATASETS[name]
    n = cfg["scaled_n"] if scaled else cfg["n"]
    x = mf_factors(n, cfg["d"], cfg["rank"], decay=cfg["decay"],
                   norm_tail=cfg["norm_tail"], seed=seed)
    if name == "sift":
        x = np.abs(x)  # SIFT descriptors are non-negative
    return x


def paper_queries(name: str, n_queries: int = 100, *, seed: int = 1):
    cfg = DATASETS[name]
    q = mf_factors(n_queries, cfg["d"], cfg["rank"], decay=cfg["decay"], seed=seed)
    if name == "sift":
        q = np.abs(q)
    return q


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TokenStream:
    """Deterministic zipf-ish token stream; batch(step) is pure in (seed, step)."""
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.3

    def batch_at(self, step: int, host: int = 0, n_hosts: int = 1):
        rng = np.random.RandomState((self.seed * 1_000_003 + step * 97 + host) % 2**31)
        b_local = self.batch // n_hosts
        raw = rng.zipf(self.zipf_a, size=(b_local, self.seq + 1))
        tokens = (raw % (self.vocab - 1)).astype(np.int32) + 1
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:].copy()}


def lm_batch(cfg, shape, step: int = 0, seed: int = 0):
    """Concrete batch for one (arch, shape) cell (smoke/benchmark scale)."""
    stream = TokenStream(vocab=cfg.vocab, batch=shape.global_batch, seq=shape.seq_len, seed=seed)
    batch = stream.batch_at(step)
    if cfg.frontend == "vision":
        rng = np.random.RandomState(seed + 7)
        batch["patches"] = rng.standard_normal(
            (shape.global_batch, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        batch["labels"] = batch["labels"]
    if cfg.frontend == "audio":
        rng = np.random.RandomState(seed + 11)
        batch["frames"] = rng.standard_normal(
            (shape.global_batch, cfg.frontend_len, cfg.d_model)).astype(np.float32)
    return batch
