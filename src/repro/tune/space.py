"""Declared parameter space of the offline autotuner (DESIGN.md §15).

Every hardware knob the search runtime exposes — as opposed to the
STATISTICAL knobs the paper derives (m*, x_p, Theorem-2 budgets), which the
tuner never touches — is declared here once, with its legal range, the
hand-picked default the codebase shipped with before the tuner existed, and
the section of the tuning-cache entry it lands in:

  runtime   per-search `RuntimeConfig` knobs (no rebuild needed)
  build     `api.build` / `build_index` knobs (changing one rebuilds)
  serve     `serve.engine.DecodeEngine` knobs

Cache entries are keyed by `shape_key(n, d)` = the pow2 n-bucket, exact d,
jax platform and jax version — the four things that change which config
wins (`results/tune/tuning.json`; see `tune.cache`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple


def n_bucket(n: int) -> int:
    """pow2 bucketing of the corpus size (same quantizer as the fused tile
    shapes): a tuned entry covers every n in (bucket/2, bucket]."""
    return 1 << max(0, int(n) - 1).bit_length()


def shape_key(n: int, d: int, platform: Optional[str] = None,
              jax_version: Optional[str] = None) -> str:
    """Cache key for one tuning point. Platform/version default to the
    CURRENT process's jax backend — a cache tuned on another box or jax
    build simply never matches, falling back to the hand-picked defaults."""
    if platform is None or jax_version is None:
        import jax
        platform = platform or jax.default_backend()
        jax_version = jax_version or jax.__version__
    return f"n{n_bucket(n)}:d{int(d)}:{platform}:jax{jax_version}"


@dataclass(frozen=True)
class Knob:
    """One tunable knob: its cache section, hand-picked default, and the
    candidate values the coordinate-descent search tries (a () candidates
    tuple means the candidates are derived per point at tune time, e.g.
    ``tile_cap`` from the observed union sizes)."""

    name: str
    section: str                 # "runtime" | "build" | "serve"
    default: Any
    candidates: Tuple[Any, ...]
    description: str


KNOBS: Tuple[Knob, ...] = (
    Knob("verification", "runtime", "fused", ("fused", "batched"),
         "candidate-scoring backend (bit-identical results at every budget)"),
    Knob("dense_frac", "runtime", 0.9, (0.5, 0.7, 0.8, 0.9, 1.0),
         "union fraction above which the fused tile is every block in place "
         "(dense and sparse tiles are result-bit-identical)"),
    Knob("tile_cap", "runtime", None, (),
         "extra clamp on both fused rounds' tile sizes below the budget "
         "rule; candidates derived from the observed union sizes (an exact-"
         "fit cap removes the next_pow2 padding)"),
    Knob("prefilter_eps", "runtime", 1.0, (0.05, 0.08, 0.1, 0.15, 0.2),
         "quantized-sketch bound scale; 1.0 is lossless, smaller prunes "
         "harder (only tuned when the workload runs with prefilter=True)"),
    Knob("page_bytes", "build", 4096, (2048, 4096, 8192),
         "block page size -> page_rows geometry (requires rebuild)"),
    Knob("max_probe_groups", "build", None, (256, 512, 1024),
         "cap on the Quick-Probe group table (None = all distinct sign "
         "codes; dropping groups is conservative — the probe still returns "
         "a valid point — but weakens r0; requires rebuild)"),
    Knob("decode_batch_slots", "serve", 4, (2, 4, 8),
         "serve-engine decode batch slots (continuous-batching width)"),
    Knob("result_cache_size", "serve", 256, (0, 64, 256, 1024),
         "LRU hot-query result-cache capacity for the decode search "
         "(serve/qcache.py; 0 disables — cold traffic is bit-identical "
         "either way, so the knob only trades memory for Zipfian hit rate)"),
    Knob("max_refill_per_step", "serve", None, (1, 2, 4),
         "cap on requests admitted per engine step (None = refill every "
         "free slot; lower bounds the per-step prefill burst at the cost "
         "of queue wait)"),
)

# The pre-tuner defaults, by cache section: `tune.cache.resolved` overlays a
# tuned entry on top of this dict, so a missing cache / missing key / partial
# entry always resolves to EXACTLY the hand-picked behavior (the bit-identity
# fallback tests/test_tune.py pins).
HAND_PICKED = {
    "runtime": {"verification": "fused", "dense_frac": 0.9, "tile_cap": None,
                "prefilter_eps": 1.0},
    "build": {"page_bytes": 4096, "max_probe_groups": None},
    "serve": {"decode_batch_slots": 4, "result_cache_size": 256,
              "max_refill_per_step": None},
}


def knob(name: str) -> Knob:
    for k in KNOBS:
        if k.name == name:
            return k
    raise KeyError(f"unknown knob: {name!r}")


__all__ = ["Knob", "KNOBS", "HAND_PICKED", "knob", "n_bucket", "shape_key"]
