"""Tuning strategy: budgeted coordinate descent with a parity gate.

One knob at a time, in a fixed order (verification backend, dense_frac,
tile_cap, prefilter_eps, then the rebuild-requiring build knobs when
enabled), each candidate measured against the INCUMBENT config with the
interleaved median-of-adjacent-pairs protocol (`cutout.interleaved_ratio`)
and accepted only when it is faster by more than the noise margin.

Every candidate must first pass the PARITY GATE: its (ids, scores) on the
cutout workload must be bitwise identical to the hand-picked baseline's.
A candidate that changes results — a lossy ``prefilter_eps``, a truncating
``tile_cap``, a ``page_bytes`` that moves the block geometry — is recorded
in the trace with ``status: "rejected_parity"`` and never shipped, so a
tuned cache can only ever change WHERE time goes, not what comes back
(the tuned-vs-default parity test in tests/test_tune.py, and the ci.sh
guard, both lean on this). The warm-up/compile run doubles as the parity
check, so the gate costs nothing extra.

The whole descent is capped in measured seconds (``budget_s``): when the
budget runs out, remaining candidates are recorded ``skipped_budget``
instead of silently dropped. If nothing beats the baseline, the entry
honestly ships the hand-picked values with the measured ~1.0 ratios in its
trace — "already on the frontier" is a valid tuning result.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import numpy as np

from ..core.promips import ProMIPS
from ..core.search_common import next_pow2
from . import cache as _cache
from . import cutout as _cutout
from . import space as _space

# accept a candidate only when the incumbent/candidate time ratio clears
# this margin — below it, host wall-clock jitter wins coin flips
ACCEPT_MARGIN = 0.02


def _search_fn(pm: ProMIPS, queries, opts: dict, knobs: dict):
    """Zero-arg search closure for one (workload opts, tuned knobs) pair.
    Every tuned knob is passed EXPLICITLY (tile_cap's "no cap" is
    n_blocks), so the measurement never consults the cache being written."""
    tile_cap = knobs["tile_cap"]
    if tile_cap is None:
        tile_cap = pm.meta.n_blocks
    eps = knobs["prefilter_eps"] if opts.get("prefilter") else 1.0

    def call():
        return pm.search(
            queries, k=opts.get("k", 10), budget=opts.get("budget"),
            budget2=opts.get("budget2"),
            norm_adaptive=opts.get("norm_adaptive", False),
            cs_prune=opts.get("cs_prune", False),
            verification=knobs["verification"],
            prefilter=opts.get("prefilter", False), prefilter_eps=eps,
            dense_frac=knobs["dense_frac"], tile_cap=int(tile_cap))
    return call


def _result_parity(res_a, res_b) -> bool:
    ids_a, scores_a, _ = res_a
    ids_b, scores_b, _ = res_b
    return (np.array_equal(np.asarray(ids_a), np.asarray(ids_b))
            and np.array_equal(np.asarray(scores_a), np.asarray(scores_b)))


def _candidate_roofline(pm: ProMIPS, queries, opts: dict, knobs: dict,
                        measured_s: float) -> dict:
    """Static roofline bound of the candidate's full in-graph search next
    to its measured time (best-effort: cost_analysis can be unavailable)."""
    from ..core import runtime as rt
    from ..launch.roofline import kernel_cost
    try:
        tile_cap = knobs["tile_cap"]
        cfg = rt.RuntimeConfig(
            k=opts.get("k", 10), budget=opts.get("budget"),
            budget2=opts.get("budget2"),
            norm_adaptive=opts.get("norm_adaptive", False),
            cs_prune=opts.get("cs_prune", False),
            verification=knobs["verification"],
            prefilter=opts.get("prefilter", False),
            prefilter_eps=(knobs["prefilter_eps"] if opts.get("prefilter")
                           else 1.0),
            dense_frac=knobs["dense_frac"],
            tile_cap=int(tile_cap) if tile_cap is not None
            else pm.meta.n_blocks)
        qj = jax.numpy.asarray(queries, jax.numpy.float32)
        fn = jax.jit(lambda a, q: rt.search(a, pm.meta, q, cfg))
        cost = kernel_cost(fn, pm.arrays, qj)
        return {"roofline_s": cost["roofline_s"], "bound": cost["bound"],
                "flops": cost["flops"], "bytes": cost["bytes"],
                "roofline_frac": cost["roofline_s"] / max(measured_s, 1e-12)}
    except Exception as e:
        return {"roofline_error": f"{type(e).__name__}: {e}"}


def _tile_cap_candidates(pm: ProMIPS, queries, opts: dict) -> list:
    """Derived per point: the exact round-1 union (removes the pow2
    padding) and a 25%-headroom variant, when they undercut the bucketed
    tile the default rule would pick."""
    u1 = _cutout.round1_union(
        pm.arrays, pm.meta, queries, k=opts.get("k", 10),
        prefilter=opts.get("prefilter", False),
        prefilter_eps=opts.get("prefilter_eps", 1.0))
    if u1 == 0:
        return []
    default_tile = min(next_pow2(u1), pm.meta.n_blocks)
    cands = sorted({u1, min(-(-u1 * 5 // 4), pm.meta.n_blocks)})
    return [c for c in cands if c < default_tile]


def tune_point(x: np.ndarray, queries: np.ndarray, *, build_opts: dict,
               search_opts: dict, budget_s: float = 60.0, reps: int = 5,
               include_build: bool = False, stages: bool = True,
               roofline: bool = True, write: bool = False,
               path: Optional[str] = None, progress=None) -> dict:
    """Tune one ``(n, d)`` point; returns the cache-entry-shaped record.

    ``build_opts`` go to `ProMIPS.build`; ``search_opts`` fix the workload
    (k, budgets, norm_adaptive, cs_prune, prefilter, prefilter_eps) — the
    statistical contract is never tuned, only the hardware knobs declared
    in `tune.space`. The baseline is the hand-picked config; the returned
    entry's ``runtime`` section is the coordinate-descent winner (== the
    baseline when nothing beats it) and its ``trace`` carries every
    candidate's measured ratio, parity verdict and roofline fraction.
    ``write=True`` saves the entry via `cache.save_entry`.
    """
    say = progress if progress is not None else (lambda *_: None)
    t_start = time.monotonic()
    n, d = int(x.shape[0]), int(x.shape[1])

    pm = ProMIPS.build(x, **build_opts)
    opts = dict(search_opts)
    baseline = dict(_space.HAND_PICKED["runtime"])
    baseline["prefilter_eps"] = float(opts.get("prefilter_eps", 1.0))

    best = dict(baseline)
    fn_best = _search_fn(pm, queries, opts, best)
    ref = fn_best()                       # compile + parity reference
    jax.block_until_ready(ref[1])
    n_q = max(int(np.atleast_2d(queries).shape[0]), 1)
    t_base = _cutout.time_call(fn_best, reps=reps, warmup=0)
    trace: list = []

    def out_of_budget() -> bool:
        return time.monotonic() - t_start > budget_s

    def try_candidate(name: str, value, make_fn):
        rec = {"knob": name, "value": value, "incumbent": best.get(name)}
        if out_of_budget():
            rec["status"] = "skipped_budget"
            trace.append(rec)
            return None
        fn_c = make_fn()
        try:
            res_c = fn_c()                # compile; doubles as parity check
            jax.block_until_ready(res_c[1])
        except Exception as e:
            rec["status"] = f"error: {type(e).__name__}: {e}"
            trace.append(rec)
            return None
        if not _result_parity(ref, res_c):
            rec["status"] = "rejected_parity"
            trace.append(rec)
            say(f"  {name}={value!r}: rejected (changes results)")
            return None
        t_inc, t_cand, ratio = _cutout.interleaved_ratio(fn_best, fn_c, reps)
        rec.update(incumbent_us_per_query=t_inc * 1e6 / n_q,
                   candidate_us_per_query=t_cand * 1e6 / n_q,
                   ratio_incumbent_over_candidate=ratio)
        if roofline:
            rec.update(_candidate_roofline(pm, queries, opts,
                                           {**best, name: value}, t_cand))
        accepted = ratio > 1.0 + ACCEPT_MARGIN
        rec["status"] = "accepted" if accepted else "rejected_slower"
        trace.append(rec)
        say(f"  {name}={value!r}: x{ratio:.3f} "
            f"({'accepted' if accepted else 'kept incumbent'})")
        return fn_c if accepted else None

    # -- coordinate descent over the runtime knobs --------------------------
    say(f"tuning ({n}, {d}) runtime knobs, budget {budget_s:.0f}s")
    for name in ("verification", "dense_frac", "tile_cap", "prefilter_eps"):
        if name == "prefilter_eps" and not opts.get("prefilter"):
            continue
        if (name in ("dense_frac", "tile_cap")
                and best["verification"] != "fused"):
            # fused-only tile knobs: measuring them against a non-fused
            # incumbent would accept pure wall-clock noise
            trace.append({"knob": name, "status": "skipped_not_fused"})
            continue
        if name == "tile_cap":
            cands = _tile_cap_candidates(pm, queries, opts)
        else:
            cands = [c for c in _space.knob(name).candidates
                     if c != best[name]]
        for value in cands:
            won = try_candidate(
                name, value,
                lambda v=value: _search_fn(pm, queries, opts,
                                           {**best, name: v}))
            if won is not None:
                best[name] = value
                fn_best = won

    # -- build knobs (rebuild per candidate; smoke/CLI only by default) -----
    build_best = dict(_space.HAND_PICKED["build"])
    if "page_bytes" in build_opts:
        build_best["page_bytes"] = int(build_opts["page_bytes"])
    if include_build:
        for name in ("page_bytes", "max_probe_groups"):
            for value in [c for c in _space.knob(name).candidates
                          if c != build_best[name]]:
                def rebuild(v=value, knob_name=name):
                    pm2 = ProMIPS.build(x, **{**build_opts, knob_name: v})
                    return _search_fn(pm2, queries, opts, best)
                won = try_candidate(name, value, rebuild)
                if won is not None:
                    build_best[name] = value
                    fn_best = won
    else:
        trace.append({"knob": "build", "status": "skipped_disabled",
                      "note": "rebuild-per-candidate tuning off "
                              "(include_build=False)"})

    t_best = _cutout.time_call(fn_best, reps=reps, warmup=0)
    summary = {
        "n": n, "d": d, "n_blocks": int(pm.meta.n_blocks),
        "baseline": baseline, "workload": opts,
        "baseline_us_per_query": t_base * 1e6 / n_q,
        "best_us_per_query": t_best * 1e6 / n_q,
        "speedup_tuned_vs_default": t_base / max(t_best, 1e-12),
        "budget_s": budget_s, "elapsed_s": time.monotonic() - t_start,
        "n_candidates": sum(1 for r in trace if "knob" in r
                            and "ratio_incumbent_over_candidate" in r),
    }
    entry_trace = {"summary": summary, "candidates": trace}
    if stages:
        tc = best["tile_cap"]
        entry_trace["stages_best"] = _cutout.stage_records(
            pm.arrays, pm.meta, queries, k=opts.get("k", 10),
            prefilter=opts.get("prefilter", False),
            prefilter_eps=(best["prefilter_eps"] if opts.get("prefilter")
                           else 1.0),
            dense_frac=best["dense_frac"],
            tile_cap=int(tc) if tc is not None else None, reps=reps)
    say(f"tuned ({n}, {d}): x{summary['speedup_tuned_vs_default']:.3f} "
        f"vs hand-picked in {summary['elapsed_s']:.1f}s "
        f"({summary['n_candidates']} candidates measured)")

    entry = {"runtime": best, "build": build_best, "trace": entry_trace}
    if write:
        key = _cache.save_entry(n, d, runtime=best, build=build_best,
                                trace=entry_trace, path=path)
        entry["key"] = key
    return entry


__all__ = ["tune_point", "ACCEPT_MARGIN"]
