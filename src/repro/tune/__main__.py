"""CLI: tune one (n, d) point and optionally write the cache entry.

Defaults match the LARGE_N benchmark point (benchmarks/paper_figures.py),
so the committed `results/tune/tuning.json` entry covers the shape the
--quick perf guard runs at:

  PYTHONPATH=src python -m repro.tune --prefilter --budget-s 120 --write
"""
from __future__ import annotations

import argparse
import json

from . import cutout, search


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--n-q", type=int, default=64)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--decay", type=float, default=0.5)
    ap.add_argument("--norm-tail", type=float, default=0.6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--k-p", type=int, default=8)
    ap.add_argument("--k-sp", type=int, default=8)
    ap.add_argument("--norm-strata", type=int, default=8)
    ap.add_argument("--c", type=float, default=0.9)
    ap.add_argument("--p", type=float, default=0.6)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--prefilter", action="store_true")
    ap.add_argument("--prefilter-eps", type=float, default=0.1)
    ap.add_argument("--budget-s", type=float, default=120.0)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--include-build", action="store_true",
                    help="also tune rebuild-requiring knobs (page_bytes, "
                         "max_probe_groups) — one index rebuild per "
                         "candidate")
    ap.add_argument("--write", action="store_true",
                    help="save the entry to the tuning cache "
                         "(results/tune/tuning.json or $REPRO_TUNE_CACHE)")
    ap.add_argument("--out", default=None,
                    help="explicit cache path (implies --write)")
    args = ap.parse_args()

    x, q = cutout.make_cutout(args.n, args.d, args.n_q, rank=args.rank,
                              decay=args.decay, norm_tail=args.norm_tail,
                              seed=args.seed)
    build_opts = dict(m=args.m, c=args.c, p=args.p, k_p=args.k_p,
                      k_sp=args.k_sp, norm_strata=args.norm_strata,
                      seed=args.seed)
    search_opts = dict(k=args.k, norm_adaptive=True, cs_prune=True,
                       prefilter=args.prefilter,
                       prefilter_eps=args.prefilter_eps)
    entry = search.tune_point(
        x, q, build_opts=build_opts, search_opts=search_opts,
        budget_s=args.budget_s, reps=args.reps,
        include_build=args.include_build,
        write=args.write or args.out is not None, path=args.out,
        progress=print)
    print(json.dumps({"runtime": entry["runtime"],
                      "build": entry["build"],
                      "summary": entry["trace"]["summary"]}, indent=1))


if __name__ == "__main__":
    main()
