"""Tuning cache: measured winners, consulted by default (DESIGN.md §15).

One JSON file (default ``results/tune/tuning.json`` at the repo root) maps
`space.shape_key(n, d)` keys to entries:

  {"version": 1,
   "entries": {
     "n131072:d128:cpu:jax0.x.y": {
       "key": {"n_bucket": ..., "d": ..., "platform": ..., "jax_version": ...},
       "provenance": {"commit": <git sha>, "ts": <utc iso>, ...},
       "runtime": {"verification": ..., "dense_frac": ..., "tile_cap": ...,
                   "prefilter_eps": ...},
       "build":   {"page_bytes": ..., "max_probe_groups": ...},
       "serve":   {"decode_batch_slots": ...},
       "trace":   [per-candidate tuning measurements]}}}

`core.runtime.search`, `api.build` and `serve.engine` consult `resolved()`
whenever the caller left a promoted knob at its ``None`` sentinel; explicit
kwargs never reach this module. A missing file, missing key, or unknown
field resolves to `space.HAND_PICKED` — bit-identical to the pre-tuner
behavior. The env var ``REPRO_TUNE_CACHE`` overrides the path (set it to
the empty string to disable lookups entirely — what CI's empty-cache guard
and the fallback tests use).

Reads are memoized on (path, mtime, size): the steady-state per-search cost
is one `os.stat`, noise next to a single device dispatch.
"""
from __future__ import annotations

import json
import os
import subprocess
from datetime import datetime, timezone
from typing import Any, Dict, Optional

from . import space

ENV_VAR = "REPRO_TUNE_CACHE"
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
DEFAULT_PATH = os.path.join(_REPO_ROOT, "results", "tune", "tuning.json")

_memo: Dict[str, tuple] = {}


def cache_path() -> Optional[str]:
    """Active cache path, or None when lookups are disabled
    (``REPRO_TUNE_CACHE=""``)."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        return env or None
    return DEFAULT_PATH


def clear_memo() -> None:
    """Drop the mtime memo (tests that rewrite the cache in-place within
    one mtime granule call this; normal writers go through `save_entry`,
    which clears it automatically)."""
    _memo.clear()


def load(path: Optional[str] = None) -> dict:
    """Parsed cache contents ({} when absent/disabled/corrupt — a broken
    cache must never break a search, only lose its tuned values)."""
    if path is None:
        path = cache_path()
    if not path:
        return {}
    try:
        st = os.stat(path)
    except OSError:
        return {}
    stamp = (st.st_mtime_ns, st.st_size)
    hit = _memo.get(path)
    if hit is not None and hit[0] == stamp:
        return hit[1]
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        data = {}
    if not isinstance(data, dict):
        data = {}
    _memo[path] = (stamp, data)
    return data


def lookup(n: int, d: int, path: Optional[str] = None) -> Optional[dict]:
    """The full tuned entry for this point's shape key, or None."""
    entries = load(path).get("entries")
    if not isinstance(entries, dict):
        return None
    entry = entries.get(space.shape_key(n, d))
    return entry if isinstance(entry, dict) else None


def resolved(section: str, n: int, d: int,
             path: Optional[str] = None) -> Dict[str, Any]:
    """Hand-picked defaults for ``section`` overlaid with the tuned entry
    for this point (only knobs declared in `space.HAND_PICKED[section]` are
    taken from the entry — a cache written by a newer revision cannot
    smuggle unknown knobs in)."""
    out = dict(space.HAND_PICKED[section])
    entry = lookup(n, d, path)
    if entry:
        tuned = entry.get(section)
        if isinstance(tuned, dict):
            out.update({k: v for k, v in tuned.items() if k in out})
    return out


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def save_entry(n: int, d: int, *, runtime: Optional[dict] = None,
               build: Optional[dict] = None, serve: Optional[dict] = None,
               trace: Optional[list] = None,
               path: Optional[str] = None) -> str:
    """Write/replace the entry for this point's shape key (atomic rename),
    stamped with git-SHA provenance like `results/bench/history.jsonl`
    records. Returns the shape key written."""
    if path is None:
        path = cache_path() or DEFAULT_PATH
    import jax
    key = space.shape_key(n, d)
    data = load(path)
    data.setdefault("version", 1)
    entries = data.setdefault("entries", {})
    entry: Dict[str, Any] = {
        "key": {"n_bucket": space.n_bucket(n), "d": int(d),
                "platform": jax.default_backend(),
                "jax_version": jax.__version__},
        "provenance": {
            "commit": _git_sha(),
            "ts": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        },
    }
    for name, section in (("runtime", runtime), ("build", build),
                          ("serve", serve)):
        if section is not None:
            entry[name] = dict(section)
    if trace is not None:
        entry["trace"] = trace
    entries[key] = entry
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    _memo.pop(path, None)
    return key


__all__ = ["ENV_VAR", "DEFAULT_PATH", "cache_path", "clear_memo", "load",
           "lookup", "resolved", "save_entry"]
