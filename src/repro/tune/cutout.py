"""Cutout runner: isolate and time one search stage at a target point.

The tuner never times a stage inside the full pipeline — each stage is cut
out and driven alone on deterministic synthetic data matching the target
``(n, d)`` point (`make_cutout`, the same `mf_factors` family every
benchmark corpus uses), with the interleaved median-of-adjacent-pairs
protocol `benchmarks/run.py` uses for A/B comparisons
(`interleaved_ratio`): candidate and incumbent alternate within one
session, and the reported ratio is the MEDIAN over adjacent pairs, so a
background-noise spike inflates one pair instead of poisoning a whole
arm's mean.

`stage_records` reports, per stage, the measured wall-clock next to
`launch/roofline.kernel_cost`'s compile-time bound and their ratio
(``roofline_frac``). The bound uses the v5e constants and sums every
lax.switch branch (it is flagged ``static_upper_bound``) — on the CPU
container the fraction is a normalization for comparing candidates, not an
achieved-MFU claim; on TPU it approaches the real roofline gap.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import runtime as _runtime
from ..core import search_fused as sf
from ..core.index import IndexArrays, IndexMeta
from ..data.synthetic import mf_factors
from ..launch.roofline import kernel_cost


def make_cutout(n: int, d: int, n_q: int = 64, *, rank: int = 16,
                decay: float = 0.5, norm_tail: float = 0.6, seed: int = 0):
    """Deterministic synthetic (corpus, queries) for one tuning point —
    the same MF-factor family (and, at the default kwargs, the same seeds
    0/1 convention) as the benchmark corpora, so a LARGE_N cutout is the
    LARGE_N bench workload. Bit-reproducible under a fixed ``seed``
    (pinned by tests/test_tune.py)."""
    x = mf_factors(n, d, rank, decay=decay, seed=seed, norm_tail=norm_tail)
    q = mf_factors(n_q, d, rank, decay=decay, seed=seed + 1)
    return x, q


def _block(v):
    jax.block_until_ready(v)
    return v


def time_call(fn, *args, reps: int = 5, warmup: int = 1) -> float:
    """Median wall-clock seconds of ``fn(*args)`` over ``reps`` fenced
    calls (after ``warmup`` compile/cache-warming calls)."""
    for _ in range(max(warmup, 0)):
        _block(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def interleaved_ratio(fn_a, fn_b, reps: int = 5):
    """(median_t_a, median_t_b, median per-pair t_a/t_b) over ``reps``
    interleaved A/B pairs — host wall clock jitters ±20% on this container,
    so comparisons are made within adjacent pairs, never across sessions.
    Callers warm both arms (compile) before measuring."""
    ta, tb, ratios = [], [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(fn_a())
        t1 = time.perf_counter()
        _block(fn_b())
        t2 = time.perf_counter()
        ta.append(t1 - t0)
        tb.append(t2 - t1)
        ratios.append((t1 - t0) / max(t2 - t1, 1e-12))
    return (float(np.median(ta)), float(np.median(tb)),
            float(np.median(ratios)))


def round1_masks(arrays: IndexArrays, meta: IndexMeta, queries, *,
                 k: int = 10, prefilter: bool = False,
                 prefilter_eps: float = 1.0,
                 use_pallas: Optional[bool] = None):
    """(frontend outputs, round-1 (B, NB) mask after the optional
    prefilter) — the selection the round-1 tile is planned from."""
    qj = jnp.asarray(queries, jnp.float32)
    front = sf._frontend(arrays, meta, qj)
    mask0 = front[6]
    mask_r1 = mask0
    if prefilter and meta.sk_subspaces:
        mask_r1 = sf._prefilter1(arrays, qj, mask0, k, meta.page_rows,
                                 prefilter_eps, use_pallas)[0]
    return front, mask_r1


def round1_union(arrays: IndexArrays, meta: IndexMeta, queries, *,
                 k: int = 10, prefilter: bool = False,
                 prefilter_eps: float = 1.0,
                 use_pallas: Optional[bool] = None) -> int:
    """Number of distinct blocks the round-1 batch union selects — what
    the tile-cap candidate derivation keys off (an exact-fit cap removes
    the next_pow2 padding without truncating anything)."""
    _, mask_r1 = round1_masks(arrays, meta, queries, k=k,
                              prefilter=prefilter,
                              prefilter_eps=prefilter_eps,
                              use_pallas=use_pallas)
    return int(np.asarray(mask_r1).any(axis=0).sum())


def stage_records(arrays: IndexArrays, meta: IndexMeta, queries, *,
                  k: int = 10, prefilter: bool = False,
                  prefilter_eps: float = 1.0, dense_frac: float = 0.9,
                  tile_cap: Optional[int] = None,
                  use_pallas: Optional[bool] = None, reps: int = 5) -> dict:
    """Isolated per-stage timings at one point, against the static roofline
    bound. Stages mirror the host fused driver: `select_frontend`, the
    optional sketch prefilter, one planned fused verification tile, and the
    shared top-k rescore/merge. Returns {stage: {us, flops, bytes,
    roofline_s, roofline_frac, ...}} plus a ``_tile`` record describing the
    planned round-1 tile (union, slots, dense)."""
    qj = jnp.asarray(queries, jnp.float32)
    n_batch = int(qj.shape[0])
    recs: dict = {}

    def rec(name, fn, *args):
        us = time_call(fn, *args, reps=reps) * 1e6
        entry = {"us": us, "us_per_query": us / max(n_batch, 1)}
        try:
            entry.update(kernel_cost(fn, *args))
            entry["roofline_frac"] = entry["roofline_s"] / max(us * 1e-6,
                                                               1e-12)
        except Exception as e:  # cost_analysis is best-effort, never fatal
            entry["cost_error"] = f"{type(e).__name__}: {e}"
        recs[name] = entry

    rec("select_frontend", sf._frontend, arrays, meta, qj)
    front = sf._frontend(arrays, meta, qj)
    c_half, mask0 = front[5], front[6]
    mask_r1 = mask0
    if prefilter and meta.sk_subspaces:
        rec("prefilter_round1", sf._prefilter1, arrays, qj, mask0, k,
            meta.page_rows, prefilter_eps, use_pallas)
        mask_r1 = sf._prefilter1(arrays, qj, mask0, k, meta.page_rows,
                                 prefilter_eps, use_pallas)[0]

    cap = meta.n_blocks if tile_cap is None else min(int(tile_cap),
                                                     meta.n_blocks)
    plan = sf._plan_tile(np.asarray(mask_r1), cap, meta.n_blocks, dense_frac)
    top = sf.TopK(scores=jnp.full((n_batch, k), -jnp.inf, jnp.float32),
                  rows=jnp.full((n_batch, k), -1, jnp.int32))
    if plan is not None:
        slots, sel, _, dense = plan
        recs["_tile"] = {"n_union": int(np.asarray(mask_r1).any(0).sum()),
                         "tile_slots": int(len(slots)), "dense": bool(dense)}
        rec("fused_verify_tile", sf._verify, arrays, qj, jnp.asarray(slots),
            jnp.asarray(sel), top.scores, top.rows, c_half, k,
            meta.page_rows, dense, use_pallas, False)
        top = sf._verify(arrays, qj, jnp.asarray(slots), jnp.asarray(sel),
                         top.scores, top.rows, c_half, k, meta.page_rows,
                         dense, use_pallas, False)[0]
    rec("topk_rescore", _runtime._rescore, arrays.x, top.rows, qj)
    return recs


__all__ = ["make_cutout", "time_call", "interleaved_ratio", "round1_masks",
           "round1_union", "stage_records"]
