"""Roofline-driven offline autotuner (DESIGN.md §15).

Makes the search runtime's HARDWARE knobs — the fused tile's dense-path
threshold and cap, the verification backend, the sketch-prefilter eps, the
page geometry, the Quick-Probe group count, the serve decode batch — self-
optimizing: `tune.search.tune_point` measures candidates on stage cutouts
and full-search A/B pairs (`tune.cutout`), gates every candidate on bitwise
result parity, and records winners in `results/tune/tuning.json`
(`tune.cache`) keyed by (n-bucket, d, platform, jax version). The runtime
(`core.runtime.search`), `api.build` and the serve engine consult the cache
by default whenever a promoted knob is left at ``None``; explicit kwargs
always win and a missing key is bit-identical to the hand-picked defaults.

  PYTHONPATH=src python -m repro.tune --n 100000 --d 128 --prefilter \\
      --budget-s 120 --write

This module stays import-light (space + cache only): `core.runtime`
lazy-imports `tune.cache` on the search path, so pulling the measurement
machinery (`cutout`, `search`) in here would create an import cycle and
put benchmark code on the serving path.
"""
from . import cache, space
from .cache import lookup, resolved, save_entry
from .space import HAND_PICKED, KNOBS, shape_key

__all__ = ["cache", "space", "lookup", "resolved", "save_entry",
           "HAND_PICKED", "KNOBS", "shape_key"]
