"""Crash-safe durability + fault injection (DESIGN.md §16).

  wal.py          CRC32-checksummed, length-prefixed write-ahead log over
                  the mutable index's insert/delete/update/compaction ops;
                  `recover()` = last good snapshot + replay, bit-identical
                  to the uncrashed stream.
  snapshot.py     manifest'd (per-file SHA256 + provenance) atomic snapshot
                  directories; `CorruptSnapshotError` fail-fast on load.
  faultpoints.py  named, seeded fault points (`fault.at("wal.append")`)
                  threaded through every durability-critical path so each
                  failure mode is deterministic in tests.
  watchdog.py     the one EWMA step-latency monitor (trainer straggler
                  policy + serve degradation ladder share it).
"""
from .faultpoints import FAULT_POINTS, FaultInjected, FaultInjector, fault
from .snapshot import CorruptSnapshotError, verify_dir, write_atomic_dir
from .wal import (WAL_MAGIC, WalConfig, WalCorruptError, WalRecord,
                  WriteAheadLog, read_records, recover, replay_into)
from .watchdog import EwmaWatchdog

__all__ = [
    "FAULT_POINTS", "FaultInjected", "FaultInjector", "fault",
    "CorruptSnapshotError", "verify_dir", "write_atomic_dir",
    "WAL_MAGIC", "WalConfig", "WalCorruptError", "WalRecord",
    "WriteAheadLog", "read_records", "recover", "replay_into",
    "EwmaWatchdog",
]
