"""Write-ahead log for the mutable index (DESIGN.md §16).

Durability model: the WAL makes `MutableProMIPS` crash-safe *between*
snapshots. Every acknowledged write (insert / delete / update) and every
compaction lifecycle event (begin / commit / abort, positioned exactly at
the freeze / install / abandon points in the op order) is one
length-prefixed, CRC32-checksummed record:

    [u32 payload_len][u32 crc32(payload)][payload]
    payload = [u64 seq][u8 opcode][body]

Recovery = load the last good snapshot (checksummed, atomic — see
`robust/snapshot.py`) + replay every record with ``seq`` greater than the
snapshot's persisted ``wal_seq``. Because every mutation is deterministic
given its record (gids are explicit, `rebuild_base` is seeded and
canonical-ordered) and the compaction markers sit at the exact freeze /
install points, the recovered stream's searches are BIT-IDENTICAL — ids,
scores, every stats field — to the uncrashed stream (property-tested with
a crash at every record boundary in tests/test_robust.py).

A torn final record (crash mid-write) is TRUNCATED, not an error: replay
stops at the last record whose length and CRC both verify, and recovery
trims the file so subsequent appends start clean. Corruption *before* the
tail (a flipped bit in an fsync'd record) is a real integrity failure and
raises `WalCorruptError` — silently dropping acknowledged ops would be a
lie.

``fsync`` policy per `WalConfig`:

    "always"  flush + os.fsync every append — survives power loss
    "os"      flush to the OS page cache every append — survives process
              crash, not power loss (the default: the property the tests
              exercise)
    "never"   library-buffered; flushed on close/checkpoint only
"""
from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from ..obs import metrics as _metrics
from .faultpoints import fault

__all__ = ["WAL_MAGIC", "WalConfig", "WalRecord", "WalCorruptError",
           "WriteAheadLog", "read_records", "recover"]

WAL_MAGIC = b"PWAL0001"
_HDR = struct.Struct("<II")          # payload_len, crc32
_SEQ_OP = struct.Struct("<QB")       # seq, opcode
_U32 = struct.Struct("<I")

_OPCODES = {"insert": 0x49, "delete": 0x44, "update": 0x55,
            "compact_begin": 0x42, "compact_commit": 0x43,
            "compact_abort": 0x41}
_OPNAMES = {v: k for k, v in _OPCODES.items()}
_ROW_OPS = ("insert", "update")


class WalCorruptError(RuntimeError):
    """Mid-log corruption: a record BEFORE the tail failed its CRC (a torn
    *final* record is normal crash debris and is truncated instead)."""


@dataclass(frozen=True)
class WalConfig:
    fsync: str = "os"     # "always" | "os" | "never"

    def __post_init__(self):
        if self.fsync not in ("always", "os", "never"):
            raise ValueError(f"unknown fsync policy {self.fsync!r}; valid "
                             "choices: always, os, never")


@dataclass(frozen=True)
class WalRecord:
    seq: int
    op: str                          # one of _OPCODES
    gids: Optional[np.ndarray] = None
    rows: Optional[np.ndarray] = None


def _encode(seq: int, op: str, gids=None, rows=None) -> bytes:
    parts = [_SEQ_OP.pack(seq, _OPCODES[op])]
    if op in _ROW_OPS:
        gids = np.ascontiguousarray(gids, np.int64)
        rows = np.ascontiguousarray(rows, np.float32)
        parts.append(_U32.pack(len(gids)))
        parts.append(_U32.pack(rows.shape[1]))
        parts.append(gids.tobytes())
        parts.append(rows.tobytes())
    elif op == "delete":
        gids = np.ascontiguousarray(gids, np.int64)
        parts.append(_U32.pack(len(gids)))
        parts.append(gids.tobytes())
    return b"".join(parts)


def _decode(payload: bytes) -> WalRecord:
    seq, opcode = _SEQ_OP.unpack_from(payload, 0)
    op = _OPNAMES[opcode]
    off = _SEQ_OP.size
    if op in _ROW_OPS:
        (n,) = _U32.unpack_from(payload, off)
        (d,) = _U32.unpack_from(payload, off + 4)
        off += 8
        gids = np.frombuffer(payload, np.int64, count=n, offset=off).copy()
        off += n * 8
        rows = np.frombuffer(payload, np.float32, count=n * d,
                             offset=off).reshape(n, d).copy()
        return WalRecord(seq, op, gids, rows)
    if op == "delete":
        (n,) = _U32.unpack_from(payload, off)
        gids = np.frombuffer(payload, np.int64, count=n, offset=off + 4).copy()
        return WalRecord(seq, op, gids)
    return WalRecord(seq, op)


class WriteAheadLog:
    """Append-only checksummed op log bound to one file.

    ``fresh=True`` truncates any existing file and writes the magic;
    otherwise the file is opened for append at ``append_at`` (recovery
    passes the verified good length so a torn tail is overwritten)."""

    def __init__(self, path: str, fsync: str = "os", *, fresh: bool = False,
                 append_at: Optional[int] = None):
        self.path = os.path.abspath(path)
        self.cfg = WalConfig(fsync=fsync)
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        exists = os.path.exists(self.path)
        self._f = open(self.path, "wb" if fresh or not exists else "r+b")
        if fresh or not exists:
            self._f.write(WAL_MAGIC)
            self._f.flush()
        else:
            self._f.seek(append_at if append_at is not None
                         else os.path.getsize(self.path))
            if append_at is not None:
                self._f.truncate(append_at)

    def append(self, seq: int, op: str, gids=None, rows=None) -> None:
        """Durably append one record (per the fsync policy). The
        ``wal.append`` fault fires BEFORE any bytes are written (clean op
        loss); ``wal.torn`` fires after HALF the record (torn tail)."""
        fault.at("wal.append")
        payload = _encode(seq, op, gids, rows)
        blob = _HDR.pack(len(payload), zlib.crc32(payload)) + payload
        if fault.fires("wal.torn"):
            self._f.write(blob[: max(1, len(blob) // 2)])
            self._f.flush()
            raise OSError(f"injected torn write at {self.path!r}")
        self._f.write(blob)
        if self.cfg.fsync != "never":
            self._f.flush()
            if self.cfg.fsync == "always":
                os.fsync(self._f.fileno())
        if _metrics.enabled():
            _metrics.counter("stream.wal_appends").inc()
            _metrics.counter("stream.wal_bytes").inc(len(blob))

    def flush(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())

    def reset(self) -> None:
        """Truncate to an empty log (after a checkpoint baked every op into
        the snapshot). Sequence numbers keep counting — the snapshot's
        ``wal_seq`` is what replay skips against, so a crash between the
        snapshot landing and this truncate is harmless."""
        self._f.seek(0)
        self._f.truncate(0)
        self._f.write(WAL_MAGIC)
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_records(path: str) -> Tuple[List[WalRecord], int, bool]:
    """Parse a WAL file tolerantly.

    Returns ``(records, good_length, clean)``: every record up to the
    first torn/corrupt point, the byte offset of the last good record's
    end (the truncation point for re-opening), and whether the file ended
    exactly on a record boundary. A bad CRC followed by MORE parseable
    bytes is mid-log corruption (not crash debris) and raises
    `WalCorruptError`.
    """
    with open(path, "rb") as f:
        blob = f.read()
    if blob[: len(WAL_MAGIC)] != WAL_MAGIC:
        raise WalCorruptError(f"{path!r}: bad WAL magic "
                              f"{blob[:len(WAL_MAGIC)]!r}")
    records: List[WalRecord] = []
    off = len(WAL_MAGIC)
    while True:
        if off + _HDR.size > len(blob):
            break                                   # torn/absent header
        length, crc = _HDR.unpack_from(blob, off)
        start, end = off + _HDR.size, off + _HDR.size + length
        if end > len(blob):
            break                                   # torn payload
        payload = blob[start:end]
        if zlib.crc32(payload) != crc:
            if end < len(blob):
                raise WalCorruptError(
                    f"{path!r}: CRC mismatch at offset {off} with "
                    f"{len(blob) - end} bytes following — mid-log "
                    "corruption, not a torn tail; acknowledged ops would "
                    "be silently lost. Restore the file from backup.")
            break                                   # torn final record
        records.append(_decode(payload))
        off = end
    return records, off, off == len(blob)


def replay_into(stream, records, base_seq: int = 0) -> int:
    """Apply WAL records with ``seq > base_seq`` onto a restored stream.

    Mirrors the live execution exactly: ops go through the public mutation
    methods (so delta slots, tombstones and the op log fill identically),
    ``compact_begin`` freezes, ``compact_commit`` rebuilds + installs,
    ``compact_abort`` abandons. A pending freeze at end-of-log (crash
    mid-rebuild) is abandoned — exactly what the crashed process lost.
    Returns the last applied seq.
    """
    from ..stream.compaction import rebuild_base

    last = base_seq
    pending = None
    stream._wal_replaying = True
    try:
        for rec in records:
            if rec.seq <= base_seq:
                continue
            if rec.op == "insert":
                stream.insert(rec.gids, rec.rows)
            elif rec.op == "delete":
                stream.delete(rec.gids)
            elif rec.op == "update":
                stream.update(rec.gids, rec.rows)
            elif rec.op == "compact_begin":
                pending = stream._freeze_for_compaction()
            elif rec.op == "compact_commit":
                gids, rows = pending
                stream._install_compacted(
                    rebuild_base(gids, rows, stream.build_kwargs))
                pending = None
            elif rec.op == "compact_abort":
                stream._abandon_compaction()
                pending = None
            last = rec.seq
        if stream._oplog is not None:   # crash mid-compaction: drop the
            stream._abandon_compaction()  # in-flight rebuild, keep the ops
    finally:
        stream._wal_replaying = False
    return last


def recover(wal_dir: str, *, attach: bool = True, fsync: str = "os"):
    """Recover a WAL'd `promips-stream` searcher from its durability dir.

    ``wal_dir`` is the directory `api.build(..., wal_dir=...)` maintains:
    ``snapshot/`` (checksummed atomic save) + ``wal.log``. Loads the
    snapshot (manifest-verified), replays every record past the snapshot's
    ``wal_seq``, truncates any torn tail, and (with ``attach=True``)
    re-attaches the WAL for continued appends. Returns the searcher.
    """
    from .. import api   # lazy: robust must stay importable below api

    snap = os.path.join(wal_dir, "snapshot")
    wal_path = os.path.join(wal_dir, "wal.log")
    searcher = api.load(snap)
    stream = getattr(searcher, "inner", None)
    if stream is None or not hasattr(stream, "_wal_seq"):
        raise ValueError(f"snapshot at {snap!r} is a "
                         f"{searcher.name!r} index, not a WAL-capable "
                         "promips-stream")
    if os.path.exists(wal_path):
        records, good_len, _clean = read_records(wal_path)
        last = replay_into(stream, records, base_seq=stream._wal_seq)
        stream._wal_seq = max(stream._wal_seq, last)
        if attach:
            stream.attach_wal(WriteAheadLog(wal_path, fsync=fsync,
                                            append_at=good_len))
    elif attach:
        stream.attach_wal(WriteAheadLog(wal_path, fsync=fsync, fresh=True))
    searcher._wal_dir = wal_dir
    return searcher
