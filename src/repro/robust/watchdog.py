"""EWMA latency watchdog — the ONE step-latency monitor (DESIGN.md §16).

Grew out of `distributed/fault.py`'s StragglerMonitor (the trainer's
deadline-based data-skip policy) and is now shared by the trainer and the
serve engine's degradation ladder, so there is exactly one EWMA
implementation: a step slower than ``threshold ×`` the running EWMA is a
straggler event. The serve engine mirrors the EWMA into the declared
``serve.step_latency_ewma`` gauge every step.

Two call styles, same math:

    wd.start(); ...; slow = wd.stop()      # trainer's bracket style
    slow = wd.observe(dt)                  # serve engine feeds measured dt
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

__all__ = ["EwmaWatchdog"]


@dataclass
class EwmaWatchdog:
    threshold: float = 2.5
    alpha: float = 0.2
    ewma: float = 0.0
    events: int = 0
    _t0: float = field(default=0.0, repr=False)

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Returns True if the bracketed step was a straggler."""
        return self.observe(time.perf_counter() - self._t0)

    def observe(self, dt: float) -> bool:
        """Feed one step latency; True if it was a straggler. The first
        sample seeds the EWMA and is never flagged."""
        if self.ewma == 0.0:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if slow:
            self.events += 1
        return slow
