"""Named, seeded fault points (DESIGN.md §16).

Every failure path the durability layer must survive — a WAL append that
dies, a snapshot write that tears, a compaction rebuild that throws, a
decode step that explodes — is guarded by a *named* fault point:

    fault.at("wal.append")          # in production code: no-op unless armed

Tests (or an operator, via ``REPRO_FAULTS``) arm points deterministically:

    fault.arm("compaction.rebuild", times=2)      # fail the next 2 hits
    fault.arm("wal.append", p=0.5, seed=3)        # seeded coin per hit
    fault.arm("snapshot.write", after=1, times=1) # fail exactly the 2nd hit

so every failure path above is exercisable — and *reproducible* — in tests
without monkeypatching internals. The disarmed fast path is one empty-dict
check, so production code pays nothing.

``REPRO_FAULTS`` is parsed once at import:
``name:p[:after[:times]]`` entries joined by ``,`` — e.g.
``REPRO_FAULTS="wal.append:1:0:1,compaction.rebuild:0.5"``.

The set of valid names is the declared :data:`FAULT_POINTS` inventory
(rendered in DESIGN.md §16); arming an undeclared name raises, so a typo'd
fault silently never firing cannot happen.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional

import numpy as np

from ..obs import metrics as _metrics

__all__ = ["FAULT_POINTS", "FaultInjected", "FaultInjector", "fault"]


# Declared inventory: name -> where it is threaded (DESIGN.md §16 table).
FAULT_POINTS: Dict[str, str] = {
    "wal.append": "WriteAheadLog.append, before any bytes hit the file "
                  "(a fired fault loses the op cleanly; the stream is "
                  "not mutated because logging is write-ahead)",
    "wal.torn": "WriteAheadLog.append, after writing HALF the record "
                "(simulates a crash mid-write: recovery must truncate "
                "the torn tail, not fail)",
    "snapshot.write": "Searcher.save's temp-dir phase, once per file "
                      "written (a fired fault leaves the previous "
                      "snapshot untouched)",
    "compaction.rebuild": "stream/compaction.rebuild_base entry (drives "
                          "the Compactor's retry/backoff ladder)",
    "serve.decode": "DecodeEngine.step, before the decode computation",
}


class FaultInjected(RuntimeError):
    """The exception a fired fault point raises (unless overridden)."""


class _Point:
    __slots__ = ("p", "after", "times", "seed", "exc", "hits", "fired", "rng")

    def __init__(self, p: float, after: int, times: Optional[int],
                 seed: int, exc: type):
        self.p = float(p)
        self.after = int(after)
        self.times = None if times is None else int(times)
        self.seed = int(seed)
        self.exc = exc
        self.hits = 0
        self.fired = 0
        self.rng = np.random.RandomState(seed)

    def roll(self) -> bool:
        """One hit: returns True when the point fires this time."""
        self.hits += 1
        if self.hits <= self.after:
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        if self.p < 1.0 and self.rng.random_sample() >= self.p:
            return False
        self.fired += 1
        return True


class FaultInjector:
    """Process-wide registry of armed fault points (thread-safe)."""

    def __init__(self, env: Optional[str] = None):
        self._lock = threading.Lock()
        self._points: Dict[str, _Point] = {}
        if env:
            for entry in env.split(","):
                entry = entry.strip()
                if not entry:
                    continue
                name, *rest = entry.split(":")
                p = float(rest[0]) if len(rest) > 0 else 1.0
                after = int(rest[1]) if len(rest) > 1 else 0
                times = int(rest[2]) if len(rest) > 2 else None
                self.arm(name, p=p, after=after, times=times)

    # -- arming ---------------------------------------------------------------
    def arm(self, name: str, *, p: float = 1.0, after: int = 0,
            times: Optional[int] = None, seed: int = 0,
            exc: type = FaultInjected) -> None:
        """Arm ``name``: fire with probability ``p`` per hit, skipping the
        first ``after`` hits, at most ``times`` total (None = unlimited).
        The per-point RNG is seeded, so a probabilistic fault schedule is
        bit-reproducible."""
        if name not in FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {name!r}; declared points: "
                f"{', '.join(sorted(FAULT_POINTS))}")
        with self._lock:
            self._points[name] = _Point(p, after, times, seed, exc)

    def disarm(self, name: Optional[str] = None) -> None:
        """Disarm one point, or every point (``name=None``)."""
        with self._lock:
            if name is None:
                self._points.clear()
            else:
                self._points.pop(name, None)

    def armed(self, name: str) -> bool:
        return name in self._points

    def counts(self, name: str) -> tuple:
        """(hits, fired) of an armed point; (0, 0) if not armed."""
        pt = self._points.get(name)
        return (pt.hits, pt.fired) if pt is not None else (0, 0)

    # -- hit sites ------------------------------------------------------------
    def fires(self, name: str) -> bool:
        """One hit of ``name``; True when it fires. Disarmed = one dict
        lookup on an (almost always) empty dict — effectively free."""
        if not self._points:
            return False
        pt = self._points.get(name)
        if pt is None:
            return False
        with self._lock:
            fired = pt.roll()
        if fired and _metrics.enabled():
            _metrics.counter("robust.faults_injected").inc()
        return fired

    def at(self, name: str) -> None:
        """One hit of ``name``; raises the point's exception when it fires."""
        if not self._points:
            return
        if self.fires(name):
            raise self._points[name].exc(f"injected fault at {name!r}")


# Module singleton every hit site uses; REPRO_FAULTS arms points at import.
fault = FaultInjector(os.environ.get("REPRO_FAULTS"))
