"""Checksummed atomic snapshot directories (DESIGN.md §16).

A saved index directory gains a ``manifest.json``:

    {"format": "repro.api-index", "version": 1,
     "files": {"arrays.npz": "<sha256>", "meta.json": "<sha256>"},
     "provenance": {"commit": ..., "jax_version": ..., "platform": ...}}

and the whole directory is written ATOMICALLY: all files land in a temp
dir next to the destination, are fsync'd, and the temp dir is renamed into
place — a crash mid-save leaves either the previous snapshot or the new
one, never a torn mix. `verify_dir` re-hashes every manifest entry at load
time and raises `CorruptSnapshotError` naming the FIRST file that failed
(missing, truncated, or bit-flipped), so corruption fail-fasts with an
actionable message instead of surfacing as a numpy unpickling error three
layers down. A directory without a manifest (pre-durability save) loads
with a warning, unverified.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import warnings
from typing import Callable, Dict

from .faultpoints import fault

__all__ = ["CorruptSnapshotError", "MANIFEST_FILE", "provenance",
           "verify_dir", "write_atomic_dir"]

MANIFEST_FILE = "manifest.json"
_HASH_CHUNK = 1 << 20


class CorruptSnapshotError(RuntimeError):
    """A saved index failed integrity verification; the message names the
    file that failed and why (missing / size mismatch / hash mismatch)."""


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(_HASH_CHUNK), b""):
            h.update(chunk)
    return h.hexdigest()


def provenance() -> dict:
    """Code + toolchain identity stamped into every manifest (same fields
    benchmarks/run.py stamps into history.jsonl)."""
    try:
        import subprocess
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        commit = "unknown"
    try:
        import jax
        jax_version, platform = jax.__version__, jax.default_backend()
    except Exception:  # pragma: no cover — jax is a hard dep everywhere else
        jax_version = platform = "unknown"
    return {"commit": commit, "jax_version": jax_version,
            "platform": platform}


def write_atomic_dir(path: str, writers: Dict[str, Callable[[str], None]],
                     manifest_extra: dict = None) -> str:
    """Write a snapshot directory atomically.

    ``writers`` maps each file name to a callable that writes it given a
    full path; every file is hashed into the manifest as it is written.
    The ``snapshot.write`` fault point fires once per file, BEFORE the
    write — an injected fault aborts the temp dir and leaves any previous
    snapshot at ``path`` untouched.
    """
    path = os.path.abspath(path)
    parent = os.path.dirname(path)
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=parent, prefix=".save-tmp-")
    try:
        files = {}
        for fname, write in writers.items():
            fault.at("snapshot.write")
            fpath = os.path.join(tmp, fname)
            write(fpath)
            files[fname] = _sha256(fpath)
        manifest = {"files": files, "provenance": provenance()}
        manifest.update(manifest_extra or {})
        fault.at("snapshot.write")
        mpath = os.path.join(tmp, MANIFEST_FILE)
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
        for fname in list(files) + [MANIFEST_FILE]:
            fd = os.open(os.path.join(tmp, fname), os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        # atomic install: rename the old dir aside, the temp dir in, then
        # drop the old one. The only non-atomic window is between the two
        # renames (dest briefly absent); both endpoints are complete,
        # verified snapshots, so a crash never leaves a torn mix.
        if os.path.exists(path):
            old = tempfile.mkdtemp(dir=parent, prefix=".save-old-")
            os.rmdir(old)
            os.rename(path, old)
            os.rename(tmp, path)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.rename(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return path


def verify_dir(path: str) -> bool:
    """Verify a snapshot directory against its manifest.

    Returns True when verified, False when there is no manifest (legacy
    pre-durability save — a warning is emitted and the caller loads it
    unverified). Raises `CorruptSnapshotError` naming the failing file on
    a missing entry, size mismatch, or hash mismatch, and on an unreadable
    manifest itself.
    """
    mpath = os.path.join(path, MANIFEST_FILE)
    if not os.path.exists(mpath):
        warnings.warn(
            f"saved index at {path!r} has no {MANIFEST_FILE} (written by a "
            "pre-durability version); loading UNVERIFIED — re-save to gain "
            "integrity checking", stacklevel=3)
        return False
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        files = manifest["files"]
    except (ValueError, KeyError) as e:
        raise CorruptSnapshotError(
            f"snapshot at {path!r}: unreadable {MANIFEST_FILE} ({e}); the "
            "snapshot cannot be trusted — restore from a backup or re-save "
            "from a live index") from e
    for fname, want in files.items():
        fpath = os.path.join(path, fname)
        if not os.path.exists(fpath):
            raise CorruptSnapshotError(
                f"snapshot at {path!r}: {fname} is listed in the manifest "
                "but missing on disk")
        got = _sha256(fpath)
        if got != want:
            raise CorruptSnapshotError(
                f"snapshot at {path!r}: {fname} failed its checksum "
                f"(manifest sha256 {want[:12]}…, on-disk {got[:12]}…) — "
                "the file is truncated or corrupted")
    return True
