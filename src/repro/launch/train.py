"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir /tmp/ckpt

Features exercised here (the fault-tolerance story):
  * auto-resume from the latest complete checkpoint (restart-safe);
  * deterministic data position = step index (no replay/skip after restart);
  * straggler monitor -> tightened checkpoint cadence while degraded;
  * elastic mesh: built from the devices that are actually alive, and
    checkpoints reshard on load (ElasticPolicy + mesh-agnostic restore);
  * async (non-blocking) checkpoint writes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..data.synthetic import TokenStream
from ..distributed import checkpoint as ckpt_lib
from ..distributed.fault import ElasticPolicy, StragglerMonitor
from ..distributed.sharding import batch_specs, param_specs
from ..models import transformer as model_lib
from ..train.loop import TrainCfg, init_state, make_train_step
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", help="smoke-size model")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=2)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainCfg(lr=args.lr, warmup=max(10, args.steps // 10),
                    total_steps=args.steps, microbatches=args.microbatches,
                    compress_grads=args.compress_grads,
                    remat="full")

    policy = ElasticPolicy(model_parallel=args.model_parallel)
    mesh = make_host_mesh(policy.mesh_shape(len(jax.devices()))[1])
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"devices={mesh.devices.size}")

    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(key, cfg)
    state = init_state(params, tcfg)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(jax.eval_shape(lambda: params), mesh))
    state_shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), state,
    )
    state_shardings = state_shardings._replace(
        params=p_sh,
        opt=state_shardings.opt._replace(mu=p_sh, nu=p_sh),
        ef=state_shardings.ef._replace(residual=p_sh) if state.ef is not None else None,
    )
    state = jax.device_put(state, state_shardings)

    start_step = 0
    if args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore(args.ckpt_dir, latest, state, state_shardings)
            start_step = latest
            print(f"resumed from step {latest}")

    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        {"tokens": P("data", None), "labels": P("data", None)})
    step_fn = jax.jit(make_train_step(cfg, tcfg),
                      in_shardings=(state_shardings, b_sh),
                      out_shardings=(state_shardings, NamedSharding(mesh, P())),
                      donate_argnums=(0,))

    stream = TokenStream(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    monitor = StragglerMonitor()
    ckpt_every = args.ckpt_every
    pending = None
    t_start = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = stream.batch_at(step)
        monitor.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        straggled = monitor.stop()
        if straggled:
            ckpt_every = max(10, ckpt_every // 2)  # tighten cadence while degraded
            print(f"[straggler] step {step}: latency spike; ckpt_every -> {ckpt_every}")
        if args.log_every and step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")
        if args.ckpt_dir and (step + 1) % ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt_lib.save(args.ckpt_dir, step + 1, state, blocking=False)
    if pending is not None:
        pending.join()
    if args.ckpt_dir:
        ckpt_lib.save(args.ckpt_dir, args.steps, state)
    dt = time.time() - t_start
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s "
          f"({(args.steps - start_step) / max(dt, 1e-9):.2f} steps/s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"straggler events: {monitor.events}")
    return losses


if __name__ == "__main__":
    main()
