import os
if __name__ == "__main__":  # pragma: no cover - CLI entry only
    # The 512-host-device trick is only for the CLI's production-mesh
    # analysis; importers (the search benchmark pulls `kernel_cost`) must
    # NOT have their jax backend reconfigured as an import side effect.
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")
"""Roofline analysis (deliverable g).

XLA's cost_analysis counts a while-loop body once regardless of trip count,
so FLOPs/bytes/collective-bytes are measured on small UNROLLED variants
(scan_util.set_unroll) and extrapolated linearly in depth groups and
microbatches:

  all kinds : C(G1), C(G2); total(G) = C(G1) + (G-G1) (C(G2) - C(G1))
  (train variants run with microbatches=1: the total step work is
  microbatch-count independent — same tokens — modulo the optimizer,
  which is depth-extrapolated with everything else)

Depth group sizes: attn=1 layer, xlstm_7_1=8 layers, zamba2=shared_every
layers, encdec varies enc/dec separately. The sLSTM time recurrence cannot
be unrolled (seq_len steps); its FLOPs are added analytically
(`slstm_correction`). Terms use v5e constants: 197 TF/s bf16, 819 GB/s HBM,
50 GB/s/link ICI; collective wire-bytes = per-device result bytes x ring
factor (all-reduce 2x, others 1x).

  PYTHONPATH=src python -m repro.launch.roofline --all [--out results/roofline]
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, SHAPES_BY_NAME, get_config  # noqa: E402
from ..models import scan_util  # noqa: E402
from . import specs as specs_lib  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# NOTE: `.dryrun` also mutates XLA_FLAGS at import; it is imported lazily
# inside `_cost` so `kernel_cost` importers keep their jax backend as-is.

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def kernel_cost(fn, *args):
    """Roofline terms of ONE jit-able callable on its example ``args``.

    Lowers + compiles ``fn`` (wrapping in `jax.jit` unless it already
    carries `.lower`) and reads XLA's cost_analysis — the same figures the
    cell-level analysis above uses, without the unroll/extrapolation
    machinery. Used by the search benchmark to report ACHIEVED bytes/flops
    next to the v5e roofline bound for the fused-verification graph.

    The figures are a compile-time STATIC UPPER BOUND, not a measurement:
    cost_analysis sums EVERY branch of a `lax.switch`/`lax.cond` (the fused
    drivers compile one branch per pow2 tile bucket, of which exactly one
    executes per round) and counts a while body once regardless of trip
    count. The returned record carries ``static_upper_bound: True`` so
    BENCH consumers do not read it as achieved traffic; for measured
    per-stage wall-clock against this bound use the offline cutout runner,
    `repro.tune.cutout.stage_records` (DESIGN.md §15).
    """
    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    t_comp = flops / PEAK_FLOPS
    t_mem = nbytes / HBM_BW
    bound = max(t_comp, t_mem)
    return {"flops": flops, "bytes": nbytes,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "roofline_s": bound,
            "bound": "compute" if t_comp >= t_mem else "memory",
            "static_upper_bound": True}


def _cost(cfg, shape, mesh, *, microbatches=None):
    """Compile one unrolled variant; return {flops, bytes, coll:{op:bytes}}."""
    fn, args, in_sh, out_sh = specs_lib.build_cell(
        cfg, shape, mesh, microbatch_override=microbatches)
    scan_util.set_unroll(True)
    try:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
    finally:
        scan_util.set_unroll(False)
    from .dryrun import parse_collectives
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    coll, _ = parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def _lin(c_lo, c_hi, steps_lo, steps_hi):
    """Per-extra-step delta of every cost field."""
    def d(a, b):
        return (b - a) / (steps_hi - steps_lo)
    coll = {k: d(c_lo["coll"].get(k, 0), c_hi["coll"].get(k, 0))
            for k in set(c_lo["coll"]) | set(c_hi["coll"])}
    return {"flops": d(c_lo["flops"], c_hi["flops"]),
            "bytes": d(c_lo["bytes"], c_hi["bytes"]), "coll": coll}


def _combine(base, body, n_extra):
    coll = {k: base["coll"].get(k, 0) + n_extra * body["coll"].get(k, 0)
            for k in set(base["coll"]) | set(body["coll"])}
    return {"flops": base["flops"] + n_extra * body["flops"],
            "bytes": base["bytes"] + n_extra * body["bytes"], "coll": coll}


def _group_info(cfg):
    """(group_layer_count, total_groups_float, variant_cfgs (G1, G2))."""
    if cfg.block_pattern == "xlstm_7_1":
        g = 8
        return g, cfg.n_layers / g, (dataclasses.replace(cfg, n_layers=8),
                                     dataclasses.replace(cfg, n_layers=16))
    if cfg.block_pattern == "zamba2":
        g = cfg.shared_attn_every
        return g, cfg.n_layers / g, (dataclasses.replace(cfg, n_layers=g),
                                     dataclasses.replace(cfg, n_layers=2 * g))
    if cfg.block_pattern == "encdec":
        return 1, None, None  # handled separately
    return 1, float(cfg.n_layers), (dataclasses.replace(cfg, n_layers=1),
                                    dataclasses.replace(cfg, n_layers=2))


def fused_memory_bytes(cfg, shape, mesh, microbatches):
    """Analytic per-chip HBM traffic assuming production kernel fusion.

    cost_analysis' "bytes accessed" sums operand/result bytes of every HLO
    op — in the unrolled jnp graph that counts flash-attention score tiles
    and gating intermediates that live in VMEM once the Pallas kernels
    (kernels/) fuse them. This model counts only the traffic that MUST hit
    HBM: parameters (per microbatch re-read), optimizer state, saved
    activations (remat=full saves layer inputs), logits, embeddings and KV
    caches. The HLO figure is reported alongside as an unfused upper bound.
    """
    import numpy as np
    from ..distributed import sharding as shard_lib
    chips = mesh.devices.size
    model_sz = shard_lib.axis_size(mesh, "model")
    dp = shard_lib.axis_size(mesh, shard_lib.dp_axes(mesh))
    n_params = cfg.param_count()
    p_loc = 2.0 * n_params / model_sz              # bf16 weights per chip
    d = cfg.d_model
    kh, dh = cfg.n_kv_heads, cfg.head_dim_
    v_loc = cfg.vocab_padded * 2.0 / model_sz      # bf16 logits row bytes/chip

    if shape.kind == "train":
        tokens_loc = shape.global_batch * shape.seq_len / dp
        mb_tokens = tokens_loc / microbatches
        act = 2.0 * mb_tokens * d                  # bf16 layer input
        n_layers = cfg.n_layers
        per_mb = (
            2.0 * p_loc                            # weights fwd + bwd-recompute
            + n_layers * act * 2                   # save + reload boundaries
            + n_layers * act * 8                   # fused layer io (qkv/mlp r/w)
            + mb_tokens * v_loc * 3                # logits write + CE read (f32)
        )
        opt = (4.0 * n_params / chips) * 6         # f32 g, mu, nu r/w (ZeRO)
        return microbatches * per_mb + opt + 2.0 * p_loc
    if shape.kind == "prefill":
        tokens_loc = shape.global_batch * shape.seq_len / dp
        cache = 2.0 * 2 * cfg.n_layers * tokens_loc * kh * dh / max(
            model_sz if kh % model_sz == 0 or dh % model_sz == 0 else 1, 1)
        return p_loc + tokens_loc * d * 2 * 10 + cache + tokens_loc / shape.seq_len * v_loc
    # decode: weights + full KV cache read per token + states
    b_loc = max(shape.global_batch / dp, 1)
    kv_len = min(shape.seq_len, cfg.window) if cfg.attn == "swa" else shape.seq_len
    n_kv_layers = {"attn": cfg.n_layers, "encdec": cfg.n_layers,
                   "zamba2": max(cfg.n_layers // cfg.shared_attn_every, 1),
                   "xlstm_7_1": 0}[cfg.block_pattern]
    kv_shard = model_sz if (kh % model_sz == 0 or dh % model_sz == 0) else (
        model_sz if shape.global_batch < dp else model_sz)
    cache = 2.0 * 2 * n_kv_layers * b_loc * kv_len * kh * dh / kv_shard
    state = 0.0
    if cfg.block_pattern == "zamba2":
        inner = cfg.ssm.expand * d
        state = 4.0 * 2 * cfg.n_layers * b_loc * inner * cfg.ssm.state_dim / cfg.ssm.head_dim / model_sz * cfg.ssm.head_dim
    if cfg.block_pattern == "xlstm_7_1":
        p = d // cfg.n_heads
        state = 4.0 * 2 * cfg.n_layers * b_loc * d * p / model_sz
    return p_loc + cache + state + b_loc * v_loc


def slstm_correction(cfg, shape):
    """Analytic FLOPs of the sLSTM time recurrence (not unrollable).

    Per step per layer: recurrent einsum 2*d*4p + ~24 elementwise ops on
    (h,p); times tokens processed."""
    if cfg.block_pattern != "xlstm_7_1":
        return 0.0
    d = cfg.d_model
    p = d // cfg.n_heads
    n_slstm = cfg.n_layers // 8
    per_tok = 2 * d * 4 * p + 24 * d
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 3.0 * per_tok * tokens * n_slstm  # fwd + bwd ~ 3x fwd
    if shape.kind == "prefill":
        return float(per_tok * shape.global_batch * shape.seq_len * n_slstm)
    return float(per_tok * shape.global_batch * n_slstm)


def _scale(total, factor):
    return {"flops": total["flops"] * factor, "bytes": total["bytes"] * factor,
            "coll": {k: v * factor for k, v in total["coll"].items()}}


def _measure_total(cfg, shape, mesh, mb1):
    """Depth-extrapolated costs for one (possibly seq-reduced) shape."""
    if cfg.block_pattern == "encdec":
        c11 = _cost(dataclasses.replace(cfg, enc_layers=1, n_layers=1), shape, mesh,
                    microbatches=mb1)
        c21 = _cost(dataclasses.replace(cfg, enc_layers=2, n_layers=1), shape, mesh,
                    microbatches=mb1)
        c12 = _cost(dataclasses.replace(cfg, enc_layers=1, n_layers=2), shape, mesh,
                    microbatches=mb1)
        enc_body, dec_body = _lin(c11, c21, 1, 2), _lin(c11, c12, 1, 2)
        return _combine(_combine(c11, enc_body, cfg.enc_layers - 1),
                        dec_body, cfg.n_layers - 1)
    g_layers, n_groups, (cfg1, cfg2) = _group_info(cfg)
    c1 = _cost(cfg1, shape, mesh, microbatches=mb1)
    c2 = _cost(cfg2, shape, mesh, microbatches=mb1)
    return _combine(c1, _lin(c1, c2, 1, 2), n_groups - 1)


def analyse_cell(arch_id, shape_name, mesh):
    cfg = get_config(arch_id)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = specs_lib.cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "status": reason}
    chips = mesh.devices.size

    mb1 = 1 if shape.kind == "train" else None
    long_seq = shape.kind in ("train", "prefill") and shape.seq_len > 2048
    if cfg.block_pattern == "xlstm_7_1" and long_seq:
        # sLSTM's time scan makes full-seq unrolled compiles infeasible;
        # every xLSTM term is linear in tokens -> measure short, scale.
        s1 = 512
        total = _measure_total(cfg, dataclasses.replace(shape, seq_len=s1),
                               mesh, mb1)
        total = _scale(total, shape.seq_len / s1)
    elif cfg.block_pattern == "zamba2" and long_seq:
        # mamba terms are linear in S, the shared attention quadratic:
        # two-point fit f(S) = a S + b S^2.
        s1, s2 = 1024, 2048
        f1 = _measure_total(cfg, dataclasses.replace(shape, seq_len=s1), mesh, mb1)
        f2 = _measure_total(cfg, dataclasses.replace(shape, seq_len=s2), mesh, mb1)

        def fit(v1, v2):
            b = (v2 / s2 - v1 / s1) / (s2 - s1)
            a = v1 / s1 - b * s1
            return max(a * shape.seq_len + b * shape.seq_len ** 2, 0.0)

        total = {"flops": fit(f1["flops"], f2["flops"]),
                 "bytes": fit(f1["bytes"], f2["bytes"]),
                 "coll": {k: fit(f1["coll"].get(k, 0), f2["coll"].get(k, 0))
                          for k in set(f1["coll"]) | set(f2["coll"])}}
    else:
        total = _measure_total(cfg, shape, mesh, mb1)

    total["flops"] += slstm_correction(cfg, shape) / chips

    # cost_analysis reports the PER-DEVICE (post-partition) program, so the
    # terms are per-chip quantities already (calibrated in EXPERIMENTS.md).
    mb = (specs_lib.choose_microbatches(cfg, shape, mesh)
          if shape.kind == "train" else 1)
    fused_bytes = fused_memory_bytes(cfg, shape, mesh, mb)
    t_comp = total["flops"] / PEAK_FLOPS
    t_mem_hlo = total["bytes"] / HBM_BW
    t_mem = fused_bytes / HBM_BW
    wire = sum(RING_FACTOR.get(op, 1.0) * b for op, b in total["coll"].items())
    t_coll = wire / ICI_BW  # per-device wire bytes over one link
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    bound = max(t_comp, t_mem, t_coll)
    return {
        "arch": arch_id, "shape": shape_name, "status": "ok",
        "kind": shape.kind, "chips": chips,
        "hlo_flops_per_chip": total["flops"], "hlo_bytes_per_chip": total["bytes"],
        "collective_bytes_per_chip": {k: round(v) for k, v in total["coll"].items()},
        "wire_bytes_per_chip": round(wire),
        "fused_bytes_per_chip": round(fused_bytes),
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_hlo_upper_s": t_mem_hlo, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_frac": (model_flops / (total["flops"] * chips)
                              if total["flops"] else 0),
        "bound_mfu": (model_flops / (chips * PEAK_FLOPS)) / bound if bound else 0,
        "roofline_time_s": bound,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)  # roofline is single-pod
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    os.makedirs(args.out, exist_ok=True)
    for a in archs:
        for s in shapes:
            path = os.path.join(args.out, f"{a}__{s}.json")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {a}/{s}")
                continue
            t0 = time.time()
            try:
                rec = analyse_cell(a, s, mesh)
            except Exception as e:
                rec = {"arch": a, "shape": s, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                print(f"[ok  ] {a}/{s} ({time.time()-t0:.0f}s) dom={rec['dominant']} "
                      f"t=({rec['t_compute_s']:.4f},{rec['t_memory_s']:.4f},"
                      f"{rec['t_collective_s']:.4f})s bound_mfu={rec['bound_mfu']:.3f}",
                      flush=True)
            else:
                print(f"[{rec['status'][:5]}] {a}/{s} {rec.get('error','')}", flush=True)


if __name__ == "__main__":
    main()
