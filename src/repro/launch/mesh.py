"""Production meshes.

Single pod  : (data=16, model=16)            = 256 chips (v5e pod)
Multi-pod   : (pod=2, data=16, model=16)     = 512 chips

A FUNCTION (not a module constant) so importing never touches jax device
state — only launch/dryrun.py sets the 512-device host platform flag.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """`jax.make_mesh` across jax versions: newer jax wants explicit
    ``axis_types`` (Auto) for shard_map meshes; jax <= 0.4.x has no
    ``AxisType`` at all and its meshes are implicitly Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model_parallel: int = 2):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    mp = model_parallel
    while mp > 1 and n % mp:
        mp //= 2
    return make_mesh_compat((n // mp, mp), ("data", "model"))
