"""Production meshes.

Single pod  : (data=16, model=16)            = 256 chips (v5e pod)
Multi-pod   : (pod=2, data=16, model=16)     = 512 chips

A FUNCTION (not a module constant) so importing never touches jax device
state — only launch/dryrun.py sets the 512-device host platform flag.
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_parallel: int = 2):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    mp = model_parallel
    while mp > 1 and n % mp:
        mp //= 2
    return jax.make_mesh((n // mp, mp), ("data", "model"), axis_types=_auto(2))
