"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --reduced \
      --requests 12 --logits-mode promips
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import transformer as model_lib
from ..serve.engine import DecodeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--logits-mode", choices=("exact", "promips"), default="exact")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(params, cfg, batch_slots=args.slots,
                          max_len=args.max_len, logits_mode=args.logits_mode)
    rng = np.random.RandomState(0)
    reqs = [engine.submit(rng.randint(1, cfg.vocab, size=args.prompt_len),
                          max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s, engine steps {engine.steps}, "
          f"logit pages {engine.pages})")
    for i, r in enumerate(reqs[:3]):
        print(f"  req{i}: {r.out_tokens[:10]}...")
    return reqs


if __name__ == "__main__":
    main()
