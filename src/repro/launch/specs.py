"""Abstract (ShapeDtypeStruct) inputs + shardings for every dry-run cell.

No device allocation anywhere in this module — everything is eval_shape /
ShapeDtypeStruct, so a 512-device mesh of host CPUs can lower and compile
each (arch × shape × mesh) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ArchConfig, ShapeCfg
from ..distributed import sharding as shard_lib
from ..models import transformer as model_lib
from ..train.loop import TrainCfg, TrainState, init_state, make_train_step
from ..train.optimizer import AdamWState


def model_dtype(cfg):
    return jnp.bfloat16


def abstract_params(cfg: ArchConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(
        functools.partial(model_lib.init_params, cfg=cfg, dtype=model_dtype(cfg)), key
    )


def choose_microbatches(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh) -> int:
    """Grad-accum factor so one microbatch's activations fit HBM.

    Heuristic: target <= ~4096 tokens per data-parallel shard per microbatch
    for >= 8B-param models, <= 16384 otherwise; clipped to divisors of the
    global batch.
    """
    dp = shard_lib.axis_size(mesh, shard_lib.dp_axes(mesh))
    tokens_per_shard = shape.global_batch * shape.seq_len // dp
    big = cfg.param_count() >= 8e9
    target = 2048 if big else 16384
    mb = max(1, tokens_per_shard // target)
    while shape.global_batch % mb:
        mb -= 1
    return max(1, mb)


def train_cfg_for(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh) -> TrainCfg:
    return TrainCfg(microbatches=choose_microbatches(cfg, shape, mesh), remat="full")


def batch_structs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["patches"] = jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), model_dtype(cfg))
    if cfg.frontend == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), model_dtype(cfg))
    return batch


def state_structs(cfg: ArchConfig, tcfg: TrainCfg):
    params = abstract_params(cfg)
    return jax.eval_shape(functools.partial(init_state, tcfg=tcfg), params)


def state_shardings(cfg: ArchConfig, tcfg: TrainCfg, mesh: Mesh):
    params_shape = abstract_params(cfg)
    pspec = shard_lib.param_specs(params_shape, mesh)
    zspec = shard_lib.zero1_specs(params_shape, mesh)
    ns = lambda spec_tree: jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)
    return TrainState(
        params=ns(pspec),
        opt=AdamWState(mu=ns(zspec), nu=ns(zspec), count=NamedSharding(mesh, P())),
        ef=ns(zspec) if tcfg.compress_grads else None,
        step=NamedSharding(mesh, P()),
    )


def cache_structs(cfg: ArchConfig, shape: ShapeCfg):
    cache = jax.eval_shape(
        functools.partial(model_lib.init_cache, cfg, shape.global_batch,
                          shape.seq_len, model_dtype(cfg))
    )
    return cache


def cache_shardings(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh):
    specs = shard_lib.cache_specs(cfg, shape, mesh)
    cache_shape = cache_structs(cfg, shape)

    def spec_of(path, leaf):
        name = shard_lib._path_str(path).split("/")[0]
        sp = specs.get(name, None)
        if isinstance(sp, tuple) and not isinstance(sp, P):
            idx = int(shard_lib._path_str(path).split("/")[1])
            sp = sp[idx]
        if sp is None:
            sp = P(*([None] * len(leaf.shape)))
        return NamedSharding(mesh, sp)

    return jax.tree_util.tree_map_with_path(spec_of, cache_shape)


# ---------------------------------------------------------------------------
# step functions per cell kind
# ---------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
               microbatch_override: int | None = None):
    """Returns (fn, abstract_args, in_shardings, out_shardings) for the cell."""
    ns = lambda spec: NamedSharding(mesh, spec)

    if shape.kind == "train":
        tcfg = train_cfg_for(cfg, shape, mesh)
        if microbatch_override is not None:
            import dataclasses
            tcfg = dataclasses.replace(tcfg, microbatches=microbatch_override)
        acc_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            shard_lib.zero1_specs(abstract_params(cfg), mesh))
        mb_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, P(None, *s)),
            shard_lib.batch_specs(cfg, shape, mesh))
        p_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            shard_lib.param_specs(abstract_params(cfg), mesh))
        step = make_train_step(cfg, tcfg, acc_shardings=acc_sh, mb_shardings=mb_sh,
                               param_shardings=p_sh)
        st_sh = state_shardings(cfg, tcfg, mesh)
        b_sh = jax.tree.map(ns, shard_lib.batch_specs(cfg, shape, mesh))
        args = (state_structs(cfg, tcfg), batch_structs(cfg, shape))
        in_sh = (st_sh, b_sh)
        out_sh = (st_sh, ns(P()))
        return step, args, in_sh, out_sh

    params_struct = abstract_params(cfg)
    p_sh = jax.tree.map(ns, shard_lib.param_specs(params_struct, mesh))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model_lib.prefill(params, cfg, batch, shape.seq_len, remat="none")
        args = (params_struct, batch_structs(cfg, shape))
        c_sh = cache_shardings(cfg, shape, mesh)
        in_sh = (p_sh, jax.tree.map(ns, shard_lib.batch_specs(cfg, shape, mesh)))
        out_sh = (c_sh, ns(shard_lib.logits_spec(cfg, mesh)))
        return prefill_step, args, in_sh, out_sh

    if shape.kind == "decode":
        def serve_step(params, cache, token):
            return model_lib.decode_step(params, cfg, cache, token)
        b = shape.global_batch
        token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        args = (params_struct, cache_structs(cfg, shape), token)
        c_sh = cache_shardings(cfg, shape, mesh)
        t_sh = ns(shard_lib.decode_token_spec(cfg, shape, mesh))
        in_sh = (p_sh, c_sh, t_sh)
        out_sh = (ns(shard_lib.logits_spec(cfg, mesh)), c_sh)
        return serve_step, args, in_sh, out_sh

    raise ValueError(shape.kind)


def cell_applicable(cfg: ArchConfig, shape: ShapeCfg):
    """(ok, reason) — long_500k only for sub-quadratic families (DESIGN §6)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "skipped(full-attention)"
    return True, ""
