import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
512 placeholder host devices; record memory analysis, FLOPs/bytes and the
collective schedule for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
Cells are cached as JSON (one file per cell) and skipped when present —
the sweep is resumable.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, SHAPES_BY_NAME, get_config  # noqa: E402
from . import specs as specs_lib  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Sum result-shape bytes per collective op kind (wire-bytes proxy;
    ring factors applied in roofline.py)."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        type_str, op = m.group(1), m.group(2)
        out[op] = out.get(op, 0) + _shape_bytes(type_str)
        counts[op] = counts.get(op, 0) + 1
    return out, counts


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool, mesh=None):
    cfg = get_config(arch_id)
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = specs_lib.cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
                "status": reason}
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_sh, out_sh = specs_lib.build_cell(cfg, shape, mesh)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rec = {
        "arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "kind": shape.kind,
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "microbatches": (specs_lib.choose_microbatches(cfg, shape, mesh)
                          if shape.kind == "train" else 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    try:
        mem = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                rec[f] = int(v)
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis_error"] = str(e)
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        rec["hlo_flops"] = float(cost.get("flops", -1))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", -1))
        rec["cost_raw"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float)) and ("bytes" in k or k in ("flops", "transcendentals"))}
    except Exception as e:
        rec["cost_analysis_error"] = str(e)
    try:
        text = compiled.as_text()
        coll, counts = parse_collectives(text)
        rec["collective_bytes"] = coll
        rec["collective_counts"] = counts
        rec["hlo_lines"] = text.count("\n")
    except Exception as e:
        rec["collective_error"] = str(e)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    mesh_cache = {}
    for arch_id, shape_name, mp in cells:
        mesh_dir = "multipod_2x16x16" if mp else "pod_16x16"
        out_dir = os.path.join(args.out, mesh_dir, arch_id)
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{shape_name}.json")
        if os.path.exists(path) and not args.force:
            print(f"[skip] {mesh_dir}/{arch_id}/{shape_name} (cached)")
            continue
        if mp not in mesh_cache:
            mesh_cache[mp] = make_production_mesh(multi_pod=mp)
        print(f"[run ] {mesh_dir}/{arch_id}/{shape_name} ...", flush=True)
        try:
            rec = run_cell(arch_id, shape_name, multi_pod=mp, mesh=mesh_cache[mp])
        except Exception as e:
            rec = {"arch": arch_id, "shape": shape_name, "multi_pod": mp,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"       -> {rec.get('status')} "
              f"(lower {rec.get('lower_s', '-')}s, compile {rec.get('compile_s', '-')}s, "
              f"flops {rec.get('hlo_flops', '-')})", flush=True)


if __name__ == "__main__":
    main()
