"""Observability substrate (DESIGN.md §14).

Two small, dependency-free-within-the-repo modules:

  obs.trace    near-zero-overhead-when-disabled span tracer with a
               thread-safe bounded ring buffer, opt-in `block_until_ready`
               fencing (honest device timings under JAX async dispatch),
               optional `jax.profiler.TraceAnnotation` pass-through, and
               Chrome trace-event JSON export (viewable in Perfetto).
  obs.metrics  process-wide registry of counters / gauges / log2-bucket
               histograms with a DECLARED name glossary, `snapshot()`,
               JSONL flush and Prometheus text exposition. Fed from the
               `core/stats.stats_totals` choke point and the span tracer.

Neither module imports anything from `repro.core` (the core imports THEM),
so there are no cycles and `import repro.obs` stays cheap.
"""
from . import metrics, trace
from .trace import (configure, disable, enable, enabled, export_chrome_trace,
                    span)

__all__ = ["metrics", "trace", "span", "configure", "enable", "disable",
           "enabled", "export_chrome_trace"]
