"""Process-wide metrics registry (DESIGN.md §14).

Three instrument kinds behind one lock:

  Counter    monotonic float/int accumulator (`inc(n)`)
  Gauge      last-written value (`set(v)`)
  Histogram  log2-bucketed distribution (`observe(v)`): bucket ``i`` counts
             observations with ``2^(i-1) < v <= 2^i`` (``i=0`` holds
             ``v <= 1``), plus exact ``count`` / ``sum`` — enough for
             p50/p99-style questions at a fixed 2x resolution with O(64)
             storage and no per-observation allocation.

**Every name must be declared** in `GLOSSARY` below (name -> (kind, help)):
`counter()/gauge()/histogram()` raise ``ValueError`` on an undeclared name
or a kind mismatch, so an instrumented path can never silently invent a
metric — scripts/ci.sh's obs tier relies on this to fail loudly.

Instruments are created lazily on first use; `snapshot()` returns only the
instruments that exist, so a snapshot taken after a smoke search shows
exactly which paths actually recorded. Feeds:

  * `core/stats.stats_totals` — the single choke point every stats class's
    `to_dict()` goes through — calls `observe_search(totals)` when the
    registry is ENABLED (`enable()`); one bool check when disabled.
  * `obs.trace` spans feed declared ``*_us`` histograms on exit.
  * Per-call instrumentation behind `RuntimeConfig.obs` / engine ``obs=``
    writes directly (already gated by its own flag).

`register_collector(fn)` adds a callback run at every `snapshot()` /
`prometheus_text()` — used for pull-style values (e.g. the fused driver's
retrace total) that would otherwise need a hook on every mutation.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Callable, Dict

__all__ = ["GLOSSARY", "Counter", "Gauge", "Histogram", "counter", "gauge",
           "histogram", "snapshot", "reset", "enable", "disable", "enabled",
           "observe_search", "register_collector", "flush_jsonl",
           "prometheus_text"]

# --------------------------------------------------------------------------
# Declared metric-name glossary: name -> (kind, help). DESIGN.md §14 renders
# this table; ci.sh fails if instrumentation emits a name not listed here.
# --------------------------------------------------------------------------
GLOSSARY: Dict[str, tuple] = {
    # stats choke point (core/stats.stats_totals, all four stats classes)
    "search.queries": ("counter", "queries accounted through stats_totals"),
    "search.pages": ("counter", "logical 4KB pages touched (paper's axis)"),
    "search.candidates": ("counter", "rows scored by verification"),
    "search.exhausted": ("counter", "queries that hit a budget cap"),
    # per-phase span timings (host-orchestrated fused driver + runtime)
    "search.batch_us": ("histogram", "end-to-end search() batch wall µs"),
    "search.frontend_us": ("histogram", "select_frontend span µs"),
    "search.compensation_us": ("histogram", "Condition-B mask span µs"),
    "search.prefilter_us": ("histogram", "sketch prefilter round span µs"),
    "search.plan_us": ("histogram", "host tile planning span µs (includes "
                                    "the mask device->host pull)"),
    "search.verify_round_us": ("histogram", "one fused verify round µs"),
    "search.rescore_us": ("histogram", "shared top-k rescore span µs"),
    "search.merge_us": ("histogram", "stream segment merge span µs"),
    "search.prefilter_survivor_frac": ("gauge",
                                       "blocks surviving the sketch "
                                       "prefilter / blocks selected"),
    # fused driver round shape + jit-cache health
    "fused.rounds_dense": ("counter", "verify rounds on the dense path"),
    "fused.rounds_sparse": ("counter", "verify rounds on the gathered tile"),
    "fused.rounds_skipped": ("counter", "rounds skipped (empty union)"),
    "fused.rounds_cached": ("counter", "rounds served from the dense "
                                       "score cache (no new matmul)"),
    "fused.verify_retraces": ("gauge", "total verify-jit retraces ever "
                                       "(bounded ring's monotonic count)"),
    # sharded fan-out
    "sharded.fanout_us": ("histogram", "in-graph shard_map fan-out µs"),
    "sharded.dispatch_us": ("histogram", "host-merge per-shard dispatch µs "
                                         "(enqueue only: NOT fenced, shard "
                                         "searches overlap by design)"),
    "sharded.merge_us": ("histogram", "host k x shards merge µs (includes "
                                      "pulling per-shard results)"),
    # streaming index
    "stream.delta_appends": ("counter", "rows appended to delta segments"),
    "stream.deletes": ("counter", "rows tombstoned"),
    "stream.compactions": ("counter", "compactions installed (sync + bg)"),
    "stream.compaction_us": ("histogram", "synchronous compact() span µs"),
    # durability + fault handling (DESIGN.md §16)
    "stream.compaction_errors": ("counter", "background rebuild attempts "
                                            "that raised"),
    "stream.compaction_retries": ("counter", "failed rebuilds retried with "
                                             "backoff"),
    "stream.wal_appends": ("counter", "records appended to the WAL"),
    "stream.wal_bytes": ("counter", "bytes appended to the WAL"),
    "robust.faults_injected": ("counter", "armed fault points that fired"),
    # serve engine (DecodeEngine obs=True)
    "serve.requests_submitted": ("counter", "requests accepted by submit()"),
    "serve.requests_completed": ("counter", "requests finished (EOS/len)"),
    "serve.requests_shed": ("counter", "requests rejected: queue full"),
    "serve.tombstones": ("counter", "vocab ids retired via delete()"),
    "serve.decode_steps": ("counter", "engine decode steps"),
    "serve.pages": ("counter", "index pages touched by decode searches"),
    "serve.queue_wait_us": ("histogram", "submit -> slot admission µs"),
    # hot-query result cache (serve/qcache.py, DESIGN.md §17)
    "serve.cache_hits": ("counter", "decode searches served from the "
                                    "hot-query result cache"),
    "serve.cache_misses": ("counter", "decode searches that went to the "
                                      "index (cache cold/absent rows)"),
    "serve.cache_evictions": ("counter", "LRU evictions from the hot-query "
                                         "result cache"),
    "serve.request_us": ("histogram", "submit -> completion µs"),
    "serve.step_us": ("histogram", "one engine step µs"),
    "serve.slot_occupancy": ("gauge", "active slots / batch slots"),
    "serve.queue_depth": ("gauge", "queued requests after last step"),
    # serve degradation ladder (DESIGN.md §16)
    "serve.degradation_tier": ("gauge", "current budget tier (0 = full "
                                        "quality, higher = cheaper)"),
    "serve.tier_stepdowns": ("counter", "ladder transitions to a cheaper "
                                        "tier under overload"),
    "serve.tier_stepups": ("counter", "ladder recoveries to a richer tier"),
    "serve.deadline_expired": ("counter", "requests dropped past their "
                                          "deadline"),
    "serve.step_latency_ewma": ("gauge", "EWMA of engine step seconds "
                                         "(the shared robust watchdog)"),
}

_lock = threading.Lock()
_registry: Dict[str, object] = {}
_collectors: list = []
_enabled = False


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        with _lock:
            self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v) -> None:
        with _lock:
            self.value = float(v)


class Histogram:
    """log2 buckets: index i counts v in (2^(i-1), 2^i]; i=0 counts v<=1."""

    __slots__ = ("name", "count", "sum", "buckets")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.buckets: Dict[int, int] = {}

    @staticmethod
    def bucket_of(v: float) -> int:
        if v <= 1.0:
            return 0
        return int(math.ceil(math.log2(v)))

    def observe(self, v) -> None:
        v = float(v)
        b = self.bucket_of(v)
        with _lock:
            self.count += 1
            self.sum += v
            self.buckets[b] = self.buckets.get(b, 0) + 1

    def to_dict(self) -> dict:
        with _lock:
            return {"count": self.count, "sum": self.sum,
                    "mean": self.sum / self.count if self.count else 0.0,
                    "buckets": {str(k): v
                                for k, v in sorted(self.buckets.items())}}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


def _get(name: str, kind: str):
    decl = GLOSSARY.get(name)
    if decl is None:
        raise ValueError(
            f"undeclared metric name {name!r}: every metric must be listed "
            "in repro.obs.metrics.GLOSSARY (DESIGN.md §14 glossary)")
    if decl[0] != kind:
        raise ValueError(f"metric {name!r} is declared as a {decl[0]}, "
                         f"requested as a {kind}")
    inst = _registry.get(name)
    if inst is None:
        with _lock:
            inst = _registry.get(name)
            if inst is None:
                inst = _KINDS[kind](name)
                _registry[name] = inst
    return inst


def counter(name: str) -> Counter:
    return _get(name, "counter")


def gauge(name: str) -> Gauge:
    return _get(name, "gauge")


def histogram(name: str) -> Histogram:
    return _get(name, "histogram")


def enable() -> None:
    """Turn on the ambient feeds (the stats_totals choke point)."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def reset() -> None:
    """Drop every instrument (collectors stay registered)."""
    with _lock:
        _registry.clear()


def register_collector(fn: Callable[[], None]) -> None:
    with _lock:
        _collectors.append(fn)


def observe_search(totals: dict) -> None:
    """The `core/stats.stats_totals` choke-point feed. No-op (one bool
    check) unless `enable()` was called — the disabled path stays free."""
    if not _enabled:
        return
    counter("search.queries").inc(int(totals.get("queries", 0)))
    counter("search.pages").inc(int(totals.get("pages", 0)))
    counter("search.candidates").inc(int(totals.get("candidates", 0)))
    counter("search.exhausted").inc(int(totals.get("exhausted", 0)))


def snapshot() -> dict:
    """One plain dict of every live instrument: counters/gauges -> number,
    histograms -> {count, sum, mean, buckets}. Runs collectors first."""
    for fn in list(_collectors):
        fn()
    with _lock:
        items = list(_registry.items())
    out = {}
    for name, inst in items:
        out[name] = (inst.to_dict() if isinstance(inst, Histogram)
                     else inst.value)
    return out


def flush_jsonl(path: str, extra: dict = None) -> None:
    """Append one `snapshot()` line (plus ``extra`` fields) to ``path``."""
    import os
    rec = dict(extra or {})
    rec["metrics"] = snapshot()
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def _prom_name(name: str) -> str:
    return "repro_" + name.replace(".", "_")


def prometheus_text() -> str:
    """Prometheus text exposition (0.0.4): counters/gauges verbatim,
    histograms as cumulative ``_bucket{le=...}`` + ``_sum``/``_count``
    with le = 2^i upper bounds matching the log2 buckets."""
    for fn in list(_collectors):
        fn()
    with _lock:
        items = sorted(_registry.items())
    lines = []
    for name, inst in items:
        kind, help_text = GLOSSARY[name]
        pname = _prom_name(name)
        lines.append(f"# HELP {pname} {help_text}")
        if isinstance(inst, Histogram):
            lines.append(f"# TYPE {pname} histogram")
            cum = 0
            for b, cnt in sorted(inst.buckets.items()):
                cum += cnt
                lines.append(f'{pname}_bucket{{le="{float(2 ** b)}"}} {cum}')
            lines.append(f'{pname}_bucket{{le="+Inf"}} {inst.count}')
            lines.append(f"{pname}_sum {inst.sum}")
            lines.append(f"{pname}_count {inst.count}")
        else:
            lines.append(f"# TYPE {pname} "
                         f"{'counter' if kind == 'counter' else 'gauge'}")
            lines.append(f"{pname} {inst.value}")
    return "\n".join(lines) + "\n"
