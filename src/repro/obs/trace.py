"""Span tracer: ``with span("select_frontend"): ...`` (DESIGN.md §14).

Design constraints, in order:

1. **Disabled cost ~ one function call.** `span()` returns a shared no-op
   context manager when tracing is off (and the caller didn't force
   ``active=True``), so an instrumented hot path pays one global read, one
   branch and an empty ``with`` — a few hundred ns against search batches
   measured in milliseconds (the ci.sh obs guard holds this under 1%).
   Nothing here ever runs inside a jit trace: call sites are all
   host-orchestrated code, gated so the disabled path stays off the trace.

2. **Honest timings under jit need fencing.** JAX dispatches asynchronously:
   an un-fenced span around a jit call measures *enqueue* time, not device
   time — the cost surfaces in whichever later span first forces the value
   (a `np.asarray`, a `block_until_ready`). `sp.fence(x)` calls
   `jax.block_until_ready(x)` *only when fencing is configured on*
   (`enable(fence=True)`), so production tracing can stay async while
   benchmark/per-phase runs opt into sequential, attributable timings.
   Spans record whether they were fenced (`fenced` flag, exported in the
   Chrome trace args) so a reader can tell the two apart.

3. **Bounded storage, thread-safe.** Completed spans land in a ring buffer
   (default 8192) under a lock; a long-lived serve process can leave tracing
   on without unbounded growth. `total()` counts every span ever recorded.

`export_chrome_trace(path)` writes the standard ``{"traceEvents": [...]}``
JSON (``ph="X"`` complete events, µs timestamps) that chrome://tracing and
https://ui.perfetto.dev load directly. With ``annotate=True`` each span also
enters a `jax.profiler.TraceAnnotation`, so spans line up with XLA events in
a `jax.profiler.trace()` capture (SNIPPETS.md snippet 3).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

__all__ = ["span", "configure", "enable", "disable", "enabled", "fencing",
           "spans", "clear", "total", "export_chrome_trace"]

_lock = threading.Lock()
_enabled = False
_fence = False
_annotate = False
_capacity = 8192
_ring: list = []          # completed span dicts, append order, bounded
_total = 0                # every span ever recorded (monotonic)

_EPOCH_NS = time.perf_counter_ns()   # trace timestamps are relative to import


def configure(enabled: Optional[bool] = None, fence: Optional[bool] = None,
              annotate: Optional[bool] = None,
              capacity: Optional[int] = None) -> None:
    """Set any subset of the tracer's four knobs (None = leave unchanged)."""
    global _enabled, _fence, _annotate, _capacity
    with _lock:
        if enabled is not None:
            _enabled = bool(enabled)
        if fence is not None:
            _fence = bool(fence)
        if annotate is not None:
            _annotate = bool(annotate)
        if capacity is not None:
            if int(capacity) < 1:
                raise ValueError(f"capacity must be >= 1, got {capacity!r}")
            _capacity = int(capacity)
            del _ring[: max(0, len(_ring) - _capacity)]


def enable(fence: bool = False, annotate: bool = False) -> None:
    configure(enabled=True, fence=fence, annotate=annotate)


def disable() -> None:
    configure(enabled=False, fence=False, annotate=False)


def enabled() -> bool:
    return _enabled


def fencing() -> bool:
    return _fence


def clear() -> None:
    """Drop stored spans (does not reset `total()` — it is monotonic)."""
    with _lock:
        _ring.clear()


def total() -> int:
    return _total


def spans() -> list:
    """Completed spans (oldest first) as dicts:
    ``{name, t0_us, dur_us, tid, fenced}``. A snapshot copy — safe to
    iterate while other threads keep tracing."""
    with _lock:
        return list(_ring)


def _record(rec: dict) -> None:
    global _total
    with _lock:
        _total += 1
        _ring.append(rec)
        if len(_ring) > _capacity:
            del _ring[: len(_ring) - _capacity]


class _NullSpan:
    """Shared no-op span: the entire disabled-path cost."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def fence(self, x):
        return x


_NULL = _NullSpan()


class _Span:
    __slots__ = ("name", "metric", "_t0", "_fenced", "_ann")

    def __init__(self, name: str, metric: Optional[str]):
        self.name = name
        self.metric = metric
        self._fenced = False
        self._ann = None

    def __enter__(self):
        if _annotate:
            try:
                import jax
                self._ann = jax.profiler.TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:       # profiler backend absent: spans still work
                self._ann = None
        self._t0 = time.perf_counter_ns()
        return self

    def fence(self, x):
        """Block on ``x`` (any pytree of jax arrays) iff fencing is on.
        Returns ``x`` either way, so call sites read naturally."""
        if _fence and x is not None:
            import jax
            jax.block_until_ready(x)
            self._fenced = True
        return x

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        dur_us = (t1 - self._t0) / 1e3
        _record({"name": self.name,
                 "t0_us": (self._t0 - _EPOCH_NS) / 1e3,
                 "dur_us": dur_us,
                 "tid": threading.get_ident(),
                 "fenced": self._fenced})
        if self.metric is not None:
            from . import metrics
            metrics.histogram(self.metric).observe(dur_us)
        return False


def span(name: str, active: Optional[bool] = None,
         metric: Optional[str] = None):
    """Open a span. ``active=None`` follows the global switch; ``True``
    forces recording for this call (the `RuntimeConfig.obs` per-call
    opt-in), ``False`` forces the no-op. ``metric`` names a declared
    histogram (obs.metrics glossary) fed the span duration in µs."""
    if not (_enabled if active is None else active):
        return _NULL
    return _Span(name, metric)


def export_chrome_trace(path: str) -> str:
    """Write stored spans as Chrome trace-event JSON (Perfetto-loadable).
    Returns ``path``. One ``ph="X"`` complete event per span; ``args``
    carries the ``fenced`` flag so un-fenced (enqueue-time) spans are
    distinguishable from honest device timings."""
    recs = spans()
    tids = {}
    events = []
    for r in recs:
        tid = tids.setdefault(r["tid"], len(tids))
        events.append({"name": r["name"], "ph": "X", "pid": 0, "tid": tid,
                       "ts": r["t0_us"], "dur": r["dur_us"],
                       "cat": "repro.obs",
                       "args": {"fenced": r["fenced"]}})
    doc = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": {"exporter": "repro.obs.trace",
                         "span_count": len(events)}}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path
