"""Registered backends: ProMIPS family + the paper's §VIII-A1 baselines.

Each adapter maps one existing engine onto the `Searcher` protocol:

  promips         core/promips.ProMIPS through the unified device runtime
                  (two_phase FUSED block-sparse verification by default —
                  `core/search_fused.py` eagerly, the traceable
                  `core/search_graph.py` driver inside jit/shard_map; opts
                  select mode="progressive", norm_adaptive, cs_prune,
                  verification="batched"/"scan")
  promips-stream  stream/mutable.MutableProMIPS (mutation + compaction)
  sharded         core/sharded.MutableShardedProMIPS (range-routed shards,
                  mutation, host-side k x shards merge)
  exact           baselines/exact.ExactMIPS (ground-truth full scan)
  h2alsh          baselines/h2_alsh.H2ALSH
  pq              baselines/pq.PQBased
  rangelsh        baselines/range_lsh.RangeLSH

The ProMIPS family derives m / radii / budgets from the `GuaranteeConfig`
(m* from the Section V-B cost model unless the caller overrides ``m``;
x_p = Psi_m^{-1}(p0) is computed inside `build_index` from the same (c, p0));
baselines take (c, p0) as tuning hints only and report guaranteed=False.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import numpy as np

from ..baselines import ExactMIPS, H2ALSH, PQBased, RangeLSH
from ..core.index import IndexArrays, IndexMeta, ProMIPSIndex
from ..core.promips import ProMIPS
from ..core.runtime import RuntimeConfig
from ..core.runtime import search as runtime_search
from ..core.sharded import MutableShardedProMIPS
from ..stream.mutable import MutableProMIPS
from .base import Searcher
from .registry import register
from .types import Capabilities, GuaranteeConfig


def _runtime_from_opts(guarantee: GuaranteeConfig, mode: str,
                       verification: Optional[str],
                       norm_adaptive: Optional[bool],
                       cs_prune: Optional[bool], budget, budget2,
                       prefilter: bool = False,
                       prefilter_eps: Optional[float] = None,
                       obs: bool = False,
                       shape: Optional[tuple] = None) -> RuntimeConfig:
    """Map facade opts onto a `RuntimeConfig` with guarantee-safe defaults:
    budgets stay None (scan every selected block — the Theorem-2 bound
    requires no truncation) unless the caller explicitly trades them.
    ``prefilter`` turns on the quantized-sketch block prefilter; at the
    default ``prefilter_eps=1.0`` it is lossless, so the guarantee holds.
    ``obs`` turns on per-call span/metric instrumentation (DESIGN.md §14);
    results are bit-identical either way.

    ``verification=None`` / ``prefilter_eps=None`` consult the offline
    tuning cache for the ``shape=(n, d)`` point (`repro.tune`, DESIGN.md
    §15) and fall back to the hand-picked "fused" / 1.0 on a miss —
    bit-identical to passing them explicitly. The `RuntimeConfig` keeps its
    own None sentinels for dense_frac/tile_cap (resolved per-search)."""
    if mode == "progressive":
        norm_adaptive = True if norm_adaptive is None else norm_adaptive
        cs_prune = True if cs_prune is None else cs_prune
    if verification is None or prefilter_eps is None:
        from ..tune import cache as _tune_cache
        tuned = (_tune_cache.resolved("runtime", *shape) if shape is not None
                 else dict(_tune_cache.space.HAND_PICKED["runtime"]))
        if verification is None:
            verification = str(tuned["verification"])
        if prefilter_eps is None:
            # a tuned eps only ever describes a prefiltered workload; with
            # the prefilter off the knob is dead and stays at lossless 1.0
            prefilter_eps = (float(tuned["prefilter_eps"]) if prefilter
                             else 1.0)
    return RuntimeConfig(
        k=guarantee.k, budget=budget, budget2=budget2, mode=mode,
        verification=verification,
        norm_adaptive=bool(norm_adaptive) if norm_adaptive is not None else False,
        cs_prune=bool(cs_prune) if cs_prune is not None else False,
        prefilter=bool(prefilter), prefilter_eps=float(prefilter_eps),
        obs=bool(obs))


@register
class PromipsSearcher(Searcher):
    """Immutable ProMIPS index.

    ``search_path="device"`` (default) runs the unified jit'd runtime
    (`core/runtime.search`, fused block-sparse Pallas verification);
    ``search_path="host"`` runs the paper-faithful sequential NumPy search
    (`HostSearcher`) with the EXACT resident-4KB-page accounting the
    paper's figures count — the accuracy benchmarks select it through
    `METHOD_SPECS`, not by calling a different API.
    """

    name = "promips"
    capabilities = Capabilities(guaranteed=True, prefilter=True)

    def __init__(self, pm: ProMIPS, runtime: RuntimeConfig,
                 search_path: str = "device"):
        if search_path not in ("device", "host"):
            raise ValueError(f"unknown search_path {search_path!r}; valid "
                             "choices: device, host")
        self.pm = pm
        self.runtime = runtime
        self.search_path = search_path

    @classmethod
    def build(cls, x, *, guarantee, seed, page_bytes, m=None,
              mode="two_phase", verification=None, norm_adaptive=None,
              cs_prune=None, budget=None, budget2=None, norm_strata=None,
              prefilter=False, prefilter_eps=None, obs=False,
              search_path="device", **index_opts) -> "PromipsSearcher":
        plan = guarantee.derive(len(x))
        if norm_strata is None:
            # progressive mode's adaptive radii need norm-homogeneous
            # sub-partitions to bite (DESIGN.md §4)
            norm_strata = 4 if mode == "progressive" else 1
        pm = ProMIPS.build(x, m=plan.m if m is None else int(m),
                           c=guarantee.c, p=guarantee.p0,
                           page_bytes=page_bytes, seed=seed,
                           norm_strata=int(norm_strata), **index_opts)
        return cls(pm, _runtime_from_opts(guarantee, mode, verification,
                                          norm_adaptive, cs_prune,
                                          budget, budget2, prefilter,
                                          prefilter_eps, obs,
                                          shape=(len(x), int(x.shape[1]))),
                   search_path)

    def _search_host(self, queries, k, cfg: RuntimeConfig
                     ) -> Tuple[np.ndarray, np.ndarray, dict]:
        queries = np.asarray(queries, np.float32)
        ids = np.full((len(queries), k), -1, np.int64)
        scores = np.full((len(queries), k), -np.inf, np.float32)
        pages = candidates = exhausted = 0
        for i, q in enumerate(queries):
            if cfg.mode == "progressive":
                qi, qs, st = self.pm.search_host_progressive(
                    q, k=k, cs_prune=cfg.cs_prune)
            else:
                qi, qs, st = self.pm.search_host(
                    q, k=k, norm_adaptive=cfg.norm_adaptive,
                    cs_prune=cfg.cs_prune)
            ids[i], scores[i] = qi, qs
            d = st.to_dict()
            pages += d["pages"]
            candidates += d["candidates"]
            exhausted += d["exhausted"]
        return ids, scores, {"pages": pages, "candidates": candidates,
                             "exhausted": exhausted, "queries": len(queries)}

    def _search(self, queries, k, runtime: Optional[RuntimeConfig] = None
                ) -> Tuple[np.ndarray, np.ndarray, dict]:
        cfg = dataclasses.replace(self.runtime if runtime is None else runtime,
                                  k=k)
        if self.search_path == "host":
            return self._search_host(queries, k, cfg)
        ids, scores, stats = runtime_search(self.pm.arrays, self.pm.meta,
                                            queries, cfg)
        return np.asarray(ids), np.asarray(scores), stats.to_dict()

    @property
    def n(self) -> int:
        return self.pm.meta.n

    @property
    def dim(self) -> int:
        return self.pm.meta.d

    @property
    def index_bytes(self) -> int:
        return self.pm.meta.index_bytes

    def state(self) -> Tuple[dict, dict]:
        arrays = {f: np.asarray(getattr(self.pm.index.arrays, f))
                  for f in IndexArrays._fields}
        return arrays, dict(meta=dataclasses.asdict(self.pm.meta),
                            runtime=dataclasses.asdict(self.runtime),
                            search_path=self.search_path)

    @classmethod
    def from_state(cls, arrays, meta) -> "PromipsSearcher":
        index = ProMIPSIndex(
            arrays=IndexArrays(**{f: np.asarray(arrays[f])
                                  for f in IndexArrays._fields}),
            meta=IndexMeta(**meta["meta"]), layout=None)
        return cls(ProMIPS(index), RuntimeConfig(**meta["runtime"]),
                   meta.get("search_path", "device"))


class _MutableMixin:
    """Forwarders for the mutation contract (inner = stream-family object)."""

    def insert(self, ids, rows) -> None:
        self.inner.insert(ids, rows)

    def delete(self, ids) -> None:
        self.inner.delete(ids)

    def update(self, ids, rows) -> None:
        self.inner.update(ids, rows)

    def alive_items(self):
        return self.inner.alive_items()

    def compact(self) -> None:
        self.inner.compact()

    @property
    def n(self) -> int:
        return self.inner.n_alive

    @property
    def dim(self) -> int:
        return self.inner.d


@register
class StreamSearcher(_MutableMixin, Searcher):
    """Streaming ProMIPS (base + delta segments, tombstones, compaction)."""

    name = "promips-stream"
    capabilities = Capabilities(guaranteed=True, supports_mutation=True,
                                prefilter=True)

    def __init__(self, stream: MutableProMIPS, runtime: RuntimeConfig):
        self.inner = stream
        self.runtime = runtime

    @classmethod
    def build(cls, x, *, guarantee, seed, page_bytes, ids=None, m=None,
              mode="two_phase", verification=None, norm_adaptive=None,
              cs_prune=None, budget=None, budget2=None, norm_strata=1,
              prefilter=False, prefilter_eps=None, obs=False,
              delta_capacity=None, auto_compact=False, **index_opts
              ) -> "StreamSearcher":
        plan = guarantee.derive(len(x))
        stream = MutableProMIPS(
            x, ids=ids, delta_capacity=delta_capacity,
            auto_compact=auto_compact, m=plan.m if m is None else int(m),
            c=guarantee.c, p=guarantee.p0, page_bytes=page_bytes, seed=seed,
            norm_strata=int(norm_strata), **index_opts)
        return cls(stream, _runtime_from_opts(guarantee, mode, verification,
                                              norm_adaptive, cs_prune,
                                              budget, budget2, prefilter,
                                              prefilter_eps, obs,
                                              shape=(len(x),
                                                     int(x.shape[1]))))

    def _search(self, queries, k, runtime: Optional[RuntimeConfig] = None
                ) -> Tuple[np.ndarray, np.ndarray, dict]:
        cfg = self.runtime if runtime is None else runtime
        ids, scores, stats = self.inner.search(queries, k=k, runtime=cfg)
        return np.asarray(ids), np.asarray(scores), stats.to_dict()

    def flush(self, timeout=None) -> None:
        self.inner.join_compaction(timeout)

    # -- durability (robust/wal.py, DESIGN.md §16) ---------------------------
    def enable_wal(self, wal_dir: str, fsync: str = "os") -> str:
        """Make this index crash-safe: write an initial checksummed snapshot
        under ``wal_dir/snapshot`` and attach a write-ahead log at
        ``wal_dir/wal.log`` — every subsequent acknowledged mutation is
        logged before it is applied. `repro.robust.recover(wal_dir)`
        restores the exact state after a crash."""
        from ..robust.wal import WriteAheadLog
        self.flush()
        self.save(os.path.join(wal_dir, "snapshot"))
        self.inner.mark_wal_floor()
        self.inner.attach_wal(
            WriteAheadLog(os.path.join(wal_dir, "wal.log"), fsync=fsync,
                          fresh=True))
        self._wal_dir = wal_dir
        return wal_dir

    def checkpoint(self) -> str:
        """Fold the WAL into a fresh snapshot: save (atomic, checksummed),
        then truncate the log. A crash at ANY point is safe — the snapshot
        persists ``wal_seq`` and replay skips records at or below it, so
        dying between the save and the truncate only replays no-ops."""
        if getattr(self, "_wal_dir", None) is None:
            raise RuntimeError("no WAL attached (build with wal_dir= or "
                               "call enable_wal() first)")
        self.flush()
        self.save(os.path.join(self._wal_dir, "snapshot"))
        self.inner.mark_wal_floor()
        self.inner._wal.reset()
        return self._wal_dir

    def wal_lag(self) -> int:
        return self.inner.wal_lag()

    def maintenance_status(self) -> dict:
        """Compaction + WAL health for `engine.health()`."""
        comp = (self.inner.compactor.status()
                if self.inner.compactor is not None else None)
        return {"compaction": comp, "wal_attached": self.inner._wal is not None,
                "wal_lag": self.inner.wal_lag()}

    @property
    def index_bytes(self) -> int:
        base = self.inner.meta.index_bytes
        delta = self.inner._delta
        return base + delta.x.nbytes + delta.gids.nbytes + delta.alive.nbytes

    def state(self) -> Tuple[dict, dict]:
        self.flush()
        arrays, meta = self.inner.state_dict()
        return arrays, dict(meta, runtime=dataclasses.asdict(self.runtime))

    @classmethod
    def from_state(cls, arrays, meta) -> "StreamSearcher":
        runtime = RuntimeConfig(**meta["runtime"])
        return cls(MutableProMIPS.from_state(arrays, meta), runtime)


@register
class ShardedSearcher(_MutableMixin, Searcher):
    """Range-routed multi-shard streaming index (host k x shards merge)."""

    name = "sharded"
    capabilities = Capabilities(guaranteed=True, supports_mutation=True,
                                supports_sharding=True, prefilter=True)

    def __init__(self, sharded: MutableShardedProMIPS, runtime: RuntimeConfig):
        self.inner = sharded
        self.runtime = runtime

    @classmethod
    def build(cls, x, *, guarantee, seed, page_bytes, n_shards=2, m=None,
              mode="two_phase", verification=None, norm_adaptive=None,
              cs_prune=None, budget=None, budget2=None, norm_strata=1,
              prefilter=False, prefilter_eps=None, obs=False,
              delta_capacity=None, auto_compact=False, **index_opts
              ) -> "ShardedSearcher":
        # m* is derived from the PER-SHARD corpus size (each shard owns its
        # own Quick-Probe group table over ~n/n_shards points)
        plan = guarantee.derive(max(len(x) // max(int(n_shards), 1), 1))
        sharded = MutableShardedProMIPS(
            x, int(n_shards), delta_capacity=delta_capacity,
            auto_compact=auto_compact, m=plan.m if m is None else int(m),
            c=guarantee.c, p=guarantee.p0, page_bytes=page_bytes, seed=seed,
            norm_strata=int(norm_strata), **index_opts)
        # shards each hold ~n/n_shards points, which is what the tuned-entry
        # shape key should match (the per-shard search is what runs)
        return cls(sharded, _runtime_from_opts(
            guarantee, mode, verification, norm_adaptive, cs_prune,
            budget, budget2, prefilter, prefilter_eps, obs,
            shape=(max(len(x) // max(int(n_shards), 1), 1),
                   int(x.shape[1]))))

    def _search(self, queries, k, runtime: Optional[RuntimeConfig] = None
                ) -> Tuple[np.ndarray, np.ndarray, dict]:
        cfg = self.runtime if runtime is None else runtime
        ids, scores, stats = self.inner.search(queries, k=k, runtime=cfg)
        return np.asarray(ids), np.asarray(scores), stats.to_dict()

    def alive_items(self):
        gids, rows = [], []
        for shard in self.inner.shards:
            g, r = shard.alive_items()
            gids.append(g)
            rows.append(r)
        return np.concatenate(gids), np.concatenate(rows)

    def flush(self, timeout=None) -> None:
        for shard in self.inner.shards:
            shard.join_compaction(timeout)

    @property
    def dim(self) -> int:
        return self.inner.shards[0].d

    def maintenance_status(self) -> dict:
        """Aggregated per-shard compaction health (`engine.health()` hook):
        worst-case rollup — any shard's latched error surfaces in the
        ``compaction`` rollup; per-shard detail rides along."""
        per = [s.compactor.status() if s.compactor is not None else None
               for s in self.inner.shards]
        live = [p for p in per if p is not None]
        comp = None
        if live:
            comp = {
                "in_flight": any(p["in_flight"] for p in live),
                "runs": sum(p["runs"] for p in live),
                "failures": sum(p["failures"] for p in live),
                "retries": sum(p["retries"] for p in live),
                "error_latched": any(p["error_latched"] for p in live),
                "last_error": next((p["last_error"] for p in live
                                    if p["last_error"]), None),
                "shards": per,
            }
        return {"compaction": comp, "wal_attached": False, "wal_lag": 0}

    @property
    def index_bytes(self) -> int:
        return sum(s.meta.index_bytes for s in self.inner.shards)

    def state(self) -> Tuple[dict, dict]:
        self.flush()
        arrays, meta = self.inner.state_dict()
        return arrays, dict(meta, runtime=dataclasses.asdict(self.runtime))

    @classmethod
    def from_state(cls, arrays, meta) -> "ShardedSearcher":
        runtime = RuntimeConfig(**meta["runtime"])
        return cls(MutableShardedProMIPS.from_state(arrays, meta), runtime)


# ---------------------------------------------------------------------------
# Baselines: deterministic rebuild persistence (raw rows + ctor kwargs + seed)
# ---------------------------------------------------------------------------

class _BaselineSearcher(Searcher):
    """Shared adapter for the numpy baselines (single-query engines).

    Persistence saves the raw rows plus the constructor kwargs (explicit
    seed included); load re-runs the deterministic build, which is
    bit-identical by the seeded-RNG contract — the same trick compaction
    uses for `rebuild_base`.
    """

    inner_cls: type = None           # set by subclasses
    seeded = True                    # inner_cls accepts a ``seed`` kwarg

    def __init__(self, inner, x: np.ndarray, ctor: dict):
        self.inner = inner
        self._x = x
        self._ctor = ctor

    @classmethod
    def build(cls, x, *, guarantee, seed, page_bytes, **opts):
        ctor = dict(opts, page_bytes=int(page_bytes))
        if cls.seeded:
            ctor.setdefault("seed", int(seed))
        return cls(cls.inner_cls(**ctor).build(x), x, ctor)

    def _search(self, queries, k, **_ignored
                ) -> Tuple[np.ndarray, np.ndarray, dict]:
        queries = np.asarray(queries, np.float32)  # numpy engines below
        ids = np.full((len(queries), k), -1, np.int64)
        scores = np.full((len(queries), k), -np.inf, np.float32)
        pages = candidates = 0
        for i, q in enumerate(queries):
            qi, qs, st = self.inner.search(q, k=k)
            ids[i, : len(qi)] = qi
            scores[i, : len(qs)] = qs
            pages += int(st["pages"])
            candidates += int(st["candidates"])
        return ids, scores, {"pages": pages, "candidates": candidates,
                             "exhausted": 0, "queries": len(queries)}

    @property
    def n(self) -> int:
        return len(self._x)

    @property
    def dim(self) -> int:
        return int(self._x.shape[1])

    @property
    def index_bytes(self) -> int:
        return int(self.inner.index_bytes)

    def state(self) -> Tuple[dict, dict]:
        return {"x": self._x}, dict(ctor=self._ctor)

    @classmethod
    def from_state(cls, arrays, meta) -> "_BaselineSearcher":
        x = np.ascontiguousarray(arrays["x"], np.float32)
        ctor = dict(meta["ctor"])
        return cls(cls.inner_cls(**ctor).build(x), x, ctor)


@register
class ExactSearcher(_BaselineSearcher):
    name = "exact"
    # the full scan IS the guarantee (c=1, p0=1) and pays n/page_rows pages
    capabilities = Capabilities(guaranteed=True)
    inner_cls = ExactMIPS
    seeded = False


@register
class H2ALSHSearcher(_BaselineSearcher):
    name = "h2alsh"
    capabilities = Capabilities()
    inner_cls = H2ALSH


@register
class PQSearcher(_BaselineSearcher):
    name = "pq"
    capabilities = Capabilities()
    inner_cls = PQBased


@register
class RangeLSHSearcher(_BaselineSearcher):
    name = "rangelsh"
    capabilities = Capabilities()
    inner_cls = RangeLSH


__all__ = ["PromipsSearcher", "StreamSearcher", "ShardedSearcher",
           "ExactSearcher", "H2ALSHSearcher", "PQSearcher",
           "RangeLSHSearcher"]
