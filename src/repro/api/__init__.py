"""One Index API (DESIGN.md §9): a backend-agnostic facade over every
build-and-search engine in the repo.

>>> from repro import api
>>> s = api.build(x, backend="promips",
...               guarantee=api.GuaranteeConfig(c=0.9, p0=0.5, k=10))
>>> res = s.search(queries)                  # SearchResult(ids, scores, stats)
>>> s.save("idx_dir"); s2 = api.load("idx_dir")   # bit-identical round trip
>>> api.backends()
('exact', 'h2alsh', 'pq', 'promips', 'promips-stream', 'rangelsh', 'sharded')

Backends declare `Capabilities`; `supports_mutation` gates the uniform
insert/delete/update surface (`promips-stream`, `sharded`).
"""
from .base import (CorruptSnapshotError, Searcher, UnsupportedOperation,
                   read_header, saved_bytes)
from .registry import backends, build, get_backend, iter_backends, load, register
from .types import (Capabilities, GuaranteeConfig, GuaranteePlan,
                    SearchResult, STAT_KEYS)

# importing the module registers the built-in backends
from . import adapters as _builtin_adapters  # noqa: E402,F401

__all__ = [
    "CorruptSnapshotError", "Searcher", "UnsupportedOperation",
    "read_header", "saved_bytes",
    "backends", "build", "get_backend", "iter_backends", "load", "register",
    "Capabilities", "GuaranteeConfig", "GuaranteePlan", "SearchResult",
    "STAT_KEYS",
]
