"""`Searcher`: the one protocol every registered backend implements.

A backend is a class with

  - ``name`` / ``capabilities`` class attributes,
  - ``build(x, *, guarantee, seed, page_bytes, **opts)`` classmethod,
  - ``_search(queries, k, **opts)`` returning raw (ids, scores, stats dict),
  - ``state() -> (arrays, meta)`` / ``from_state(arrays, meta)`` for the
    on-disk format (DESIGN.md §9: one directory holding ``arrays.npz`` +
    ``meta.json`` with an explicit seed).

The base class owns everything that must behave identically across
backends: query normalization, wall-time stamping, the `SearchResult`
envelope, capability-gated mutation stubs, and save/load framing — so an
adapter only supplies the backend-specific core.
"""
from __future__ import annotations

import abc
import dataclasses
import json
import os
import time
from typing import ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..robust.snapshot import (CorruptSnapshotError, verify_dir,
                               write_atomic_dir)
from .types import Capabilities, GuaranteeConfig, SearchResult

FORMAT_NAME = "repro.api-index"
FORMAT_VERSION = 1
_ARRAYS_FILE = "arrays.npz"
_META_FILE = "meta.json"


class UnsupportedOperation(NotImplementedError):
    """A capability-gated operation was called on a backend lacking it."""


class Searcher(abc.ABC):
    """Backend-agnostic index handle: build -> search -> (mutate) -> save."""

    name: ClassVar[str]
    capabilities: ClassVar[Capabilities] = Capabilities()

    # re-stamped by the registry build()/load() paths; the defaults keep a
    # directly-constructed or from_state()-restored adapter fully usable
    guarantee: GuaranteeConfig = GuaranteeConfig()
    seed: int = 0
    build_seconds: float = 0.0

    # -- construction --------------------------------------------------------
    @classmethod
    @abc.abstractmethod
    def build(cls, x: np.ndarray, *, guarantee: GuaranteeConfig, seed: int,
              page_bytes: int, **opts) -> "Searcher":
        """Build an index over ``x`` ((n, d) float32) under ``guarantee``."""

    # -- search --------------------------------------------------------------
    @abc.abstractmethod
    def _search(self, queries: np.ndarray, k: int, **opts
                ) -> Tuple[np.ndarray, np.ndarray, dict]:
        """Backend core: (B, d) queries -> (ids (B,k), scores (B,k), stats)."""

    def search(self, queries, k: Optional[int] = None, **opts) -> SearchResult:
        """Batched c-k-AMIP search. ``queries``: (B, d) or a single (d,) row.

        ``k`` defaults to the guarantee's k. Extra ``opts`` are forwarded to
        the backend (e.g. ``runtime=RuntimeConfig(...)`` on the ProMIPS
        family); an option the backend does not understand is rejected
        (TypeError), never silently dropped.

        Device (jax) query arrays are passed through WITHOUT a host round
        trip — the serve engine calls this with on-device activations every
        decode step; numpy-only backends convert for themselves.

        Malformed queries (NaN/Inf, non-float dtype on device arrays, wrong
        dimensionality) are rejected with a ValueError HERE, before the jit
        path — a NaN would otherwise poison every score silently and a shape
        mismatch would surface as a cryptic retrace three layers down.
        """
        q = self._validate_queries(queries)
        k = int(self.guarantee.k if k is None else k)
        if k < 1:
            raise ValueError(f"k must be a positive int, got {k!r}")
        t0 = time.perf_counter()
        ids, scores, stats = self._search(q, k, **opts)
        stats = dict(stats)
        stats.setdefault("queries", q.shape[0])
        stats["wall_time_s"] = time.perf_counter() - t0
        return SearchResult(ids=ids, scores=scores, stats=stats)

    def _validate_queries(self, queries):
        """Boundary validation shared by every backend (and reused verbatim
        by `serve.DecodeEngine.submit` for prompt token arrays).

        Device arrays are validated on STATIC properties only (dtype, rank,
        trailing dim) — a finiteness check would force a device sync on the
        decode hot path; NaNs from a model bug still surface in the numpy
        path tests and the engine's own prompt validation.
        """
        d = self.dim
        if isinstance(queries, jax.Array):
            if not jnp.issubdtype(queries.dtype, jnp.floating):
                raise ValueError(
                    f"queries must be floating point, got dtype "
                    f"{queries.dtype} (cast activations before search)")
            if queries.ndim not in (1, 2):
                raise ValueError(f"queries must be (B, d) or (d,), got "
                                 f"shape {queries.shape}")
            q = queries if queries.ndim == 2 else queries[None, :]
        else:
            try:
                q = np.atleast_2d(np.asarray(queries, np.float32))
            except (TypeError, ValueError) as e:
                raise ValueError(f"queries are not castable to float32: {e}")
            if q.ndim != 2:
                raise ValueError(f"queries must be (B, d) or (d,), got "
                                 f"shape {np.asarray(queries).shape}")
            if not np.isfinite(q).all():
                bad = int(np.sum(~np.isfinite(q)))
                raise ValueError(
                    f"queries contain {bad} non-finite value(s) (NaN/Inf); "
                    "a NaN scores -inf against every row and silently "
                    "returns garbage neighbors — rejecting at the boundary")
        if d is not None and q.shape[1] != d:
            raise ValueError(f"queries have dimension {q.shape[1]}, index "
                             f"has dimension {d}")
        return q

    # -- capability-gated mutation surface -----------------------------------
    def _require_mutation(self, op: str) -> None:
        if not self.capabilities.supports_mutation:
            raise UnsupportedOperation(
                f"backend {self.name!r} does not support {op}() "
                "(capabilities.supports_mutation=False)")

    def insert(self, ids, rows) -> None:
        self._require_mutation("insert")
        raise NotImplementedError  # pragma: no cover — adapter must override

    def delete(self, ids) -> None:
        self._require_mutation("delete")
        raise NotImplementedError  # pragma: no cover

    def update(self, ids, rows) -> None:
        self._require_mutation("update")
        raise NotImplementedError  # pragma: no cover

    def alive_items(self):
        """(gids, rows) of every live row — the mutation contract's oracle
        hook (tests and examples score recall against an exact scan of it)."""
        self._require_mutation("alive_items")
        raise NotImplementedError  # pragma: no cover

    def flush(self, timeout: Optional[float] = None) -> None:
        """Wait for background maintenance (compaction); default no-op."""

    # -- introspection -------------------------------------------------------
    @property
    @abc.abstractmethod
    def n(self) -> int:
        """Number of (live) indexed rows."""

    @property
    @abc.abstractmethod
    def index_bytes(self) -> int:
        """In-memory index size (the paper's Fig. 4a metric; 0 = no index)."""

    @property
    def dim(self) -> Optional[int]:
        """Row dimensionality, for boundary validation; None = unknown
        (validation then skips the trailing-dim check)."""
        return None

    # -- persistence ---------------------------------------------------------
    @abc.abstractmethod
    def state(self) -> Tuple[dict, dict]:
        """(arrays, meta): numpy arrays for ``arrays.npz`` and a JSON-able
        backend meta dict. Together they must reconstruct a searcher whose
        post-load searches are bit-identical to this one's."""

    @classmethod
    @abc.abstractmethod
    def from_state(cls, arrays: dict, meta: dict) -> "Searcher":
        """Inverse of :meth:`state`."""

    def save(self, path: str) -> str:
        """Persist to ``path`` (a directory): arrays.npz + meta.json +
        manifest.json, written ATOMICALLY (temp dir + rename) with per-file
        SHA256 checksums — a crash mid-save leaves the previous snapshot
        intact, never a torn mix (DESIGN.md §16)."""
        arrays, backend_meta = self.state()
        header = {
            "format": FORMAT_NAME,
            "version": FORMAT_VERSION,
            "backend": self.name,
            "seed": int(self.seed),
            "guarantee": dataclasses.asdict(self.guarantee),
            "backend_meta": backend_meta,
        }
        def _write_meta(p):
            with open(p, "w") as f:
                json.dump(header, f, indent=1)

        write_atomic_dir(path, {
            _ARRAYS_FILE: lambda p: np.savez_compressed(p, **arrays),
            _META_FILE: _write_meta,
        }, manifest_extra={"format": FORMAT_NAME,
                           "version": FORMAT_VERSION})
        return path

    @classmethod
    def load(cls, path: str) -> "Searcher":
        header = read_header(path)
        if header["backend"] != cls.name:
            raise ValueError(f"index at {path!r} was saved by backend "
                             f"{header['backend']!r}, not {cls.name!r} "
                             "(use repro.api.load to dispatch)")
        with np.load(os.path.join(path, _ARRAYS_FILE)) as z:
            arrays = {key: z[key] for key in z.files}
        obj = cls.from_state(arrays, header["backend_meta"])
        obj.guarantee = GuaranteeConfig(**header["guarantee"])
        obj.seed = int(header["seed"])
        obj.build_seconds = 0.0
        return obj

def saved_bytes(path: str) -> int:
    """Real on-disk footprint of a saved index directory (quickstart and
    the --api bench both report it; one helper so they cannot drift)."""
    total = 0
    for root, _dirs, files in os.walk(path):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def read_header(path: str) -> dict:
    """Parse and validate the ``meta.json`` header of a saved index.

    Integrity first: every manifest-listed file is re-hashed and a mismatch
    raises `CorruptSnapshotError` naming the failing file (a manifest-less
    legacy directory loads unverified, with a warning)."""
    meta_path = os.path.join(path, _META_FILE)
    if not os.path.exists(meta_path):
        raise FileNotFoundError(f"no saved index at {path!r} "
                                f"(missing {_META_FILE})")
    verify_dir(path)
    with open(meta_path) as f:
        header = json.load(f)
    if header.get("format") != FORMAT_NAME:
        raise ValueError(f"{meta_path}: not a {FORMAT_NAME} file")
    if header.get("version") != FORMAT_VERSION:
        raise ValueError(f"{meta_path}: format version "
                         f"{header.get('version')!r} != {FORMAT_VERSION}")
    return header


__all__ = ["Searcher", "UnsupportedOperation", "CorruptSnapshotError",
           "read_header", "saved_bytes", "FORMAT_NAME", "FORMAT_VERSION"]
