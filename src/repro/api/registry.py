"""Backend registry: names -> `Searcher` classes, plus the two facade
entry points `build(x, backend=...)` and `load(path)`.

Registering a backend is the whole integration surface — benchmarks,
examples, the serve engine and the conformance/persistence test suites all
iterate `backends()` instead of hard-coding classes, so a new method is a
registry entry, not a new code path.
"""
from __future__ import annotations

import time
from typing import Dict, Iterator, Optional, Tuple, Type

import numpy as np

from .base import Searcher, read_header
from .types import Capabilities, GuaranteeConfig

_REGISTRY: Dict[str, Type[Searcher]] = {}


def register(cls: Type[Searcher]) -> Type[Searcher]:
    """Class decorator: add a `Searcher` subclass under ``cls.name``."""
    name = getattr(cls, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"{cls!r} must define a string `name`")
    if not isinstance(getattr(cls, "capabilities", None), Capabilities):
        raise ValueError(f"{cls!r} must define `capabilities`")
    _REGISTRY[name] = cls
    return cls


def backends() -> Tuple[str, ...]:
    """Registered backend names, sorted."""
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> Type[Searcher]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered backends: "
                         f"{', '.join(backends())}") from None


def iter_backends() -> Iterator[Tuple[str, Type[Searcher]]]:
    for name in backends():
        yield name, _REGISTRY[name]


def build(x: np.ndarray, backend: str = "promips", *,
          guarantee: Optional[GuaranteeConfig] = None,
          seed: int = 0, page_bytes: Optional[int] = None,
          wal_dir: Optional[str] = None, wal_fsync: str = "os",
          **opts) -> Searcher:
    """Build an index over ``x`` with the named backend.

    ``guarantee`` is the declarative contract (c, p0, k); backends with
    ``capabilities.guaranteed`` derive m / radii / budgets from it
    (`GuaranteeConfig.derive`), the rest use it for tuning only. ``seed``
    makes the build bit-reproducible; ``opts`` are backend-specific
    overrides (e.g. ``m=8``, ``mode="progressive"``, ``n_shards=4``).

    ``page_bytes=None`` (default) consults the offline tuning cache
    (`repro.tune.cache`) for this data shape; an explicit value always
    wins, and with no cache entry the hand-picked 4096 is used.

    ``wal_dir`` (mutable backends only) makes the index crash-safe: an
    initial checksummed snapshot plus a write-ahead log land under that
    directory, every acknowledged mutation is logged before it is applied,
    and `repro.robust.recover(wal_dir)` restores the exact pre-crash state
    (DESIGN.md §16). ``wal_fsync`` picks the durability/latency trade
    ("always" | "os" | "never").
    """
    cls = get_backend(backend)
    guarantee = GuaranteeConfig() if guarantee is None else guarantee
    x = np.ascontiguousarray(x, np.float32)
    if x.ndim != 2:
        raise ValueError(f"x must be (n, d), got shape {x.shape}")
    if page_bytes is None:
        from ..tune import cache as _tune_cache
        page_bytes = int(_tune_cache.resolved(
            "build", x.shape[0], x.shape[1])["page_bytes"])
    t0 = time.perf_counter()
    searcher = cls.build(x, guarantee=guarantee, seed=int(seed),
                         page_bytes=int(page_bytes), **opts)
    searcher.guarantee = guarantee
    searcher.seed = int(seed)
    searcher.build_seconds = time.perf_counter() - t0
    if wal_dir is not None:
        # after the guarantee/seed stamps, so the initial snapshot's header
        # carries them (recover() round-trips the full facade state)
        if not hasattr(searcher, "enable_wal"):
            raise ValueError(f"backend {backend!r} does not support wal_dir= "
                             "(write-ahead logging needs a mutable "
                             "promips-stream index)")
        searcher.enable_wal(wal_dir, fsync=wal_fsync)
    return searcher


def load(path: str) -> Searcher:
    """Load a saved index, dispatching on the backend recorded in meta.json."""
    header = read_header(path)
    return get_backend(header["backend"]).load(path)


__all__ = ["register", "backends", "get_backend", "iter_backends", "build",
           "load"]
