"""Shared value types of the unified index API (DESIGN.md §9).

The paper's user contract is declarative: "return c-AMIP results with
probability >= p0" (Theorems 1-2). `GuaranteeConfig` captures exactly that
triple — (c, p0, k) — and *derives* the internal knobs (projected dimension
m via the Section V-B cost model, the chi-square radius threshold
x_p = Psi_m^{-1}(p0), Quick-Probe scan budgets) so callers never pick raw
budgets. `SearchResult` is the one return type every registered backend
produces; `Capabilities` is the static feature matrix that gates the
mutation / sharding / guarantee surfaces.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..core.chi2 import chi2_ppf_host
from ..core.dim_opt import optimized_projected_dimension, quick_probe_cost


@dataclass(frozen=True)
class Capabilities:
    """Static feature flags of one backend (checked, not duck-typed)."""

    supports_mutation: bool = False   # insert/delete/update after build
    supports_sharding: bool = False   # corpus split over multiple sub-indexes
    guaranteed: bool = False          # honors the (c, p0) probability contract
    prefilter: bool = False           # quantized-sketch block prefilter
                                      # (RuntimeConfig.prefilter / eps knob)


@dataclass(frozen=True)
class GuaranteePlan:
    """Everything `GuaranteeConfig.derive` computed from (c, p0, n).

    ``budget``/``budget2`` are None — "scan every selected block" — because
    any finite truncation voids the Theorem-2 probability bound; they exist
    so a caller who *knowingly* trades the guarantee for latency has a
    single place to override.
    """

    m: int                    # projected dimension m* (Section V-B argmin)
    x_p: float                # Psi_m^{-1}(p0): the static radius threshold
    probe_cost: float         # Quick-Probe cost 2^m (m+1) + n / 2^m at m*
    probe_groups: int         # group-scan budget: at most 2^m groups exist
    budget: Optional[int] = None
    budget2: Optional[int] = None


@dataclass(frozen=True)
class GuaranteeConfig:
    """Guarantee-first build/search configuration: the paper's (c, p0, k).

    c  — approximation ratio of the c-AMIP contract (0 < c <= 1).
    p0 — success probability: P[returned o has <o,q> >= c * <o*,q>] >= p0.
    k  — results per query.

    Backends that set `Capabilities.guaranteed` derive every internal knob
    from this (see :meth:`derive`); the others receive it for (c, p0)-aware
    tuning but cannot promise the bound.
    """

    c: float = 0.9
    p0: float = 0.5
    k: int = 10

    def __post_init__(self):
        if not 0.0 < self.c <= 1.0:
            raise ValueError(f"c must be in (0, 1], got {self.c!r}")
        if not 0.0 < self.p0 < 1.0:
            raise ValueError(f"p0 must be in (0, 1), got {self.p0!r}")
        if not isinstance(self.k, (int, np.integer)) or self.k < 1:
            raise ValueError(f"k must be a positive int, got {self.k!r}")

    def derive(self, n: int) -> GuaranteePlan:
        """Derive the internal knobs for a corpus of ``n`` points.

        m* minimizes the Quick-Probe cost model f(m) = 2^m (m+1) + n / 2^m
        (`core/dim_opt`, paper Section V-B); x_p = Psi_m^{-1}(p0) is the
        compile-time chi-square threshold every radius computation
        (Conditions B, Test A, compensation radius) is driven by.
        """
        m = min(optimized_projected_dimension(max(int(n), 1)), 30)
        return GuaranteePlan(
            m=m,
            x_p=float(chi2_ppf_host(self.p0, m)),
            probe_cost=quick_probe_cost(m, int(n)),
            probe_groups=2 ** m,
            budget=None,
            budget2=None,
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# Normalized stats contract: every backend's SearchResult.stats carries
# exactly these keys (satellite: SearchStats/HostStats/StreamStats.to_dict
# produce the first four; the facade stamps wall_time_s).
STAT_KEYS = ("pages", "candidates", "exhausted", "queries", "wall_time_s")


@dataclass
class SearchResult:
    """Uniform result of one batched search across every backend.

    ids    — (B, k) int64 global ids (-1 = empty slot).
    scores — (B, k) float32 exact inner products, descending per row.
    stats  — normalized accounting dict (STAT_KEYS): total logical page
             accesses, total verified candidates, number of
             budget-exhausted queries, query count, and wall time.
    """

    ids: np.ndarray
    scores: np.ndarray
    stats: dict = field(default_factory=dict)

    def __post_init__(self):
        self.ids = np.asarray(self.ids, np.int64)
        self.scores = np.asarray(self.scores, np.float32)

    @property
    def pages(self) -> int:
        return int(self.stats.get("pages", 0))

    @property
    def candidates(self) -> int:
        return int(self.stats.get("candidates", 0))

    @property
    def wall_time_s(self) -> float:
        return float(self.stats.get("wall_time_s", 0.0))

    def to_dict(self) -> dict:
        """JSON-able summary (benchmark emitters)."""
        return {"ids": self.ids.tolist(), "scores": self.scores.tolist(),
                "stats": dict(self.stats)}


__all__ = ["Capabilities", "GuaranteeConfig", "GuaranteePlan", "SearchResult",
           "STAT_KEYS"]
