"""Chi-square CDF / inverse-CDF used by ProMIPS Conditions B and Test A.

The paper's probability machinery (Lemma 2, Theorem 2, Formula 2/3) needs
``Psi_m(x)`` — the CDF of the chi-square distribution with ``m`` degrees of
freedom — and its inverse ``Psi_m^{-1}(p)``.

``Psi_m(x) = P(m/2, x/2)`` where ``P`` is the regularized lower incomplete
gamma function, available in-graph as ``jax.scipy.special.gammainc``.

The inverse is only ever needed for *static* (config-time) pairs ``(p, m)``
— the search threshold ``x_p = Psi_m^{-1}(p)`` is a compile-time constant —
so we provide a SciPy host helper plus a jit-able bisection fallback used by
tests and any in-graph consumer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.scipy.special import gammainc


def chi2_cdf(x: jax.Array, m: float) -> jax.Array:
    """Psi_m(x): CDF of chi-square with ``m`` dof. Elementwise in ``x``."""
    x = jnp.asarray(x)
    return jnp.where(x > 0, gammainc(m / 2.0, jnp.maximum(x, 0.0) / 2.0), 0.0)


def chi2_ppf_host(p: float, m: float) -> float:
    """Psi_m^{-1}(p) on host (SciPy). Use for static thresholds."""
    from scipy.stats import chi2 as _chi2

    return float(_chi2.ppf(p, m))


@functools.partial(jax.jit, static_argnames=("m", "iters"))
def chi2_ppf(p: jax.Array, m: int, iters: int = 96) -> jax.Array:
    """Psi_m^{-1}(p) via bisection on ``chi2_cdf`` — jit-able, elementwise.

    The bracket ``[0, m + 24*sqrt(2m) + 64]`` covers p < 1 - 1e-12 for the
    small m (<= 32) ProMIPS uses.
    """
    p = jnp.asarray(p, jnp.float32)
    hi0 = jnp.float32(m + 24.0 * (2.0 * m) ** 0.5 + 64.0)
    lo = jnp.zeros_like(p)
    hi = jnp.full_like(p, hi0)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        below = chi2_cdf(mid, m) < p
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)
