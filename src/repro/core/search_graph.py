"""In-graph fused two-phase search: the fused `kernels/block_mips` rounds
with tile sizing done INSIDE the jit graph, so ``verification="fused"`` is
one traceable function — it runs under `jax.jit`, inside `shard_map`
(`core/sharded.sharded_search`'s per-shard search) and anywhere else the
host-orchestrated driver (`core/search_fused.py`) cannot, with bit-identical
results.

The host driver pulls each round's (B, NB) selection to host and sizes the
verification tile to ``next_pow2(union_count)`` blocks. In a trace the union
count is an abstract value, so the tile shape cannot depend on it — instead
every pow2 bucket the host driver could have chosen is compiled as one
branch of a `jax.lax.switch`:

  buckets  = [1, 2, 4, ..., cap]  (pow2s below the budget cap, then the cap)
  branch b = one `ops.block_mips` round over a ``buckets[b]``-slot tile whose
             slot list is the cap-surviving union blocks in layout order
             (`truncate_union` then `argsort(~keep, stable)` — the same
             best-first truncation + layout-order walk as the batched
             backend's tile)
  index    = searchsorted(buckets, union_count): the smallest bucket that
             holds the union, i.e. exactly the host driver's
             ``min(next_pow2(union), cap)`` rule

plus one DENSE branch (walk every block of ``x`` in place, no gather) taken
when the union covers >= `search_fused.DENSE_FRAC` of all blocks and the cap
allows — again the host driver's rule. Only the selected branch executes at
runtime; the others cost compile time bounded by O(log n_blocks) branch
bodies, compiled ONCE inside the single enclosing jit entry (the retrace
bound DESIGN.md §12 documents — contrast the host driver, which holds one
jit cache entry per bucket).

An empty union selects the smallest bucket with an all-False ``sel``: the
round is an identity on the carried top-k with zero pages/candidates —
bit-identical to the host driver's host-side skip — so no `lax.cond` wrapper
is needed for round 1; the compensation round keeps the batched backend's
`lax.cond` skip since its union is empty for most batches.

Results (ids, scores, every `SearchStats` field) are bit-identical to BOTH
`search_fused.search_batch_fused` and ``verification="batched"`` at every
budget: the tile-cap rule (the ``budget`` best-priority union blocks, walked
in layout order) and
the per-round accounting are the same; a bucketed tile only carries padding
slots whose ``sel`` column is False. tests/test_fused_verification.py
asserts this under jit and tests/test_distributed.py under shard_map.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ops
from .index import IndexArrays, IndexMeta
from .search_device import (SearchStats, TopK, block_priority,
                            compensation_masks, prefilter_round1,
                            prefilter_round2, select_frontend,
                            truncate_union)
from .search_fused import DENSE_FRAC


def _tile_buckets(cap: int) -> tuple:
    """Static pow2 tile sizes for a ``cap``-block budget: every value
    ``min(next_pow2(u), cap)`` can take for u in [1, n_blocks]."""
    sizes = []
    s = 1
    while s < cap:
        sizes.append(s)
        s <<= 1
    sizes.append(cap)
    return tuple(sizes)


def _fused_round_graph(arrays: IndexArrays, queries, mask, top: TopK, c_half,
                       k: int, cap: int, n_blocks: int, page_rows: int,
                       use_pallas: Optional[bool],
                       dense_frac: float = DENSE_FRAC, prio=None):
    """One traceable fused verification round over the (B, NB) ``mask``.

    Returns (TopK, pages (B,), cand (B,), done_a (B,), lost (B,)) with the
    exact semantics of one host-driver round (`search_fused._verify` over
    `search_fused._plan_tile`'s tile) — bucket choice and all. ``prio``
    ranks union blocks for a truncating ``cap`` (`truncate_union`), the
    same rule both other drivers apply. The body sits under a
    `jax.named_scope` so the rounds are identifiable in XLA profiles even
    though this driver never leaves the trace (DESIGN.md §14).
    """
    union = jnp.any(mask, axis=0)                              # (NB,)
    n_union = jnp.sum(union.astype(jnp.int32))
    keep = truncate_union(union, prio, cap)
    n_keep = jnp.sum(keep.astype(jnp.int32))
    order = jnp.argsort(~keep, stable=True).astype(jnp.int32)  # kept first
    valid = arrays.ids >= 0
    sizes = _tile_buckets(cap)
    have_dense = cap >= n_blocks

    def make_branch(n_slots: int, dense: bool):
        def branch(_):
            if dense:
                slots = jnp.arange(n_blocks, dtype=jnp.int32)
                sel = mask
                slot_valid = jnp.ones((n_blocks,), bool)
            else:
                slots = order[:n_slots]
                slot_valid = jnp.arange(n_slots) < n_keep
                sel = jnp.take(mask, slots, axis=1) & slot_valid[None, :]
            top_s, top_r, cnt, pages, cand = ops.block_mips(
                arrays.x, valid, queries, slots, sel, top.scores, top.rows,
                c_half, k=k, page_rows=page_rows, dense=dense,
                use_pallas=use_pallas)
            # branches must agree in output shape: reduce the (B, NS) hit
            # counts (NS differs per bucket) to the (B,) total the
            # Condition-A test consumes
            hits = jnp.sum(cnt, axis=1)
            in_tile = jnp.zeros(n_blocks, bool).at[slots].set(slot_valid)
            lost = jnp.any(mask & ~in_tile[None, :], axis=1)
            return top_s, top_r, pages, cand, hits, lost
        return branch

    def bucketed(_):
        # smallest bucket that holds the union == min(next_pow2(n_union),
        # cap); an empty union lands on bucket 0 with sel all-False (an
        # identity round)
        branches = [make_branch(ns, False) for ns in sizes]
        idx = jnp.minimum(jnp.searchsorted(jnp.asarray(sizes), n_union),
                          len(sizes) - 1)
        return jax.lax.switch(idx, branches, None)

    with jax.named_scope("fused_verify_round"):
        if have_dense:
            # The dense fast path sits OUTSIDE the bucket switch, behind a
            # plain two-way cond: on the XLA CPU backend a many-branch switch
            # carrying the full corpus in every branch closure costs real
            # per-call overhead, while a cond is free — and in the dense
            # regime (union >= dense_frac) the bucket switch would pick a
            # full-size tile anyway. Small unions take the switch, whose
            # branches then only carry small tiles.
            top_s, top_r, pages, cand, hits, lost = jax.lax.cond(
                n_union >= dense_frac * n_blocks,
                make_branch(n_blocks, True), bucketed, None)
        else:
            top_s, top_r, pages, cand, hits, lost = bucketed(None)
        # "running k-th best >= threshold" <=> "n0 + total selected hits >= k"
        # (same reduction as search_fused._verify)
        n0 = jnp.sum(top.scores >= c_half[:, None], axis=1)
        done_a = (n0 + hits) >= k
    return TopK(scores=top_s, rows=top_r), pages, cand, done_a, lost


def search_batch_fused_graph(
    arrays: IndexArrays,
    meta: IndexMeta,
    queries: jnp.ndarray,
    k: int = 10,
    budget: int = 64,
    budget2: int = 64,
    norm_adaptive: bool = False,
    cs_prune: bool = False,
    use_pallas: Optional[bool] = None,
    prefilter: bool = False,
    prefilter_eps: float = 1.0,
    dense_frac: float = DENSE_FRAC,
    tile_cap: Optional[int] = None,
):
    """c-k-AMIP search, fused backend, fully in-graph. Same contract (and
    bit-identical results at every budget) as `search_fused.search_batch_fused`
    — but traceable: `search_device.search_batch` dispatches
    ``verification="fused"`` here, so jit'd callers and `sharded_search`'s
    shard_map run the fused kernel instead of the batched full-tile graph.

    The ``prefilter`` sketch stage calls the SAME `search_fused` prefilter
    functions the host driver jit-wraps — same expressions, same dispatch —
    which is what keeps the two drivers bit-identical with it enabled.
    """
    n_blocks = meta.n_blocks
    n_batch = queries.shape[0]
    cap = min(budget, n_blocks)
    cap2 = min(budget2, n_blocks)
    if tile_cap is not None:
        # same clamp as the host driver: the tuner-promoted tile knob caps
        # both rounds below the budget rule (a no-op when >= n_blocks)
        cap = min(cap, int(tile_cap))
        cap2 = min(cap2, int(tile_cap))

    q_proj, q_l2sq, d_sp, r0, probe_ok, c_half, mask0 = select_frontend(
        arrays, meta, queries)
    # same best-first truncation key as the batched / host-fused drivers,
    # only materialized when a finite cap can actually truncate
    prio = (block_priority(arrays, q_proj)
            if min(cap, cap2) < n_blocks else None)
    mask_r1 = mask0
    sk_est = sk_bnd = sk_bvalid = None
    if prefilter:
        mask_r1, sk_est, sk_bnd, sk_bvalid = prefilter_round1(
            arrays, queries, mask0, k, meta.page_rows, prefilter_eps,
            use_pallas)
    # strong f32 init (same reason as the host driver: round 2 carries the
    # strong-typed round-1 output back in)
    top = TopK(scores=jnp.full((n_batch, k), -jnp.inf, jnp.float32),
               rows=jnp.full((n_batch, k), -1, jnp.int32))

    top, pages1, cand1, done_a, lost1 = _fused_round_graph(
        arrays, queries, mask_r1, top, c_half, k, cap, n_blocks,
        meta.page_rows, use_pallas, dense_frac, prio=prio)
    # same barrier as the batched graph: stops XLA CPU re-materializing
    # round-1 fusions inside the round-2 consumers
    top, done_a, mask0 = jax.lax.optimization_barrier((top, done_a, mask0))

    s_k = top.scores[:, k - 1]
    need2, r1, mask1 = compensation_masks(arrays, meta, d_sp, q_l2sq, s_k, r0,
                                          done_a, mask0, norm_adaptive,
                                          cs_prune)
    mask_r2 = mask1
    if prefilter:
        mask_r2 = prefilter_round2(mask1, sk_est, sk_bnd, sk_bvalid, s_k)

    # An empty compensation union is the common case (every query stopped by
    # A/B in round 1); the skip branch is the identity the host driver takes
    # on host, so results stay bit-identical either way.
    def round2(args):
        mask_r2, top = args
        out_top, pages, cand, _, lost = _fused_round_graph(
            arrays, queries, mask_r2, top, c_half, k, cap2, n_blocks,
            meta.page_rows, use_pallas, dense_frac, prio=prio)
        return out_top, pages, cand, lost

    def skip2(args):
        _, top = args
        zero = jnp.zeros(n_batch, jnp.int32)
        return top, zero, zero, jnp.zeros(n_batch, bool)

    top, pages2, cand2, lost2 = jax.lax.cond(
        jnp.any(mask_r2), round2, skip2, (mask_r2, top))

    stats = SearchStats(
        pages=pages1 + pages2,
        candidates=cand1 + cand2,
        probe_passed=probe_ok,
        used_round2=need2,
        radius0=r0,
        radius1=jnp.where(need2, r1, 0.0),
        exhausted=lost1 | (need2 & lost2),
        rows=top.rows,
    )
    ids = jnp.where(top.rows >= 0, arrays.ids[jnp.maximum(top.rows, 0)], -1)
    return ids, top.scores, stats


__all__ = ["search_batch_fused_graph", "_tile_buckets"]
