"""Accuracy metrics from the paper's evaluation (Section VIII-A3)."""
from __future__ import annotations

import numpy as np


def overall_ratio(returned_scores: np.ndarray, exact_scores: np.ndarray) -> float:
    """(1/k) sum_i <o_i, q> / <o_i*, q> — paper's 'Overall Ratio'.

    Both arrays are descending top-k inner products for one query. Pairs are
    compared rank-by-rank. Non-positive exact scores are guarded (ratio
    clipped into [0, 1] contribution as in the reference implementations).
    """
    r = np.asarray(returned_scores, np.float64)
    e = np.asarray(exact_scores, np.float64)
    k = min(len(r), len(e))
    r, e = r[:k], e[:k]
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(e > 0, r / e, 1.0)
    return float(np.clip(ratio, 0.0, 1.0).mean())


def recall_at_k(returned_ids: np.ndarray, exact_ids: np.ndarray) -> float:
    """t/k where t = |returned ∩ exact top-k| — paper's 'Recall'."""
    k = len(exact_ids)
    if k == 0:
        return 1.0
    return len(set(map(int, returned_ids[:k])) & set(map(int, exact_ids))) / k
