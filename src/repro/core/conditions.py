"""ProMIPS stopping conditions (paper Section IV).

Condition A (Theorem 1, deterministic):
    ||o_M||^2 + ||q||^2 - 2<o_i,q>/c <= 0
    => a c-AMIP point has certainly been returned already.

Condition B (Theorem 2, probabilistic):
    Psi_m( dis^2(P(o_i),P(q)) / (||o_M||^2 + ||q||^2 - 2<o_max,q>/c) ) >= p
    => a c-AMIP point has been returned with probability >= p.

For c-k-AMIP both conditions use the current k-th best inner product
(o_max^k) in place of o_max (paper, end of Section IV).

All functions are elementwise / broadcastable and jit-safe; `best_ip` is the
running (k-th) maximum inner product, `proj_dist_sq` is the squared distance
in the projected space at the current search frontier.
"""
from __future__ import annotations

import jax.numpy as jnp

from .chi2 import chi2_cdf


def condition_a(best_ip, max_l2sq, q_l2sq, c: float):
    """Theorem 1 test. True => terminate, result is exact-guaranteed."""
    return max_l2sq + q_l2sq - 2.0 * best_ip / c <= 0.0


def condition_b_denominator(best_ip, max_l2sq, q_l2sq, c: float):
    """||o_M||^2 + ||q||^2 - 2<o_max,q>/c (the Formula 2 denominator)."""
    return max_l2sq + q_l2sq - 2.0 * best_ip / c


def condition_b(proj_dist_sq, best_ip, max_l2sq, q_l2sq, c: float, p: float, m: int):
    """Theorem 2 test. True => terminate with probability-p guarantee."""
    denom = condition_b_denominator(best_ip, max_l2sq, q_l2sq, c)
    # denom <= 0 is exactly Condition A — already guaranteed.
    ratio = proj_dist_sq / jnp.maximum(denom, 1e-30)
    return jnp.where(denom <= 0.0, True, chi2_cdf(ratio, m) >= p)


def condition_b_threshold(proj_dist_sq, best_ip, max_l2sq, q_l2sq, c: float, x_p):
    """Condition B via the precomputed static threshold x_p = Psi_m^{-1}(p).

    Psi_m(t) >= p  <=>  t >= x_p (Psi_m is monotone), avoiding in-graph
    gammainc. Used on the device hot path.
    """
    denom = condition_b_denominator(best_ip, max_l2sq, q_l2sq, c)
    return jnp.where(denom <= 0.0, True, proj_dist_sq >= x_p * denom)


def compensation_radius(best_ip, max_l2sq, q_l2sq, c: float, x_p):
    """r' = sqrt(Psi_m^{-1}(p) * (||o_M||^2 + ||q||^2 - 2<o_max,q>/c)).

    The Algorithm 3 expanded range when the Quick-Probe estimate failed
    Condition B. Non-positive denominators (Condition A territory) map to 0.
    """
    denom = condition_b_denominator(best_ip, max_l2sq, q_l2sq, c)
    return jnp.sqrt(jnp.maximum(x_p * denom, 0.0))
