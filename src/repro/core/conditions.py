"""ProMIPS stopping conditions (paper Section IV).

Condition A (Theorem 1, deterministic):
    ||o_M||^2 + ||q||^2 - 2<o_i,q>/c <= 0
    => a c-AMIP point has certainly been returned already.

Condition B (Theorem 2, probabilistic):
    Psi_m( dis^2(P(o_i),P(q)) / (||o_M||^2 + ||q||^2 - 2<o_max,q>/c) ) >= p
    => a c-AMIP point has been returned with probability >= p.

For c-k-AMIP both conditions use the current k-th best inner product
(o_max^k) in place of o_max (paper, end of Section IV).

All functions are elementwise / broadcastable and jit-safe; `best_ip` is the
running (k-th) maximum inner product, `proj_dist_sq` is the squared distance
in the projected space at the current search frontier.

The arithmetic lives in `search_common` (the backend-neutral core shared by
the host and device search paths); this module re-exports the jnp-default
forms and adds the in-graph chi-square variant of Condition B.
"""
from __future__ import annotations

import jax.numpy as jnp

from .chi2 import chi2_cdf
from .search_common import (  # noqa: F401  (re-exported public API)
    compensation_radius,
    condition_a,
    condition_b_denominator,
)
from .search_common import condition_b as condition_b_threshold  # noqa: F401


def condition_b(proj_dist_sq, best_ip, max_l2sq, q_l2sq, c: float, p: float, m: int):
    """Theorem 2 test via in-graph chi-square CDF (dynamic p). True =>
    terminate with probability-p guarantee. The hot paths use the static
    threshold form `condition_b_threshold` instead."""
    denom = condition_b_denominator(best_ip, max_l2sq, q_l2sq, c)
    # denom <= 0 is exactly Condition A — already guaranteed.
    ratio = proj_dist_sq / jnp.maximum(denom, 1e-30)
    return jnp.where(denom <= 0.0, True, chi2_cdf(ratio, m) >= p)
