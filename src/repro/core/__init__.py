# The paper's primary contribution: probability-guaranteed c-AMIP search
# with a lightweight (iDistance) index — ProMIPS, in JAX.
from .chi2 import chi2_cdf, chi2_ppf, chi2_ppf_host
from .conditions import (
    compensation_radius,
    condition_a,
    condition_b,
    condition_b_threshold,
)
from .dim_opt import optimized_projected_dimension, quick_probe_cost
from .index import IndexArrays, IndexMeta, ProMIPSIndex, build_index
from .metrics import overall_ratio, recall_at_k
from .projections import make_projection, project
from .promips import ProMIPS
from .quick_probe import (
    GroupTable,
    build_group_table,
    group_lower_bounds,
    pack_codes,
    pack_codes_np,
    quick_probe,
    quick_probe_batch,
    unpack_bits,
)
from .runtime import RuntimeConfig, search_segments
from .runtime import search as runtime_search
from .search_device import SearchStats, search_batch, search_batch_progressive
from .search_fused import search_batch_fused
from .search_host import HostSearcher, HostStats

# -- unified facade re-exports (lazy: repro.api imports this package) --------
# `repro.api` is the one index API (DESIGN.md §9): build(x, backend=...) over
# promips / promips-stream / sharded / exact / h2alsh / pq / rangelsh with a
# guarantee-first GuaranteeConfig(c, p0, k) and save/load persistence. The
# legacy entry points below (`ProMIPS.build(...).search(...)`, the baseline
# classes) keep working as thin shims over the same engines.
_FACADE_EXPORTS = {
    "build_searcher": "build",
    "load_searcher": "load",
    "Searcher": "Searcher",
    "SearchResult": "SearchResult",
    "GuaranteeConfig": "GuaranteeConfig",
    "Capabilities": "Capabilities",
}


def __getattr__(name):
    if name in _FACADE_EXPORTS:
        from .. import api
        return getattr(api, _FACADE_EXPORTS[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "build_searcher", "load_searcher", "Searcher", "SearchResult",
    "GuaranteeConfig", "Capabilities",
    "ProMIPS", "ProMIPSIndex", "IndexArrays", "IndexMeta", "build_index",
    "chi2_cdf", "chi2_ppf", "chi2_ppf_host",
    "condition_a", "condition_b", "condition_b_threshold", "compensation_radius",
    "optimized_projected_dimension", "quick_probe_cost",
    "make_projection", "project",
    "GroupTable", "build_group_table", "group_lower_bounds",
    "pack_codes", "pack_codes_np", "quick_probe", "quick_probe_batch",
    "unpack_bits",
    "SearchStats", "search_batch", "search_batch_fused",
    "search_batch_progressive",
    "RuntimeConfig", "runtime_search", "search_segments",
    "HostSearcher", "HostStats",
    "overall_ratio", "recall_at_k",
]
