"""Unified two-phase search runtime (DESIGN.md §3.2).

Single entry point the device, sharded and serve layers all call. A
`RuntimeConfig` names the algorithm (`mode`) and the candidate-verification
backend (`verification`); the runtime clamps budgets to the index size and
dispatches to the jit'd implementations in `search_device`:

  mode="two_phase"   Algorithm 3 (Quick-Probe + range + compensation round);
                     verification="fused" (default) runs the fused
                     block-sparse rounds (`kernels/block_mips` walks the
                     selected pages in place, tiles sized to
                     next_pow2(union)) — host-orchestrated when called
                     eagerly (`core/search_fused.py`), and as the fully
                     in-graph `core/search_graph.py` driver under any
                     ambient jit / shard_map trace, so the fused kernel is
                     the one verification path at every scale; "batched" is
                     the single-graph full-tile union path, bit-identical
                     to "fused" at every budget; "scan" is the legacy
                     per-query lax.scan, kept as the semantics reference /
                     benchmark baseline.
                     All three are identical at the default full budget; a
                     finite ``budget`` caps the SHARED union tile under
                     "fused"/"batched" vs each query's own selection under
                     "scan" (affected queries are flagged ``exhausted``).
  mode="progressive" beyond-paper norm-adaptive frontier search.

All modes return the same (ids (B, k), scores (B, k), SearchStats) triple.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..obs import trace as _trace
from ..obs.trace import span as _span
from .index import IndexArrays, IndexMeta
from .search_common import DENSE_FRAC, next_pow2
from .search_device import SearchStats, search_batch, search_batch_progressive
from .search_fused import search_batch_fused


@jax.jit
def _rescore(x, rows, queries):
    """Exact f32 inner products for the returned candidate rows.

    Every search backend reports scores through this one compiled function,
    so "scan" and "batched" verification return BIT-IDENTICAL scores (inside
    a fused search graph XLA may re-associate the verification dots
    differently per backend; the candidate SETS are identical, so one shared
    rescore of the k winners removes the ULP-level noise from the API).
    """
    cand = jnp.take(x, jnp.maximum(rows, 0), axis=0)     # (B, k, d)
    s = jnp.einsum("bkd,bd->bk", cand, queries)
    return jnp.where(rows >= 0, s, -jnp.inf)


VALID_MODES = ("two_phase", "progressive")
VALID_VERIFICATIONS = ("fused", "batched", "scan")


@dataclass(frozen=True)
class RuntimeConfig:
    """Static (hashable) search-runtime configuration.

    Validated EAGERLY: an unknown ``mode``/``verification`` or a
    non-positive ``k``/``budget`` raises `ValueError` at construction (and
    again at `search()` entry, for configs built before this check existed)
    with the valid choices named — instead of failing deep inside the jit'd
    device path.
    """

    k: int = 10
    budget: Optional[int] = None       # None => all blocks (no truncation)
    budget2: Optional[int] = None      # compensation round; None => budget
    mode: str = "two_phase"            # "two_phase" | "progressive"
    verification: str = "fused"        # "fused" | "batched" | "scan"
                                       # (two_phase only)
    norm_adaptive: bool = False
    cs_prune: bool = False
    use_pallas: Optional[bool] = None   # None => Pallas on TPU, jnp oracle off-TPU
    prefilter: bool = False            # quantized-sketch block prefilter
    prefilter_eps: float = 1.0         # sketch-bound scale; 1.0 = lossless,
                                       # smaller prunes harder (DESIGN.md §13)
    obs: bool = False                  # per-call span/metric instrumentation
                                       # (also on whenever obs.trace is
                                       # globally enabled; DESIGN.md §14)
    # Fused tile knobs, promoted from `search_fused` module constants so the
    # offline tuner (`repro.tune`, DESIGN.md §15) can set them per shape.
    # None => consult the tuning cache (results/tune/tuning.json) for this
    # index's (n-bucket, d, platform, jax version) key; a missing key falls
    # back to the hand-picked values (dense_frac=0.9, no extra cap) —
    # bit-identical to the pre-tuner behavior. Explicit values always win;
    # pass ``tile_cap >= n_blocks`` for an explicit "no cap".
    dense_frac: Optional[float] = None  # dense-path threshold (result-
                                        # bit-identical at any value)
    tile_cap: Optional[int] = None      # extra clamp on both rounds' fused
                                        # verification tiles (below budget)

    def __post_init__(self):
        # integer-valued knobs are accepted and coerced (prefilter_eps=1 is
        # the lossless sketch bound, not an error)
        for field_name in ("prefilter_eps", "dense_frac"):
            v = getattr(self, field_name)
            if isinstance(v, (int, np.integer)) and not isinstance(v, bool):
                object.__setattr__(self, field_name, float(v))
        self.validate()

    def validate(self) -> None:
        if self.mode not in VALID_MODES:
            raise ValueError(f"unknown search mode: {self.mode!r}; valid "
                             f"choices: {', '.join(VALID_MODES)}")
        if self.verification not in VALID_VERIFICATIONS:
            raise ValueError(
                f"unknown verification backend: {self.verification!r}; valid "
                f"choices: {', '.join(VALID_VERIFICATIONS)}")
        if not isinstance(self.k, (int, np.integer)) or self.k < 1:
            raise ValueError(f"k must be a positive int, got {self.k!r}")
        for field_name in ("budget", "budget2"):
            v = getattr(self, field_name)
            if v is None:
                continue
            if not isinstance(v, (int, np.integer)) or v < 1:
                raise ValueError(f"{field_name} must be None (= all blocks) "
                                 f"or a positive int, got {v!r}")
        if not isinstance(self.prefilter, bool):
            raise ValueError(f"prefilter must be a bool, got "
                             f"{self.prefilter!r}")
        if not isinstance(self.obs, bool):
            raise ValueError(f"obs must be a bool, got {self.obs!r}")
        eps = self.prefilter_eps
        if not isinstance(eps, (int, float, np.floating)) or isinstance(
                eps, bool) or not 0.0 < float(eps) <= 1.0:
            raise ValueError(f"prefilter_eps must be a float in (0, 1], got "
                             f"{eps!r}")
        df = self.dense_frac
        if df is not None and (
                not isinstance(df, (int, float, np.floating))
                or isinstance(df, bool) or not 0.0 < float(df) <= 1.0):
            raise ValueError(f"dense_frac must be None (= tuned/default) or "
                             f"a float in (0, 1], got {df!r}")
        tc = self.tile_cap
        if tc is not None and (not isinstance(tc, (int, np.integer))
                               or isinstance(tc, bool) or tc < 1):
            raise ValueError(f"tile_cap must be None (= tuned/default) or a "
                             f"positive int, got {tc!r}")


def search(arrays: IndexArrays, meta: IndexMeta, queries,
           cfg: RuntimeConfig = RuntimeConfig()):
    """Run one batched c-k-AMIP search under ``cfg``.

    queries: (B, d). Returns (ids (B, k), scores (B, k), SearchStats).
    Safe to call inside jit / shard_map (the underlying functions are jit'd
    with static meta/config arguments).
    """
    cfg.validate()  # fail fast, naming valid choices, before the jit'd path
    if cfg.prefilter and not meta.sk_subspaces:
        raise ValueError(
            "prefilter=True but the index carries no sketch (built before "
            "the sketch existed?); rebuild the index or disable prefilter")
    if cfg.prefilter and cfg.mode != "two_phase":
        raise ValueError("prefilter is only supported in two_phase mode")
    budget = int(min(cfg.budget if cfg.budget is not None else meta.n_blocks,
                     meta.n_blocks))
    budget2 = int(min(cfg.budget2 if cfg.budget2 is not None else budget,
                      meta.n_blocks))
    # Resolve the tuner-promoted fused tile knobs: explicit cfg values win;
    # None consults the offline tuning cache for this index's shape key and
    # falls back to the hand-picked defaults on a miss (bit-identical to the
    # pre-tuner behavior — guarded by tests/test_tune.py). Pure host-side
    # python over static meta fields, so it is trace-safe.
    dense_frac, tile_cap = cfg.dense_frac, cfg.tile_cap
    if cfg.mode == "two_phase" and cfg.verification == "fused" and (
            dense_frac is None or tile_cap is None):
        from ..tune import cache as _tune_cache
        tuned = _tune_cache.resolved("runtime", meta.n, meta.d)
        if dense_frac is None:
            dense_frac = float(tuned.get("dense_frac", DENSE_FRAC))
        if tile_cap is None:
            tc = tuned.get("tile_cap")
            tile_cap = int(tc) if tc is not None else None
    elif dense_frac is None:
        dense_frac = DENSE_FRAC
    q = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
    # Host spans only make sense OUTSIDE an ambient trace (inside one they
    # would time jaxpr construction, not work — DESIGN.md §14); the check is
    # shared with the fused-driver routing below.
    clean = jax.core.trace_state_clean()
    active = clean and (cfg.obs or _trace.enabled())
    with _span("search", active=active, metric="search.batch_us") as sp_e2e:
        if cfg.mode == "progressive":
            ids, _, stats = search_batch_progressive(arrays, meta, q,
                                                     k=cfg.k, budget=budget,
                                                     cs_prune=cfg.cs_prune)
        elif cfg.mode == "two_phase":
            if cfg.verification == "fused" and clean:
                # Host-orchestrated fused rounds (tiles sized on host, an
                # empty round skipped outright, the dense-round score cache
                # on the CPU oracle). Under ANY ambient trace (jit /
                # shard_map — even when `queries` itself is a closed-over
                # concrete array, the index arrays may be traced)
                # `search_batch` runs the bit-identical IN-GRAPH fused
                # driver (`core/search_graph.py`) instead: same block_mips
                # kernel, pow2 tile buckets as lax.switch branches.
                ids, _, stats = search_batch_fused(
                    arrays, meta, q, k=cfg.k, budget=budget, budget2=budget2,
                    norm_adaptive=cfg.norm_adaptive, cs_prune=cfg.cs_prune,
                    use_pallas=cfg.use_pallas, prefilter=cfg.prefilter,
                    prefilter_eps=cfg.prefilter_eps, obs=active,
                    dense_frac=dense_frac, tile_cap=tile_cap)
            else:
                ids, _, stats = search_batch(arrays, meta, q, k=cfg.k,
                                             budget=budget, budget2=budget2,
                                             norm_adaptive=cfg.norm_adaptive,
                                             cs_prune=cfg.cs_prune,
                                             verification=cfg.verification,
                                             use_pallas=cfg.use_pallas,
                                             prefilter=cfg.prefilter,
                                             prefilter_eps=cfg.prefilter_eps,
                                             dense_frac=dense_frac,
                                             tile_cap=tile_cap)
        else:
            raise ValueError(f"unknown search mode: {cfg.mode!r}")
        with _span("rescore", active=active,
                   metric="search.rescore_us") as sp:
            scores = sp.fence(_rescore(arrays.x, stats.rows, q))
        sp_e2e.fence((ids, scores))
    return ids, scores, stats


# ---------------------------------------------------------------------------
# Segment-aware entry (streaming index, DESIGN.md §8)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "use_pallas"))
def _merge_segments(base_alive, rows, base_ids, base_scores, delta_x,
                    delta_gids, delta_valid, queries, k, use_pallas):
    """Merge base top-k_base with the exact-scored delta segment.

    ``base_scores`` are the `_rescore`d exact inner products `search` already
    computed; here tombstoned rows are masked to -inf. Every delta row is
    scored exactly in one `ops.mips_score` call (the same verification kernel
    the batched two-phase runtime uses). One `lax.top_k` over the
    concatenation is the same merge rule as `search_common.topk_merge`
    (ties break toward the base entry).
    """
    alive = (rows >= 0) & jnp.take(base_alive, jnp.maximum(rows, 0), axis=0)
    b_scores = jnp.where(alive, base_scores, -jnp.inf)
    b_ids = jnp.where(alive, base_ids, -1)

    d_scores = ops.mips_score(delta_x, queries, delta_valid,
                              use_pallas=use_pallas).T        # (B, cap)
    d_scores = jnp.where(delta_valid[None, :], d_scores, -jnp.inf)
    d_ids = jnp.broadcast_to(jnp.where(delta_valid, delta_gids, -1),
                             d_scores.shape)

    merged_s = jnp.concatenate([b_scores, d_scores], axis=1)
    merged_i = jnp.concatenate([b_ids, d_ids], axis=1)
    best_s, pos = jax.lax.top_k(merged_s, k)
    return jnp.take_along_axis(merged_i, pos, axis=1), best_s


def search_segments(snap, queries, cfg: RuntimeConfig = RuntimeConfig()):
    """Batched c-k-AMIP search over a streaming `stream.segments.Snapshot`.

    Runs the configured base search over the immutable base segment —
    over-fetching ``k + next_pow2(n_base_dead)`` results so tombstoned rows
    cannot crowd live ones out of the top-k (the quantization bounds jit
    recompiles to O(log n) distinct shapes between compactions) — then
    merges in the delta segment's exact scores. On a ``clean`` snapshot
    (no tombstones, empty delta) this is EXACTLY `search` on the base
    arrays: bit-identical ids and scores to a cold-built index.

    Returns (global ids (B, k), scores (B, k), StreamStats).
    """
    from ..stream.segments import StreamStats  # deferred: stream imports us

    q = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
    meta = snap.meta
    if snap.clean:
        ids, scores, stats = search(snap.arrays, meta, q, cfg)
        return ids, scores, StreamStats(pages=stats.pages,
                                        candidates=stats.candidates,
                                        exhausted=stats.exhausted, base=stats)

    k_base = min(cfg.k + (next_pow2(snap.n_base_dead) if snap.n_base_dead
                          else 0), meta.n_pad)
    ids_b, scores_b, stats = search(snap.arrays, meta, q,
                                    dataclasses.replace(cfg, k=k_base))
    active = ((cfg.obs or _trace.enabled())
              and jax.core.trace_state_clean())
    with _span("segments_merge", active=active,
               metric="search.merge_us") as sp:
        ids, scores = _merge_segments(snap.base_alive, stats.rows, ids_b,
                                      scores_b, snap.delta_x, snap.delta_gids,
                                      snap.delta_valid, q, cfg.k,
                                      cfg.use_pallas)
        sp.fence((ids, scores))
    delta_pages = -(-snap.delta_count // meta.page_rows)  # logical delta sweep
    return ids, scores, StreamStats(
        pages=stats.pages + jnp.int32(delta_pages),
        candidates=stats.candidates + jnp.sum(snap.delta_valid.astype(jnp.int32)),
        exhausted=stats.exhausted,
        base=stats,
    )


__all__ = ["RuntimeConfig", "SearchStats", "next_pow2", "search",
           "search_segments"]
