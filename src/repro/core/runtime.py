"""Unified two-phase search runtime (DESIGN.md §3.2).

Single entry point the device, sharded and serve layers all call. A
`RuntimeConfig` names the algorithm (`mode`) and the candidate-verification
backend (`verification`); the runtime clamps budgets to the index size and
dispatches to the jit'd implementations in `search_device`:

  mode="two_phase"   Algorithm 3 (Quick-Probe + range + compensation round);
                     verification="batched" unions the per-query block
                     selections and scores them in one `kernels/ops.mips_score`
                     call per round (the fast path), "scan" is the legacy
                     per-query lax.scan, kept as the semantics reference /
                     benchmark baseline. Results are identical at the default
                     full budget; a finite ``budget`` caps the SHARED union
                     tile under "batched" vs each query's own selection under
                     "scan" (affected queries are flagged ``exhausted``).
  mode="progressive" beyond-paper norm-adaptive frontier search.

All modes return the same (ids (B, k), scores (B, k), SearchStats) triple.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from .index import IndexArrays, IndexMeta
from .search_device import SearchStats, search_batch, search_batch_progressive


@jax.jit
def _rescore(x, rows, queries):
    """Exact f32 inner products for the returned candidate rows.

    Every search backend reports scores through this one compiled function,
    so "scan" and "batched" verification return BIT-IDENTICAL scores (inside
    a fused search graph XLA may re-associate the verification dots
    differently per backend; the candidate SETS are identical, so one shared
    rescore of the k winners removes the ULP-level noise from the API).
    """
    cand = jnp.take(x, jnp.maximum(rows, 0), axis=0)     # (B, k, d)
    s = jnp.einsum("bkd,bd->bk", cand, queries)
    return jnp.where(rows >= 0, s, -jnp.inf)


@dataclass(frozen=True)
class RuntimeConfig:
    """Static (hashable) search-runtime configuration."""

    k: int = 10
    budget: Optional[int] = None       # None => all blocks (no truncation)
    budget2: Optional[int] = None      # compensation round; None => budget
    mode: str = "two_phase"            # "two_phase" | "progressive"
    verification: str = "batched"      # "batched" | "scan" (two_phase only)
    norm_adaptive: bool = False
    cs_prune: bool = False
    use_pallas: Optional[bool] = None   # None => Pallas on TPU, jnp oracle off-TPU


def search(arrays: IndexArrays, meta: IndexMeta, queries,
           cfg: RuntimeConfig = RuntimeConfig()):
    """Run one batched c-k-AMIP search under ``cfg``.

    queries: (B, d). Returns (ids (B, k), scores (B, k), SearchStats).
    Safe to call inside jit / shard_map (the underlying functions are jit'd
    with static meta/config arguments).
    """
    budget = int(min(cfg.budget if cfg.budget is not None else meta.n_blocks,
                     meta.n_blocks))
    budget2 = int(min(cfg.budget2 if cfg.budget2 is not None else budget,
                      meta.n_blocks))
    q = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
    if cfg.mode == "progressive":
        ids, _, stats = search_batch_progressive(arrays, meta, q, k=cfg.k,
                                                 budget=budget,
                                                 cs_prune=cfg.cs_prune)
    elif cfg.mode == "two_phase":
        ids, _, stats = search_batch(arrays, meta, q, k=cfg.k, budget=budget,
                                     budget2=budget2,
                                     norm_adaptive=cfg.norm_adaptive,
                                     cs_prune=cfg.cs_prune,
                                     verification=cfg.verification,
                                     use_pallas=cfg.use_pallas)
    else:
        raise ValueError(f"unknown search mode: {cfg.mode!r}")
    return ids, _rescore(arrays.x, stats.rows, q), stats


__all__ = ["RuntimeConfig", "SearchStats", "search"]
