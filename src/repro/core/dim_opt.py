"""Optimized projected dimension (paper Section V-B).

Quick-Probe cost model: m bits split the dataset into up to 2^m groups;
computing the group lower bounds costs 2^m (m+1) and scanning one group
costs n / 2^m, so  f(m) = 2^m (m+1) + n / 2^m  is convex in m and the
optimum is  m* = argmin f(m).
"""
from __future__ import annotations


def quick_probe_cost(m: int, n: int) -> float:
    return float(2**m) * (m + 1) + n / float(2**m)


def optimized_projected_dimension(n: int, m_min: int = 2, m_max: int = 24) -> int:
    """m* = argmin_m 2^m (m+1) + n / 2^m over the practical range."""
    best_m, best_cost = m_min, float("inf")
    for m in range(m_min, m_max + 1):
        cost = quick_probe_cost(m, n)
        if cost < best_cost:
            best_m, best_cost = m, cost
    return best_m
