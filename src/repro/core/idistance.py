"""iDistance with the paper's new partition pattern (Section VI, Algorithm 4).

Build (host / pre-processing):
  1. k-means the projected points into k_p partitions (pivots O_i, radii r_i);
  2. ring keys  I(p) = i*C + floor(dis(p, O_i) / eps)   (Formula 6), with
     eps = r_avg / N_key (r_avg = mean first-stage cluster radius) and C a
     constant exceeding the max per-partition key span;
  3. k-means each (partition, ring) bucket into k_sp sub-partitions, each
     carrying a pivot + radius for sphere-intersection filtering;
  4. lay points out contiguously per sub-partition (the paper's "collectively
     organized on disks in order").

TPU adaptation (DESIGN.md §3): the B+-tree over keys becomes a sorted
permutation + dense offset tables — `searchsorted` plays the role of the
B+-tree descent, sub-partition ranges are contiguous DMA-able segments, and
fixed-size blocks of `page_rows` rows play the role of 4 KB disk pages.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _pairwise_d2(x: np.ndarray, c: np.ndarray) -> np.ndarray:
    """(n, k) squared distances via the expanded form (no (n,k,d) temps)."""
    xx = (x * x).sum(1)[:, None]
    cc = (c * c).sum(1)[None, :]
    return np.maximum(xx + cc - 2.0 * (x @ c.T), 0.0)


def kmeans_np(x: np.ndarray, k: int, iters: int = 25, seed: int = 0):
    """Lloyd's k-means. k-means++ seeding for small k, random distinct
    seeding for large k (build-time speed). Returns (centers, assign)."""
    n = x.shape[0]
    rng = np.random.RandomState(seed)
    k = max(1, min(k, n))
    x = np.asarray(x, np.float32)
    if k <= 32:
        centers = [x[rng.randint(n)]]
        for _ in range(1, k):
            d2 = _pairwise_d2(x, np.asarray(centers, np.float32)).min(1)
            tot = d2.sum()
            if tot <= 0:
                centers.append(x[rng.randint(n)])
                continue
            centers.append(x[np.searchsorted(np.cumsum(d2 / tot), rng.rand())])
        centers = np.asarray(centers, np.float32)
    else:
        centers = x[rng.choice(n, size=k, replace=False)].copy()
    assign = np.zeros(n, np.int64)
    for it in range(iters):
        new_assign = _pairwise_d2(x, centers).argmin(1)
        if np.array_equal(new_assign, assign) and it > 0:
            break
        assign = new_assign
        # vectorised center update
        counts = np.bincount(assign, minlength=k).astype(np.float32)
        sums = np.zeros_like(centers)
        np.add.at(sums, assign, x)
        nonzero = counts > 0
        centers[nonzero] = sums[nonzero] / counts[nonzero, None]
    return centers, assign


@dataclass(frozen=True)
class IDistanceLayout:
    """Host-side build product (everything in the *sorted* order)."""

    perm: np.ndarray          # (n,) permutation: sorted_row -> original row
    part_center: np.ndarray   # (k_p, m) first-stage pivots O_i
    part_radius: np.ndarray   # (k_p,)   first-stage radii
    eps: float                # ring width (Formula 6)
    c_key: int                # the constant C in Formula 6
    keys: np.ndarray          # (n,) iDistance keys, sorted ascending
    sp_center: np.ndarray     # (S, m) sub-partition pivots
    sp_radius: np.ndarray     # (S,)   sub-partition radii
    sp_start: np.ndarray      # (S+1,) row offsets (contiguous segments)
    sp_key: np.ndarray        # (S,)   iDistance key of each sub-partition
    sp_part: np.ndarray       # (S,)   first-stage partition of each sub-partition


def build_idistance(
    p_pts: np.ndarray,
    k_p: int = 5,
    n_key: int = 40,
    k_sp: int = 10,
    seed: int = 0,
) -> IDistanceLayout:
    """Algorithm 4 (steps 2-6): two-stage partitioning of projected points."""
    n, m = p_pts.shape
    part_center, assign = kmeans_np(p_pts, k_p, seed=seed)
    k_p = part_center.shape[0]
    dist = np.linalg.norm(p_pts - part_center[assign], axis=1)
    part_radius = np.zeros(k_p, np.float32)
    for i in range(k_p):
        mask = assign == i
        part_radius[i] = dist[mask].max() if mask.any() else 0.0
    r_avg = float(part_radius[part_radius > 0].mean()) if (part_radius > 0).any() else 1.0
    eps = max(r_avg / n_key, 1e-6)
    ring = np.floor(dist / eps).astype(np.int64)
    c_key = int(ring.max()) + 2
    keys = assign * c_key + ring  # Formula 6

    perm_parts: list[np.ndarray] = []
    sp_center, sp_radius, sp_key, sp_part, sp_sizes = [], [], [], [], []
    for i in range(k_p):
        for rk in np.unique(ring[assign == i]):
            rows = np.nonzero((assign == i) & (ring == rk))[0]
            centers, sub = kmeans_np(p_pts[rows], min(k_sp, len(rows)), seed=seed + 1)
            for j in range(centers.shape[0]):
                member = rows[sub == j]
                if len(member) == 0:
                    continue
                d = np.linalg.norm(p_pts[member] - centers[j], axis=1)
                perm_parts.append(member)
                sp_center.append(centers[j])
                sp_radius.append(d.max())
                sp_key.append(i * c_key + rk)
                sp_part.append(i)
                sp_sizes.append(len(member))

    perm = np.concatenate(perm_parts).astype(np.int64)
    sp_start = np.concatenate([[0], np.cumsum(sp_sizes)]).astype(np.int64)
    return IDistanceLayout(
        perm=perm,
        part_center=part_center.astype(np.float32),
        part_radius=part_radius,
        eps=float(eps),
        c_key=c_key,
        keys=keys[perm],
        sp_center=np.asarray(sp_center, np.float32),
        sp_radius=np.asarray(sp_radius, np.float32),
        sp_start=sp_start,
        sp_key=np.asarray(sp_key, np.int64),
        sp_part=np.asarray(sp_part, np.int64),
    )


def ring_key_range(layout: IDistanceLayout, q_proj: np.ndarray, radius: float):
    """The B+-tree key ranges a range-search sphere touches (host mode).

    For each first-stage partition i, the sphere (q, r) intersects rings with
    dis(q, O_i) - r <= ring*eps (+eps) <= dis(q, O_i) + r, clipped to the
    partition's radius — the classic iDistance range-search key window.
    Returns a list of (key_lo, key_hi) inclusive windows; used by the host
    searcher for faithful page accounting of the B+-tree descent.
    """
    windows = []
    for i in range(layout.part_center.shape[0]):
        dq = float(np.linalg.norm(q_proj - layout.part_center[i]))
        if dq - radius > layout.part_radius[i]:
            continue  # sphere misses the partition entirely
        lo_ring = max(0, int(np.floor(max(dq - radius, 0.0) / layout.eps)))
        hi_ring = int(np.floor((dq + radius) / layout.eps))
        windows.append((i * layout.c_key + lo_ring, i * layout.c_key + hi_ring))
    return windows
