"""Pod-scale ProMIPS: corpus sharded over the `model` mesh axis, one local
index per shard, global top-k by all-gathering the per-shard (k, score)
pairs — k x n_shards values cross the wire instead of n (DESIGN.md §3).

Build: contiguous row ranges -> per-shard build_index (ids are GLOBAL row
ids), padded to common array shapes and stacked on a leading shard axis.
Search: shard_map over the model axis; each shard runs the unified search
runtime (`core/runtime.py` — progressive frontier by default, or the
two-phase mode with fused / batched / scan verification; "fused" runs the
in-graph `core/search_graph.py` driver inside the trace) on its slice; a
tiny all_gather + top_k merges.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..obs import trace as _trace
from ..obs.trace import span as _span
from .index import IndexArrays, IndexMeta, build_index
from .runtime import RuntimeConfig
from .runtime import search as runtime_search


class ShardedIndex(NamedTuple):
    arrays: IndexArrays      # every leaf has a leading (n_shards,) axis
    meta: IndexMeta          # common (max-padded) meta


class ShardedStats(NamedTuple):
    """Aggregated accounting of one fan-out search (host-merge path).

    Same pages/candidates field contract as `SearchStats` / `HostStats` /
    `StreamStats` (a query counts exhausted if ANY shard exhausted on it);
    totals are pre-aggregated, so ``queries`` is carried explicitly.
    """

    pages: int
    candidates: int
    exhausted: int
    queries: int

    def to_dict(self) -> dict:
        from .stats import stats_totals
        return stats_totals(self.pages, self.candidates, self.exhausted,
                            queries=self.queries)


def _pad_to(arr: np.ndarray, n: int, fill):
    pad = n - arr.shape[0]
    if pad <= 0:
        return arr
    width = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return np.pad(arr, width, constant_values=fill)


def build_sharded(x: np.ndarray, n_shards: int, **kwargs) -> ShardedIndex:
    n = x.shape[0]
    bounds = np.linspace(0, n, n_shards + 1).astype(int)
    parts = []
    for s in range(n_shards):
        lo, hi = bounds[s], bounds[s + 1]
        idx = build_index(x[lo:hi], **kwargs)
        a = idx.arrays._replace(
            ids=np.where(idx.arrays.ids >= 0, idx.arrays.ids + lo, -1).astype(np.int32)
        )
        parts.append((a, idx.meta))

    n_pad = max(m.n_pad for _, m in parts)
    g_max = max(m.n_groups for _, m in parts)
    s_max = max(m.n_subparts for _, m in parts)
    nb_max = max(m.n_blocks for _, m in parts)
    kmax = max(a.block_sp_idx.shape[1] for a, _ in parts)
    kcb_max = max(m.sk_codewords for _, m in parts)
    page_rows = parts[0][1].page_rows

    stacked = {}
    for field in IndexArrays._fields:
        vals = []
        for a, m in parts:
            v = np.asarray(getattr(a, field))
            if field in ("x", "p", "ids", "l2sq"):
                v = _pad_to(v, n_pad, -1 if field == "ids" else 0)
            elif field.startswith("g_"):
                v = _pad_to(v, g_max, 0)
            elif field == "sp_start":
                v = _pad_to(v, s_max + 1, v[-1])
            elif field.startswith("sp_"):
                # unreachable centers (1e30) + zero radius => never selected
                v = _pad_to(v, s_max, 1e30 if field == "sp_center" else 0)
            elif field == "block_sp_idx":
                if v.shape[1] < kmax:
                    v = np.pad(v, ((0, 0), (0, kmax - v.shape[1])), constant_values=-1)
                v = _pad_to(v, nb_max, -1)
            elif field == "sk_codebooks":
                # codeword count tracks min(256, NB_shard): pad small shards'
                # codebooks with zero codewords (never assigned by real codes)
                if v.shape[1] < kcb_max:
                    v = np.pad(v, ((0, 0), (0, kcb_max - v.shape[1]), (0, 0)))
            elif field.startswith("sk_") or field.startswith("block_"):
                # padded blocks decode to the zero sketch with err 0; the
                # prefilter drops them via the ids-derived block validity
                v = _pad_to(v, nb_max, 0)
            vals.append(v)
        stacked[field] = np.stack(vals)
    meta = dataclasses.replace(
        parts[0][1], n=n, n_pad=n_pad, n_blocks=nb_max, n_groups=g_max,
        n_subparts=s_max, page_rows=page_rows, sk_codewords=kcb_max,
    )
    return ShardedIndex(arrays=IndexArrays(**stacked), meta=meta)


def sharded_search(
    sharded: ShardedIndex,
    queries: jnp.ndarray,
    k: int,
    mesh: Mesh,
    *,
    axis: str = "model",
    budget: int = 64,
    cs_prune: bool = True,
    runtime: Optional[RuntimeConfig] = None,
):
    """Global c-k-AMIP over the sharded corpus. queries: (B, d) replicated.

    ``runtime`` selects the per-shard search config (mode / verification
    backend); the default is the progressive norm-adaptive frontier. Pass
    e.g. ``RuntimeConfig(mode="two_phase", verification="fused",
    norm_adaptive=True)`` to run the fused block-sparse verification on
    every shard: inside this shard_map the in-graph fused driver
    (`core/search_graph.py`) sizes its pow2 tile buckets with `lax.switch`,
    so each shard walks only its selected pages — the same kernel and
    bit-identical results as the eagerly-dispatched host-merge path
    (`MutableShardedProMIPS.search`).
    """
    meta = sharded.meta
    # ``budget``/``cs_prune`` are the legacy knobs for the default config; a
    # user-supplied RuntimeConfig is taken as-is (only k is stamped in —
    # budget=None keeps its documented "all blocks" meaning).
    cfg = runtime if runtime is not None else RuntimeConfig(
        mode="progressive", cs_prune=cs_prune, budget=budget)
    cfg = dataclasses.replace(cfg, k=k)
    fn = _sharded_search_fn(meta, k, mesh, axis, cfg)
    active = jax.core.trace_state_clean() and (cfg.obs or _trace.enabled())
    with _span("sharded_fanout", active=active,
               metric="sharded.fanout_us") as sp:
        return sp.fence(fn(sharded.arrays, jnp.asarray(queries, jnp.float32)))


@functools.lru_cache(maxsize=32)
def _sharded_search_fn(meta: IndexMeta, k: int, mesh: Mesh, axis: str,
                       cfg: RuntimeConfig):
    """One jit'd shard_map per (meta, k, mesh, axis, config).

    Building the shard_map and calling it EAGERLY per search re-runs its
    Python impl every time — the whole per-shard search is re-traced on
    every call, which dominates wall clock (the in-graph fused driver's
    jaxpr is large: one lax.switch branch per pow2 tile bucket). Caching a
    `jax.jit`-wrapped callable makes repeat searches hit the C++ pjit fast
    path: trace + compile once, then zero Python graph work per call. The
    cache is BOUNDED (each entry pins a compiled executable + its mesh):
    callers that churn through many (k, config, rebuilt-meta) combinations
    evict the oldest executables instead of growing without limit.
    """
    def local(arr_shard, q):
        arrays = jax.tree.map(lambda a: a[0], arr_shard)  # drop shard dim
        ids, scores, stats = runtime_search(arrays, meta, q, cfg)
        # gather per-shard winners; merge on every shard (cheap: k x shards)
        all_ids = jax.lax.all_gather(ids, axis)        # (S, B, k)
        all_scores = jax.lax.all_gather(scores, axis)  # (S, B, k)
        s, b, _ = all_ids.shape
        flat_i = jnp.moveaxis(all_ids, 0, 1).reshape(b, s * k)
        flat_s = jnp.moveaxis(all_scores, 0, 1).reshape(b, s * k)
        best_s, pos = jax.lax.top_k(flat_s, k)
        best_i = jnp.take_along_axis(flat_i, pos, axis=1)
        pages = jax.lax.psum(jnp.sum(stats.pages), axis)
        return best_i, best_s, pages

    in_arr_spec = IndexArrays(**{f: P(axis) for f in IndexArrays._fields})
    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(in_arr_spec, P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    ))


class MutableShardedProMIPS:
    """Pod-scale streaming index: one `stream.MutableProMIPS` per shard,
    writes routed by contiguous global-ID range (DESIGN.md §8).

    The initial corpus is split into contiguous row ranges exactly like
    `build_sharded`; each shard owns its range's ids plus a private delta
    segment and tombstone bitmap, so churn on one range never touches the
    other shards' immutable bases. Ids past the initial corpus route to the
    last shard (the append range). Search fans out to the per-shard
    segment-merged runtime and merges k x n_shards (id, score) pairs — the
    same wire economics as `sharded_search`.
    """

    def __init__(self, x: np.ndarray, n_shards: int, *,
                 delta_capacity: Optional[int] = None,
                 auto_compact: bool = False, **build_kwargs):
        from ..stream.mutable import MutableProMIPS

        n = x.shape[0]
        self.bounds = np.linspace(0, n, n_shards + 1).astype(int)
        self.shards = [
            MutableProMIPS(x[lo:hi], ids=np.arange(lo, hi),
                           delta_capacity=delta_capacity,
                           auto_compact=auto_compact, **build_kwargs)
            for lo, hi in zip(self.bounds[:-1], self.bounds[1:])
        ]

    @property
    def n_alive(self) -> int:
        return sum(s.n_alive for s in self.shards)

    def _route(self, gids: np.ndarray) -> np.ndarray:
        shard = np.searchsorted(self.bounds, gids, side="right") - 1
        return np.clip(shard, 0, len(self.shards) - 1)

    def _by_shard(self, gids):
        gids = np.atleast_1d(np.asarray(gids, np.int64))
        shard = self._route(gids)
        for s in np.unique(shard):
            yield int(s), np.nonzero(shard == s)[0], gids

    def insert(self, ids, rows) -> None:
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        for s, sel, gids in self._by_shard(ids):
            self.shards[s].insert(gids[sel], rows[sel])

    def delete(self, ids) -> None:
        for s, sel, gids in self._by_shard(ids):
            self.shards[s].delete(gids[sel])

    def update(self, ids, rows) -> None:
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        for s, sel, gids in self._by_shard(ids):
            self.shards[s].update(gids[sel], rows[sel])

    def compact(self) -> None:
        for s in self.shards:
            s.compact()

    def search(self, queries, k: int = 10,
               runtime: Optional[RuntimeConfig] = None):
        """Global top-k under churn: per-shard segment-merged search, then a
        k x n_shards host merge (ties break toward the lower shard, matching
        `sharded_search`'s lowest-index-wins top_k). All shard searches are
        dispatched before any result is pulled to host, so the per-shard
        computations overlap under JAX's async dispatch.

        Returns (ids (B, k), scores (B, k), `ShardedStats`)."""
        active = jax.core.trace_state_clean() and (
            _trace.enabled() or (runtime is not None and runtime.obs))
        # the dispatch span is deliberately UNFENCED: fencing each launch
        # would serialize the shards and destroy the async-dispatch overlap
        # this loop exists to create (it times enqueue, not device work)
        with _span("sharded_dispatch", active=active,
                   metric="sharded.dispatch_us"):
            launched = [shard.search(queries, k=k, runtime=runtime)
                        for shard in self.shards]
        with _span("sharded_merge", active=active,
                   metric="sharded.merge_us") as sp:
            ids_all = [np.asarray(ids) for ids, _, _ in launched]
            scores_all = [np.asarray(scores) for _, scores, _ in launched]
            pages = sum(int(np.sum(np.asarray(st.pages)))
                        for _, _, st in launched)
            cand = sum(int(np.sum(np.asarray(st.candidates)))
                       for _, _, st in launched)
            exhausted = int(np.sum(np.any(
                np.stack([np.asarray(st.exhausted) for _, _, st in launched]),
                axis=0)))
            flat_i = np.concatenate(ids_all, axis=1)
            flat_s = np.concatenate(scores_all, axis=1)
            pos = np.argsort(-flat_s, axis=1, kind="stable")[:, :k]
            stats = ShardedStats(pages=pages, candidates=cand,
                                 exhausted=exhausted,
                                 queries=int(flat_i.shape[0]))
            out = sp.fence((np.take_along_axis(flat_i, pos, axis=1),
                            np.take_along_axis(flat_s, pos, axis=1)))
        return out[0], out[1], stats

    # -- persistence (repro.api save/load, DESIGN.md §9) ---------------------
    def state_dict(self) -> tuple[dict, dict]:
        """(arrays, meta): per-shard `MutableProMIPS.state_dict` outputs with
        ``shard{i}_`` key prefixes, plus the global-ID routing bounds."""
        arrays: dict = {"bounds": np.asarray(self.bounds, np.int64)}
        shard_metas = []
        for i, shard in enumerate(self.shards):
            a, m = shard.state_dict()
            arrays.update({f"shard{i}_{key}": v for key, v in a.items()})
            shard_metas.append(m)
        return arrays, dict(n_shards=len(self.shards), shards=shard_metas)

    @classmethod
    def from_state(cls, arrays: dict, meta: dict) -> "MutableShardedProMIPS":
        from ..stream.mutable import MutableProMIPS

        obj = cls.__new__(cls)
        obj.bounds = np.asarray(arrays["bounds"], np.int64)
        obj.shards = []
        for i in range(int(meta["n_shards"])):
            prefix = f"shard{i}_"
            shard_arrays = {key[len(prefix):]: v for key, v in arrays.items()
                            if key.startswith(prefix)}
            obj.shards.append(
                MutableProMIPS.from_state(shard_arrays, meta["shards"][i]))
        return obj


def device_put_sharded_index(sharded: ShardedIndex, mesh: Mesh, axis: str = "model"):
    arrays = jax.tree.map(
        lambda a: jax.device_put(jnp.asarray(a), NamedSharding(mesh, P(axis))),
        sharded.arrays,
    )
    return ShardedIndex(arrays=arrays, meta=sharded.meta)
