"""Host-orchestrated fused two-phase search (``verification="fused"``).

The "batched" backend builds a jit graph whose verification tile is ALWAYS
``budget`` blocks (the full index at the guarantee-default budget): every
round gathers a (budget * page_rows, d) union tile with `jnp.take`, scores
all of it, and reconstructs the sequential semantics through five
(B, R)-shaped boolean intermediates — so at n=8000 the "pruned" path moves
strictly more bytes than the brute-force matmul it is supposed to beat
(DESIGN.md §10 has the traffic accounting).

This driver splits the search into per-round device calls and keeps the
block *selection* on device but the *tile sizing* on host:

  1. `select_frontend` (one jit call) -> per-query round-1 masks (B, NB);
  2. the union of selected blocks is pulled to host (NB bools/query), and
     the verification tile is sized to ``next_pow2(union_count)`` blocks —
     pow2 BUCKETING, so the per-shape jit cache stays O(log n_blocks) —
     instead of always ``budget``;
  3. `kernels/ops.block_mips` (fused kernel on TPU / its lean jnp oracle
     elsewhere) walks exactly those slots in place and returns the
     streaming top-k + per-slot hit counts from which the Condition-A
     stop/pages/candidates accounting is reconstructed;
  4. `compensation_masks` (one jit call) -> Condition B + round-2 masks;
     a compensation round whose union is EMPTY is skipped on host
     outright — no `lax.cond` that still pays a full-tile gather.

Results (ids, scores, and every `SearchStats` field) are bit-identical to
``verification="batched"`` at EVERY budget: the tile-cap rule — the
``budget`` best-priority union blocks (`search_device.truncate_union`),
laid out in layout order — is the same; the bucketed tile only drops slots
the batched tile masks out anyway. The parity suite in
tests/test_fused_verification.py asserts this three-way (fused / batched /
scan) at full budget and pairwise (fused / batched) at finite budgets.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..obs import metrics as _metrics
from ..obs.trace import span as _span
from .index import IndexArrays, IndexMeta
# DENSE_FRAC lives in search_common (re-exported here for compatibility):
# unions covering at least this fraction of all blocks take the dense path —
# the tile is every block in place (sel still masks per query — exactly the
# batched full tile), skipping the row gather entirely. Since PR 8 it is a
# per-call knob (`dense_frac`), promoted to `RuntimeConfig` and tunable via
# the offline tuner (`repro.tune`); this constant is the hand-picked default.
from .search_common import DENSE_FRAC, next_pow2
from .search_device import (SearchStats, TopK, block_priority,
                            compensation_masks, prefilter_round1,
                            prefilter_round2, select_frontend)


class TraceRing:
    """Bounded record of `_verify` retraces.

    Each jit retrace appends one (n_slots, batch, k, flavor, want_scores)
    tuple. A long-lived serve process retraces whenever a new pow2 bucket /
    batch shape first appears, so the storage is a RING (default 256 — far
    above the O(log n_blocks) bound the tests assert) instead of the old
    unbounded module list, while keeping the list surface those tests use
    (`clear()`, `list(...)`, `len()`, slicing). ``total`` counts every
    retrace ever (monotonic, survives `clear()`) and is exported through
    the metrics registry as the ``fused.verify_retraces`` gauge.
    """

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self.total = 0
        self._items: list = []

    def append(self, item) -> None:
        self.total += 1
        self._items.append(item)
        if len(self._items) > self.capacity:
            del self._items[: len(self._items) - self.capacity]

    def clear(self) -> None:
        self._items.clear()

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return iter(self._items)

    def __getitem__(self, i):
        return self._items[i]

    def __bool__(self) -> bool:
        return bool(self._items)


# Recorded each time `_verify` RETRACES — the pow2 bucketing's jit-cache
# bound is asserted against this in tests/test_fused_verification.py.
VERIFY_TRACES = TraceRing()

_metrics.register_collector(
    lambda: _metrics.gauge("fused.verify_retraces").set(VERIFY_TRACES.total))


@functools.partial(jax.jit, static_argnames=("meta",))
def _frontend(arrays: IndexArrays, meta: IndexMeta, queries):
    return select_frontend(arrays, meta, queries)


@functools.partial(jax.jit,
                   static_argnames=("k", "page_rows", "dense", "use_pallas",
                                    "want_scores"))
def _verify(arrays: IndexArrays, queries, slots, sel, init_s, init_r, c_half,
            k: int, page_rows: int, dense: bool, use_pallas: Optional[bool],
            want_scores: bool = False):
    """One fused verification round; returns (TopK, pages, cand, done_a,
    scores_cache). ``want_scores`` (dense oracle rounds only) additionally
    returns the full (B, n_pad) score matrix so a later compensation round
    can reuse it instead of re-scoring (`_verify_cached`)."""
    VERIFY_TRACES.append((int(slots.shape[0]), int(queries.shape[0]), k,
                          dense, want_scores))
    valid = arrays.ids >= 0
    top_s, top_r, cnt, pages, cand = ops.block_mips(
        arrays.x, valid, queries, slots, sel, init_s, init_r, c_half,
        k=k, page_rows=page_rows, dense=dense, use_pallas=use_pallas)
    # "running k-th best >= threshold" <=> "n0 + total selected hits >= k"
    # (hits past the stop block only ever re-confirm an already-true stop).
    n0 = jnp.sum(init_s >= c_half[:, None], axis=1)
    done_a = (n0 + jnp.sum(cnt, axis=1)) >= k
    cache = None
    if want_scores:
        # the identical full-matrix product the dense round just consumed
        # (same (n_pad, d) @ (d, B) orientation as `ref.block_mips_ref`) —
        # XLA CSEs it with the in-round matmul, so this costs nothing extra
        cache = (arrays.x @ queries.T).T
    return TopK(scores=top_s, rows=top_r), pages, cand, done_a, cache


@functools.partial(jax.jit, static_argnames=("k", "page_rows"))
def _verify_cached(arrays: IndexArrays, scores_full, slots, sel, init_s,
                   init_r, c_half, k: int, page_rows: int):
    """Compensation round over a dense previous round's cached scores —
    no new dot products (see `ops.block_mips_cached`)."""
    VERIFY_TRACES.append((int(slots.shape[0]), int(scores_full.shape[0]), k,
                          "cached", False))
    valid = arrays.ids >= 0
    top_s, top_r, cnt, pages, cand = ops.block_mips_cached(
        scores_full, valid, slots, sel, init_s, init_r, c_half,
        k=k, page_rows=page_rows)
    n0 = jnp.sum(init_s >= c_half[:, None], axis=1)
    done_a = (n0 + jnp.sum(cnt, axis=1)) >= k
    return TopK(scores=top_s, rows=top_r), pages, cand, done_a


@functools.partial(jax.jit,
                   static_argnames=("meta", "norm_adaptive", "cs_prune"))
def _round2(arrays: IndexArrays, meta: IndexMeta, d_sp, q_l2sq, s_k, r0,
            done_a, mask0, norm_adaptive: bool, cs_prune: bool):
    return compensation_masks(arrays, meta, d_sp, q_l2sq, s_k, r0, done_a,
                              mask0, norm_adaptive, cs_prune)


# host-side jit wrappers around the shared prefilter stages (the graph
# driver calls the same functions in-trace — bit-parity by construction)
_prefilter1 = jax.jit(prefilter_round1,
                      static_argnames=("k", "page_rows", "eps", "use_pallas"))
_prefilter2 = jax.jit(prefilter_round2)


def _plan_tile(mask: np.ndarray, cap: int, n_blocks: int,
               dense_frac: float = DENSE_FRAC, prio=None):
    """Size one verification tile from the host-side (B, NB) selection.

    Returns (slots (NS,) i32, sel (B, NS) bool, lost (B,) bool, dense) or
    None when no block is selected (the round is skipped outright — an
    identity on the carried top-k with zero pages/candidates, exactly what
    the batched backend's all-masked tile computes the long way).

    NS = min(next_pow2(union), cap): at most 2x the live work, from a set
    of O(log n_blocks) distinct shapes. When the union would cover nearly
    everything anyway (>= ``dense_frac``) and the cap allows, the tile is
    ALL blocks in place (``dense``) so the kernel/oracle skips the row
    gather — dense and sparse tiles are result-bit-identical, so
    ``dense_frac`` is a pure performance knob (tunable via `repro.tune`).
    ``lost`` flags queries whose selection exceeds the ``cap``-block tile —
    the same union-tile budget rule as ``verification="batched"``;
    ``prio`` (NB,), when given, keeps the BEST union blocks under a
    truncating cap (ties by layout index — `search_device.truncate_union`'s
    rule, applied host-side) instead of the first in layout order.
    """
    union = mask.any(axis=0)
    n_union = int(union.sum())
    if n_union == 0:
        return None
    n_batch = mask.shape[0]
    if n_union >= dense_frac * n_blocks and cap >= n_blocks:
        slots = np.arange(n_blocks, dtype=np.int32)
        return slots, mask, np.zeros(n_batch, bool), True
    n_slots = min(next_pow2(n_union), cap)
    ublocks = np.nonzero(union)[0]                  # ascending layout order
    if n_union > n_slots:
        if prio is not None:                        # best blocks survive,
            best = np.argsort(prio[ublocks], kind="stable")[:n_slots]
            take = np.sort(ublocks[best])           # ...laid out in order
        else:
            take = ublocks[:n_slots]
        in_tile = np.zeros(n_blocks, bool)
        in_tile[take] = True
        lost = (mask & ~in_tile[None, :]).any(axis=1)
    else:
        take = ublocks
        lost = np.zeros(n_batch, bool)
    slots = np.zeros(n_slots, np.int32)
    slots[: len(take)] = take
    sel = np.zeros((n_batch, n_slots), bool)
    sel[:, : len(take)] = mask[:, take]
    return slots, sel, lost, False


def search_batch_fused(
    arrays: IndexArrays,
    meta: IndexMeta,
    queries: jnp.ndarray,
    k: int = 10,
    budget: int = 64,
    budget2: int = 64,
    norm_adaptive: bool = False,
    cs_prune: bool = False,
    use_pallas: Optional[bool] = None,
    prefilter: bool = False,
    prefilter_eps: float = 1.0,
    obs: bool = False,
    dense_frac: float = DENSE_FRAC,
    tile_cap: Optional[int] = None,
):
    """c-k-AMIP search, fused backend. Same contract as `search_batch`.

    Eager-only (host-orchestrated): call it outside jit. `core/runtime.search`
    routes ``verification="fused"`` here when not tracing; under an ambient
    trace the bit-identical IN-GRAPH fused driver
    (`core/search_graph.search_batch_fused_graph`) runs instead — same
    kernel, tile buckets selected by `lax.switch` rather than on host.

    ``prefilter`` scores the quantized block sketch for every candidate
    block BEFORE any page is fetched and verifies only the survivors; both
    rounds' selections shrink, the Theorem-1/2 accounting is untouched (the
    survivor rules are lossless at ``prefilter_eps=1``; see DESIGN.md §13).

    ``obs`` activates the per-phase spans and round-shape counters
    (DESIGN.md §14). Off (the default), each phase pays one no-op span
    call; no jit graph differs either way — the instrumentation is pure
    host code between the same device calls.

    ``dense_frac`` / ``tile_cap`` are the tuner-promoted tile knobs
    (DESIGN.md §15): ``dense_frac`` moves the dense-path threshold
    (result-bit-identical at any value), ``tile_cap`` additionally clamps
    both rounds' verification tiles below the budget rule (``tile_cap >=
    n_blocks`` is a no-op; a cap below a round's union truncates it under
    the SAME first-blocks-in-layout-order rule as a finite budget, flagging
    the affected queries ``exhausted``).
    """
    n_blocks = meta.n_blocks
    n_batch = queries.shape[0]
    cap = min(budget, n_blocks)
    cap2 = min(budget2, n_blocks)
    if tile_cap is not None:
        cap = min(cap, int(tile_cap))
        cap2 = min(cap2, int(tile_cap))

    with _span("select_frontend", active=obs,
               metric="search.frontend_us") as sp:
        q_proj, q_l2sq, d_sp, r0, probe_ok, c_half, mask0 = _frontend(
            arrays, meta, queries)
        sp.fence(mask0)
    # host-side copy of the shared best-first truncation key (same rule as
    # the batched / in-graph drivers), only when a cap can truncate
    prio_np = (np.asarray(block_priority(arrays, q_proj))
               if min(cap, cap2) < n_blocks else None)
    mask_r1 = mask0
    sk_est = sk_bnd = sk_bvalid = None
    if prefilter:
        with _span("prefilter_round1", active=obs,
                   metric="search.prefilter_us") as sp:
            mask_r1, sk_est, sk_bnd, sk_bvalid = _prefilter1(
                arrays, queries, mask0, k, meta.page_rows, prefilter_eps,
                use_pallas)
            sp.fence(mask_r1)
    zero = jnp.zeros(n_batch, jnp.int32)
    false = jnp.zeros(n_batch, bool)
    # strong f32 (explicit dtype): round-2 carries _verify's strong-typed
    # output back in, and a weak-typed round-1 init would double every
    # bucket's jit-cache entry
    top = TopK(scores=jnp.full((n_batch, k), -jnp.inf, jnp.float32),
               rows=jnp.full((n_batch, k), -1, jnp.int32))

    scores_cache = None
    with _span("plan_tile_round1", active=obs, metric="search.plan_us"):
        mask_np = np.asarray(mask_r1)
        if obs and prefilter:
            n_sel = float(np.asarray(mask0).sum())
            _metrics.gauge("search.prefilter_survivor_frac").set(
                float(mask_np.sum()) / max(n_sel, 1.0))
        plan = _plan_tile(mask_np, cap, n_blocks, dense_frac, prio=prio_np)
    if plan is None:
        if obs:
            _metrics.counter("fused.rounds_skipped").inc()
        pages1, cand1, done_a, lost1 = zero, zero, false, false
    else:
        slots, sel, lost_np, dense = plan
        if obs:
            _metrics.counter("fused.rounds_dense" if dense
                             else "fused.rounds_sparse").inc()
        # A dense oracle round scores the whole corpus in place; keep that
        # (B, n_pad) product so the compensation round needs NO new matmul.
        want_scores = dense and not ops._resolve(use_pallas)
        with _span("verify_round1", active=obs,
                   metric="search.verify_round_us") as sp:
            top, pages1, cand1, done_a, scores_cache = _verify(
                arrays, queries, jnp.asarray(slots), jnp.asarray(sel),
                top.scores, top.rows, c_half, k, meta.page_rows, dense,
                use_pallas, want_scores)
            sp.fence(top.scores)
        lost1 = jnp.asarray(lost_np)

    with _span("compensation", active=obs,
               metric="search.compensation_us") as sp:
        s_k = top.scores[:, k - 1]
        need2, r1, mask1 = _round2(arrays, meta, d_sp, q_l2sq, s_k, r0,
                                   done_a, mask0, norm_adaptive, cs_prune)
        sp.fence(mask1)
    mask_r2 = mask1
    if prefilter:
        with _span("prefilter_round2", active=obs,
                   metric="search.prefilter_us") as sp:
            mask_r2 = _prefilter2(mask1, sk_est, sk_bnd, sk_bvalid, s_k)
            sp.fence(mask_r2)

    with _span("plan_tile_round2", active=obs, metric="search.plan_us"):
        plan = _plan_tile(np.asarray(mask_r2), cap2, n_blocks, dense_frac,
                          prio=prio_np)
    if plan is None:
        if obs:
            _metrics.counter("fused.rounds_skipped").inc()
        pages2, cand2, lost2 = zero, zero, false
    else:
        slots, sel, lost_np, dense = plan
        with _span("verify_round2", active=obs,
                   metric="search.verify_round_us") as sp:
            if scores_cache is not None:
                if obs:
                    _metrics.counter("fused.rounds_cached").inc()
                top, pages2, cand2, _ = _verify_cached(
                    arrays, scores_cache, jnp.asarray(slots),
                    jnp.asarray(sel), top.scores, top.rows, c_half, k,
                    meta.page_rows)
            else:
                if obs:
                    _metrics.counter("fused.rounds_dense" if dense
                                     else "fused.rounds_sparse").inc()
                top, pages2, cand2, _, _ = _verify(
                    arrays, queries, jnp.asarray(slots), jnp.asarray(sel),
                    top.scores, top.rows, c_half, k, meta.page_rows, dense,
                    use_pallas, False)
            sp.fence(top.scores)
        lost2 = jnp.asarray(lost_np)

    stats = SearchStats(
        pages=pages1 + pages2,
        candidates=cand1 + cand2,
        probe_passed=probe_ok,
        used_round2=need2,
        radius0=r0,
        radius1=jnp.where(need2, r1, 0.0),
        exhausted=lost1 | (need2 & lost2),
        rows=top.rows,
    )
    ids = jnp.where(top.rows >= 0, arrays.ids[jnp.maximum(top.rows, 0)], -1)
    return ids, top.scores, stats


__all__ = ["search_batch_fused", "TraceRing", "VERIFY_TRACES", "DENSE_FRAC"]
