"""ProMIPS index: build product tying together projections, Quick-Probe
groups and the iDistance layout (paper Fig. 2 "pre-process" box).

The index is a NamedTuple of dense arrays (a valid JAX pytree — it moves to
device / shards with ``jax.device_put``) plus a static ``IndexMeta``. All
row-indexed arrays are PADDED to a multiple of ``page_rows`` so device-mode
block fetches are uniform dynamic slices; padding rows carry id -1 and are
masked to -inf scores.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple, Optional

import numpy as np

from .dim_opt import optimized_projected_dimension
from .chi2 import chi2_ppf_host
from .idistance import IDistanceLayout, build_idistance
from .projections import make_projection, project
from .quick_probe import GroupTable, build_group_table, pack_codes_np


class IndexArrays(NamedTuple):
    """Device arrays. Leading dim conventions: n_pad rows, G groups, S subparts,
    NB = n_pad / page_rows blocks."""

    a: np.ndarray            # (d, m) projection matrix
    x: np.ndarray            # (n_pad, d) original points, sorted layout
    p: np.ndarray            # (n_pad, m) projected points, sorted layout
    ids: np.ndarray          # (n_pad,) original row ids (-1 = padding)
    l2sq: np.ndarray         # (n_pad,) squared 2-norms (0 for padding)
    max_l2sq: np.ndarray     # () ||o_M||^2
    g_code: np.ndarray       # (G,) uint32
    g_min_l1: np.ndarray     # (G,)
    g_rep_proj: np.ndarray   # (G, m)
    g_rep_row: np.ndarray    # (G,)
    g_count: np.ndarray      # (G,)
    sp_center: np.ndarray    # (S, m)
    sp_radius: np.ndarray    # (S,)
    sp_start: np.ndarray     # (S+1,) row offsets into the sorted layout
    sp_max_l2sq: np.ndarray  # (S,) max ||o||^2 per sub-partition (beyond-paper:
                             # norm-adaptive radii + Cauchy-Schwarz pruning)
    block_sp_lo: np.ndarray  # (NB,) first sub-partition overlapping each block
    block_sp_hi: np.ndarray  # (NB,) one-past-last sub-partition of each block
    block_max_l2sq: np.ndarray  # (NB,) max ||o||^2 over the block's sub-partitions
    block_sp_idx: np.ndarray    # (NB, KMAX) sub-partitions per block (-1 pad) —
                                # progressive mode's per-block gap computation
    sk_mu: np.ndarray        # (NB, d) PQ-decoded block centroids (sketch; the
                             # prefilter scores q @ sk_mu.T — persisted decoded
                             # so scoring is one matmul, not per-code gathers)
    sk_codebooks: np.ndarray  # (M_sk, K_sk, d/M_sk) sketch PQ codebooks
    sk_codes: np.ndarray     # (NB, M_sk) int32 sketch PQ codes
    sk_err: np.ndarray       # (NB,) max ||o_r - mu~_b|| over valid rows


@dataclass(frozen=True)
class IndexMeta:
    n: int
    d: int
    m: int
    c: float
    p: float
    x_p: float               # Psi_m^{-1}(p), static threshold
    page_rows: int
    page_bytes: int
    n_pad: int
    n_blocks: int
    n_groups: int
    n_subparts: int
    k_p: int
    n_key: int
    k_sp: int
    seed: int
    norm_strata: int = 1
    sk_subspaces: int = 0    # sketch PQ subspaces (0 = index has no sketch)
    sk_codewords: int = 0    # sketch PQ codewords per subspace
    max_probe_groups: Optional[int] = None  # Quick-Probe group-table cap
                             # (tuner build knob; None = all sign codes —
                             # defaulted so pre-PR-8 saved indexes load)

    @property
    def index_bytes(self) -> int:
        """Size of the *index* (everything except the raw data x) — the
        paper's 'Index Size' metric (Fig. 4a)."""
        per_point = self.m * 4 + 4 + 4  # projected point + id + l2sq
        groups = self.n_groups * (4 + 4 + self.m * 4 + 4 + 4)
        subparts = self.n_subparts * (self.m * 4 + 4 + 8) + 8
        blocks = self.n_blocks * 8
        proj = self.d * self.m * 4
        sketch = 0
        if self.sk_subspaces:
            sketch = (self.n_blocks * self.d * 4          # decoded centroids
                      + self.sk_subspaces * self.sk_codewords
                      * (self.d // self.sk_subspaces) * 4  # codebooks
                      + self.n_blocks * self.sk_subspaces * 4  # codes
                      + self.n_blocks * 4)                 # err radii
        return (self.n_pad * per_point + groups + subparts + blocks + proj
                + sketch)


class ProMIPSIndex(NamedTuple):
    arrays: IndexArrays
    meta: IndexMeta
    layout: Optional[IDistanceLayout]  # host-only; None once shipped to device


def _stratified_layout(x, p_pts, k_p, n_key, k_sp, seed, norm_strata):
    """Beyond-paper: build the iDistance layout per norm-quantile stratum so
    sub-partitions are norm-homogeneous (makes the norm-adaptive radii in
    search_common.adaptive_radii bite). ``norm_strata=1`` is the paper's
    exact partition pattern."""
    from .idistance import IDistanceLayout

    n = x.shape[0]
    if norm_strata <= 1:
        return build_idistance(p_pts, k_p=k_p, n_key=n_key, k_sp=k_sp, seed=seed)
    norms = np.linalg.norm(x, axis=1)
    edges = np.quantile(norms, np.linspace(0, 1, norm_strata + 1)[1:-1])
    strat = np.searchsorted(edges, norms)
    perms, centers, radii, sp_c, sp_r, sp_k, sp_p, sizes, keys = ([] for _ in range(9))
    key_base = 0
    eps_acc, c_key_max = [], 1
    for s in range(norm_strata):
        rows = np.nonzero(strat == s)[0]
        if len(rows) == 0:
            continue
        lay = build_idistance(p_pts[rows], k_p=k_p, n_key=n_key, k_sp=k_sp, seed=seed + s)
        perms.append(rows[lay.perm])
        centers.append(lay.part_center)
        radii.append(lay.part_radius)
        sp_c.append(lay.sp_center)
        sp_r.append(lay.sp_radius)
        sp_k.append(lay.sp_key + key_base)
        sp_p.append(lay.sp_part + len(np.concatenate(centers)) - lay.part_center.shape[0])
        sizes.append(np.diff(lay.sp_start))
        keys.append(lay.keys + key_base)
        key_base += int(lay.sp_key.max()) + 2 if len(lay.sp_key) else 1
        eps_acc.append(lay.eps)
        c_key_max = max(c_key_max, lay.c_key)
    sp_start = np.concatenate([[0], np.cumsum(np.concatenate(sizes))]).astype(np.int64)
    return IDistanceLayout(
        perm=np.concatenate(perms).astype(np.int64),
        part_center=np.concatenate(centers),
        part_radius=np.concatenate(radii),
        eps=float(np.mean(eps_acc)),
        c_key=c_key_max,
        keys=np.concatenate(keys),
        sp_center=np.concatenate(sp_c),
        sp_radius=np.concatenate(sp_r),
        sp_start=sp_start,
        sp_key=np.concatenate(sp_k),
        sp_part=np.concatenate(sp_p),
    )


def build_index(
    x: np.ndarray,
    *,
    m: Optional[int] = None,
    c: float = 0.9,
    p: float = 0.5,
    k_p: int = 5,
    n_key: int = 40,
    k_sp: int = 10,
    page_bytes: int = 4096,
    seed: int = 0,
    norm_strata: int = 1,
    max_probe_groups: Optional[int] = None,
) -> ProMIPSIndex:
    """Pre-process (paper Fig. 2 left box + Algorithm 4).

    x: (n, d) float32 data points. Returns the host-side index; call
    ``jax.device_put(idx.arrays, ...)`` (or the sharded helper) to ship it.
    ``norm_strata > 1`` enables the beyond-paper norm-stratified layout.
    ``max_probe_groups`` caps the Quick-Probe group table (a tuner build
    knob — `quick_probe.build_group_table` keeps the easiest Test-A
    passers; None = every distinct sign code, the paper's table).
    """
    x = np.ascontiguousarray(x, np.float32)
    n, d = x.shape
    if m is None:
        m = optimized_projected_dimension(n)
    m = int(min(m, 30))

    a = make_projection(d, m, seed=seed)
    p_pts = project(x, a).astype(np.float32)

    layout = _stratified_layout(x, p_pts, k_p, n_key, k_sp, seed, norm_strata)
    perm = layout.perm
    xs, ps = x[perm], p_pts[perm]
    l1 = np.abs(xs).sum(axis=1).astype(np.float32)
    l2sq = (xs * xs).sum(axis=1).astype(np.float32)

    codes = pack_codes_np(ps)
    groups: GroupTable = build_group_table(codes, l1, ps,
                                           max_groups=max_probe_groups)

    page_rows = max(1, page_bytes // (4 * d))
    n_pad = int(math.ceil(n / page_rows)) * page_rows
    n_blocks = n_pad // page_rows

    def pad_rows(arr, fill=0):
        pad = n_pad - n
        if pad == 0:
            return arr
        width = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
        return np.pad(arr, width, constant_values=fill)

    sp_start = layout.sp_start
    n_sp = len(layout.sp_radius)
    sp_max_l2sq = np.asarray(
        [l2sq[sp_start[s]:sp_start[s + 1]].max() for s in range(n_sp)], np.float32
    )
    block_lo = np.searchsorted(sp_start, np.arange(n_blocks) * page_rows, side="right") - 1
    last_row = np.minimum((np.arange(n_blocks) + 1) * page_rows, n) - 1
    block_hi = np.searchsorted(sp_start, last_row, side="right")
    block_lo = np.clip(block_lo, 0, len(sp_start) - 2)
    block_hi = np.clip(block_hi, block_lo + 1, len(sp_start) - 1)
    kmax = int((block_hi - block_lo).max())
    block_sp_idx = np.full((n_blocks, kmax), -1, np.int32)
    block_max_l2sq = np.zeros(n_blocks, np.float32)
    for b in range(n_blocks):
        sps = np.arange(block_lo[b], block_hi[b])
        block_sp_idx[b, : len(sps)] = sps
        block_max_l2sq[b] = sp_max_l2sq[sps].max()

    from .sketch import build_block_sketch, pick_subspaces

    sk_subspaces = pick_subspaces(d, target=16)
    sk_codewords = min(256, n_blocks)
    sk_mu, sk_codebooks, sk_codes, sk_err = build_block_sketch(
        pad_rows(xs), pad_rows(perm.astype(np.int32), fill=-1), page_rows,
        sk_subspaces, sk_codewords, seed=seed)

    arrays = IndexArrays(
        a=a,
        x=pad_rows(xs),
        p=pad_rows(ps),
        ids=pad_rows(perm.astype(np.int32), fill=-1),
        l2sq=pad_rows(l2sq),
        max_l2sq=np.float32(l2sq.max()),
        g_code=groups.code,
        g_min_l1=groups.min_l1,
        g_rep_proj=groups.rep_proj,
        g_rep_row=groups.rep_row,
        g_count=groups.count,
        sp_center=layout.sp_center,
        sp_radius=layout.sp_radius,
        sp_start=sp_start.astype(np.int32),
        sp_max_l2sq=sp_max_l2sq,
        block_sp_lo=block_lo.astype(np.int32),
        block_sp_hi=block_hi.astype(np.int32),
        block_max_l2sq=block_max_l2sq,
        block_sp_idx=block_sp_idx,
        sk_mu=sk_mu,
        sk_codebooks=sk_codebooks,
        sk_codes=sk_codes,
        sk_err=sk_err,
    )
    meta = IndexMeta(
        n=n, d=d, m=m, c=c, p=p,
        x_p=chi2_ppf_host(p, m),
        page_rows=page_rows, page_bytes=page_bytes,
        n_pad=n_pad, n_blocks=n_blocks,
        n_groups=len(groups.code), n_subparts=len(layout.sp_radius),
        k_p=k_p, n_key=n_key, k_sp=k_sp, seed=seed, norm_strata=norm_strata,
        sk_subspaces=sk_subspaces, sk_codewords=sk_codewords,
        max_probe_groups=max_probe_groups,
    )
    return ProMIPSIndex(arrays=arrays, meta=meta, layout=layout)
