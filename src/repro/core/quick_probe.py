"""Quick-Probe (paper Section V, Algorithm 2).

Locates, without incremental NN search, a point whose projected distance to
the query can serve as the range-search radius:

1. every projected point gets a sign binary code c(o) (bit i = 1 iff
   P_i(o) >= 0); points sharing a code form a group;
2. Theorem 3: dis(P(o), P(q)) >= (1/sqrt(m)) * sum_i (c_i(o) xor c_i(q)) * |P_i(q)|
   — a per-GROUP lower bound LB_g (it only depends on the code);
3. Theorem 4: dis(o, q) <= ||o||_1 + ||q||_1 (original space);
4. Test A:  Psi_m( LB^2 / (c * (||o||_1 + ||q||_1)^2) ) >= p, evaluated with
   the group's minimum ||o||_1 (groups are sorted by ||o||_1 so that point
   maximises the testable value);
5. scan groups in ascending LB order, return the first point passing Test A;
   if none passes, return the point with the largest recorded value.

TPU adaptation (see DESIGN.md §3): the sequential ascending-LB scan is
replaced by a fully vectorised evaluation over all groups — "first passing
group in ascending LB order" == "passing group with minimal LB" — which is
exactly equivalent and removes the serial loop. Codes are bit-packed into a
single uint32 per point (m <= 30 always; m* = O(log n)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


def pack_codes_np(p_pts: np.ndarray) -> np.ndarray:
    """Sign codes of projected points, packed to uint32. (n, m) -> (n,)."""
    n, m = p_pts.shape
    assert m <= 30, "projected dimension must fit a packed uint32 code"
    bits = (p_pts >= 0.0).astype(np.uint32)
    weights = (1 << np.arange(m, dtype=np.uint32))
    return (bits * weights[None, :]).sum(axis=1).astype(np.uint32)


def pack_codes(p_pts: jnp.ndarray) -> jnp.ndarray:
    """jit-able version of :func:`pack_codes_np`. (..., m) -> (...,)."""
    m = p_pts.shape[-1]
    weights = (jnp.uint32(1) << jnp.arange(m, dtype=jnp.uint32))
    bits = (p_pts >= 0.0).astype(jnp.uint32)
    return (bits * weights).sum(axis=-1).astype(jnp.uint32)


def unpack_bits(codes: jnp.ndarray, m: int) -> jnp.ndarray:
    """uint32 codes -> (..., m) float bits."""
    shifts = jnp.arange(m, dtype=jnp.uint32)
    return ((codes[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)


class GroupTable(NamedTuple):
    """Per-group Quick-Probe metadata (G groups, padded rows allowed).

    code:     (G,) uint32 — the group's sign code.
    min_l1:   (G,) f32   — min ||o||_1 (ORIGINAL space) among members.
    rep_proj: (G, m) f32 — projected point of that min-l1 member.
    rep_row:  (G,) i32   — its row in the sorted data layout.
    count:    (G,) i32   — group size (0 marks padding).
    """

    code: jnp.ndarray
    min_l1: jnp.ndarray
    rep_proj: jnp.ndarray
    rep_row: jnp.ndarray
    count: jnp.ndarray


def build_group_table(codes: np.ndarray, l1: np.ndarray, p_pts: np.ndarray,
                      max_groups: int | None = None) -> GroupTable:
    """Host-side group construction (pre-processing phase).

    ``codes``/``l1``/``p_pts`` are in the final sorted data layout, so
    ``rep_row`` indexes directly into the index's sorted arrays.

    ``max_groups`` (a tuner build knob, `repro.tune`) caps the table at the
    groups with the SMALLEST min ||o||_1 — the easiest Test-A passers (the
    l1 term sits in the test's denominator). Dropping a group is safe: the
    probe still returns some valid data point and the fallback-to-recorded-
    maximum rule is unchanged — but the chosen radius can differ from the
    uncapped table's, so the tuner's parity gate decides whether a capped
    table ships. None (default) keeps every distinct sign code.
    """
    order = np.lexsort((l1, codes))
    sc = codes[order]
    boundaries = np.concatenate([[0], np.nonzero(np.diff(sc))[0] + 1, [len(sc)]])
    g_code, g_min_l1, g_rep_proj, g_rep_row, g_count = [], [], [], [], []
    for s, e in zip(boundaries[:-1], boundaries[1:]):
        if s == e:
            continue
        rows = order[s:e]
        rep = rows[0]  # lexsort => first member has min ||o||_1
        g_code.append(sc[s])
        g_min_l1.append(l1[rep])
        g_rep_proj.append(p_pts[rep])
        g_rep_row.append(rep)
        g_count.append(e - s)
    if max_groups is not None and len(g_code) > int(max_groups):
        # smallest-min_l1 subset, kept in the original (code-sorted) order —
        # group order is irrelevant to the probe's argmin/argmax selection,
        # but a deterministic layout keeps rebuilds bit-reproducible
        keep = np.sort(np.argsort(np.asarray(g_min_l1, np.float32),
                                  kind="stable")[: int(max_groups)])
        g_code = [g_code[i] for i in keep]
        g_min_l1 = [g_min_l1[i] for i in keep]
        g_rep_proj = [g_rep_proj[i] for i in keep]
        g_rep_row = [g_rep_row[i] for i in keep]
        g_count = [g_count[i] for i in keep]
    return GroupTable(
        code=np.asarray(g_code, np.uint32),
        min_l1=np.asarray(g_min_l1, np.float32),
        rep_proj=np.asarray(g_rep_proj, np.float32),
        rep_row=np.asarray(g_rep_row, np.int32),
        count=np.asarray(g_count, np.int32),
    )


def group_lower_bounds(g_code: jnp.ndarray, q_code: jnp.ndarray, q_proj: jnp.ndarray) -> jnp.ndarray:
    """Theorem 3 per-group lower bounds on dis(P(o), P(q)).

    g_code: (G,), q_code: scalar, q_proj: (m,) -> (G,) f32.
    """
    m = q_proj.shape[-1]
    xor_bits = unpack_bits(g_code ^ q_code, m)  # (G, m)
    return (xor_bits @ jnp.abs(q_proj)) / jnp.sqrt(jnp.float32(m))


def quick_probe(
    table: GroupTable,
    q_proj: jnp.ndarray,
    q_l1: jnp.ndarray,
    c: float,
    x_p: float,
):
    """Algorithm 2, vectorised. Returns (rep_row, radius, test_a_passed).

    Test A: Psi_m(LB^2 / (c (min_l1 + ||q||_1)^2)) >= p
        <=> LB^2 >= x_p * c * (min_l1 + ||q||_1)^2   (monotonicity of Psi_m)

    Among passing groups pick the one with the smallest LB (== first hit of
    the paper's ascending-LB scan); if none passes, fall back to the group
    with the largest tested value (paper's recorded-maximum fallback). The
    returned radius is dis(P(o), P(q)) for the chosen representative point.
    """
    q_code = pack_codes(q_proj)
    lb = group_lower_bounds(table.code, q_code, q_proj)  # (G,)
    valid = table.count > 0
    denom = c * (table.min_l1 + q_l1) ** 2
    val = lb * lb / jnp.maximum(denom, 1e-30)
    passes = (val >= x_p) & valid

    any_pass = jnp.any(passes)
    inf = jnp.float32(jnp.inf)
    first_pass = jnp.argmin(jnp.where(passes, lb, inf))
    best_val = jnp.argmax(jnp.where(valid, val, -inf))
    chosen = jnp.where(any_pass, first_pass, best_val)

    rep = table.rep_proj[chosen]
    radius = jnp.sqrt(jnp.sum((rep - q_proj) ** 2))
    return table.rep_row[chosen], radius, any_pass


def quick_probe_batch(
    table: GroupTable,
    q_proj: jnp.ndarray,
    q_l1: jnp.ndarray,
    c: float,
    x_p: float,
):
    """Batch-native Algorithm 2: one fused evaluation for a (B, m) query
    batch instead of `vmap`-of-per-query. Every step is the per-query
    computation broadcast over a leading batch axis (same op, same reduction
    order), so the result is bit-identical to ``vmap(quick_probe)`` — the
    agreement test in tests/test_fused_verification.py asserts it.

    Returns (rep_row (B,), radius (B,), test_a_passed (B,)).
    """
    q_code = pack_codes(q_proj)                                  # (B,)
    m = q_proj.shape[-1]
    xor_bits = unpack_bits(table.code[None, :] ^ q_code[:, None], m)  # (B,G,m)
    lb = (jnp.einsum("bgm,bm->bg", xor_bits, jnp.abs(q_proj))
          / jnp.sqrt(jnp.float32(m)))                            # (B, G)
    valid = table.count > 0
    denom = c * (table.min_l1[None, :] + q_l1[:, None]) ** 2
    val = lb * lb / jnp.maximum(denom, 1e-30)
    passes = (val >= x_p) & valid[None, :]

    any_pass = jnp.any(passes, axis=1)
    inf = jnp.float32(jnp.inf)
    first_pass = jnp.argmin(jnp.where(passes, lb, inf), axis=1)
    best_val = jnp.argmax(jnp.where(valid[None, :], val, -inf), axis=1)
    chosen = jnp.where(any_pass, first_pass, best_val)           # (B,)

    rep = table.rep_proj[chosen]                                 # (B, m)
    radius = jnp.sqrt(jnp.sum((rep - q_proj) ** 2, axis=-1))
    return table.rep_row[chosen], radius, any_pass
