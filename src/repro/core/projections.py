"""2-stable random projections (paper Definition 2).

``f_i(o) = v_i . o`` with ``v_i ~ N(0, I_d)``; m projections stack into a
(d, m) matrix so projecting a batch is a single matmul (MXU-friendly).
Lemma 1: ``f(o1) - f(o2) ~ N(0, dis^2(o1, o2))`` per projection, which is
what gives Lemma 2's chi-square ratio.
"""
from __future__ import annotations

import numpy as np


def make_projection(d: int, m: int, seed: int = 0) -> np.ndarray:
    """(d, m) matrix of i.i.d. standard normals. Deterministic in ``seed``.

    Built on host (pre-processing phase); replicated to devices at load.
    """
    rng = np.random.RandomState(seed)
    return rng.standard_normal((d, m)).astype(np.float32)


def project(x, a):
    """P(x) = x @ A. Works for numpy and jax arrays; (..., d) -> (..., m)."""
    return x @ a
