"""Device-mode ProMIPS search: jit-compiled, batched, fixed-budget.

Implements MIP-Search-II (Algorithm 3) with the block-granular TPU
adaptation (DESIGN.md §3):

  quick-probe -> radius r -> sub-partition sphere filter -> block selection
  -> candidate verification -> Condition B test -> compensation round with
     radius r' over the blocks NOT already scanned (the r'-selection strictly
     contains the r-selection, so scanning the difference reproduces
     Algorithm 3's "extend the range").

All condition/radius arithmetic is imported from `search_common` (the
backend-neutral core shared with `HostSearcher`). Selection (Quick-Probe,
Condition-A thresholds, sphere filter, Condition-B compensation masks) is
BATCH-NATIVE and shared by every verification backend — `select_frontend` /
`compensation_masks` below — so the per-round block masks agree across
backends by construction. Verification backends:

``verification="fused"`` (default; DESIGN.md §10/§12) — rounds over the
  fused block-sparse `kernels/block_mips` kernel: the kernel walks the
  selected pages of ``arrays.x`` in place (scalar-prefetched slot list, no
  gathered union tile) with a streaming per-query top-k, and the tile is
  sized to ``next_pow2(union)`` blocks instead of always the full budget.
  Two drivers, bit-identical to each other and to "batched" at EVERY
  budget (the tile cap rule is the same): `core/search_fused.py`
  host-orchestrates the rounds when called eagerly (tiles sized on host,
  O(log NB) jit cache); `core/search_graph.py` is the fully traceable
  driver — pow2 tile buckets precompiled as `lax.switch` branches — that
  THIS function dispatches to, so jit'd callers and `sharded_search`'s
  shard_map run the fused kernel at every scale.

``verification="batched"`` (DESIGN.md §3.2) — the single-graph two-phase
  runtime. Per round, the blocks selected by ANY query in the batch are
  unioned, their rows gathered into one (R, d) tile, and ALL queries are
  scored against the tile in a single `kernels/ops.mips_score` call (Pallas
  on TPU; its jnp oracle off-TPU — interpret mode is a correctness vehicle,
  opt in with use_pallas=True) — one MXU matmul instead of B x budget
  sequential matvecs. The sequential Condition-A semantics are then
  reconstructed EXACTLY from the precomputed scores: "running k-th best
  >= threshold after block t" is equivalent to "at least k rows scoring
  >= threshold in blocks <= t", so at the default full budget the per-query
  stop block, logical page count, candidate count and final top-k are
  bit-identical to the scan backend (the parity test in
  tests/test_search_runtime.py asserts this). With a FINITE budget the two
  backends budget differently: "scan" caps each query's own selection at
  ``budget`` blocks in layout order, "batched" caps the union tile shared
  by the whole batch, keeping the ``budget`` most PROMISING union blocks
  (`truncate_union` on `block_priority`'s projected-IP upper bound — the
  lever the serve degradation ladder pulls, DESIGN.md §16) — queries whose
  selection
  does not fit are flagged ``exhausted``.

``verification="scan"`` — the legacy per-query `lax.scan` of per-block
  matvecs, kept as the semantics reference and for the benchmark baseline.

Shapes are static: `budget` blocks per round. Work for logically-unneeded
blocks is masked rather than skipped (fixed-shape SPMD); `stats.pages`
reports the *logical* page accesses — the number the paper's Fig. 7 counts —
and is what the benchmark harness records.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import search_common as sc
from .index import IndexArrays, IndexMeta
from .quick_probe import GroupTable, quick_probe_batch


class SearchStats(NamedTuple):
    pages: jnp.ndarray          # logical data-page accesses per query
    candidates: jnp.ndarray     # verified candidate rows per query
    probe_passed: jnp.ndarray   # Quick-Probe Test A hit (bool)
    used_round2: jnp.ndarray    # compensation round triggered (bool)
    radius0: jnp.ndarray        # Quick-Probe radius
    radius1: jnp.ndarray        # compensation radius (0 if unused)
    exhausted: jnp.ndarray      # budget ran out before Condition B held
    rows: jnp.ndarray           # top-k rows in the padded sorted layout (-1 =
                                # empty); lets the runtime rescore candidates
                                # through one shared kernel call

    def to_dict(self) -> dict:
        """Normalized accounting (`core/stats.stats_totals` contract)."""
        from .stats import stats_totals
        return stats_totals(self.pages, self.candidates, self.exhausted)


class TopK(NamedTuple):
    scores: jnp.ndarray  # (k,) descending inner products
    rows: jnp.ndarray    # (k,) rows in the sorted layout (-1 = empty)


def _group_table(arrays: IndexArrays) -> GroupTable:
    return GroupTable(
        code=arrays.g_code,
        min_l1=arrays.g_min_l1,
        rep_proj=arrays.g_rep_proj,
        rep_row=arrays.g_rep_row,
        count=arrays.g_count,
    )


def subpart_distances(arrays: IndexArrays, q_proj):
    """(B, S) projected query -> sub-partition center distances.

    One matmul via the expansion ||c - q||^2 = ||c||^2 - 2 <c, q> + ||q||^2
    (clamped at 0 against cancellation) instead of a (B, S, m) difference
    tensor. Computed ONCE per search and reused by both selection rounds —
    only the radii change between rounds.
    """
    center = arrays.sp_center                                  # (S, m)
    d2 = (jnp.sum(center * center, axis=-1)[None, :]
          - 2.0 * (q_proj @ center.T)
          + jnp.sum(q_proj * q_proj, axis=-1)[:, None])        # (B, S)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def blocks_from_radii(arrays: IndexArrays, d_sp, radius):
    """Batch-native sphere-overlap filter: sub-partitions -> fixed blocks.

    d_sp: (B, S) from `subpart_distances`. ``radius`` may be (B,)
    (paper-faithful, one radius per query) or (B, S) per-sub-partition radii
    (beyond-paper norm-adaptive mode — see `search_common.adaptive_radii`).
    Entries < 0 deselect the sub-partition outright (Cauchy-Schwarz
    pruning). Returns (B, NB) bool.

    The sub-partition -> block mapping is a gather over the precomputed
    ``block_sp_idx`` (NB, KMAX) table (a block is touched iff ANY of its
    sub-partitions is selected) — equivalent to the old per-query cumsum
    over sp ranges, but O(NB * KMAX) instead of an XLA scan over S. Every
    verification backend (fused / batched / scan) goes through this one
    function, so block selections agree across backends by construction.
    """
    if radius.ndim == 1:
        radius = radius[:, None]
    sel_sp = sc.sphere_select(d_sp, arrays.sp_radius[None, :], radius)
    gathered = sel_sp[:, jnp.maximum(arrays.block_sp_idx, 0)]  # (B, NB, KMAX)
    return jnp.any(gathered & (arrays.block_sp_idx >= 0)[None], axis=2)


def select_blocks_batch(arrays: IndexArrays, q_proj, radius):
    """`subpart_distances` + `blocks_from_radii` in one call (standalone
    callers; the search paths reuse the distances across rounds)."""
    return blocks_from_radii(arrays, subpart_distances(arrays, q_proj), radius)


def block_priority(arrays: IndexArrays, q_proj):
    """Best-first key for budget truncation: per block, the NEGATED upper
    bound on any batch query's projected inner product with any point in
    the block's sub-partition balls — ``max_b(q_proj . center + |q_proj| *
    radius)`` by Cauchy-Schwarz, maximized over the block's sub-partitions.
    Ascending = more promising.

    Norm-awareness is the whole point: with norm-strata layouts the
    MIPS-dominating high-norm blocks sit at the END of the layout and are
    often FAR from the query in projection space, so both layout order and
    the ball-gap distance (the progressive driver's key, which re-tests
    every block against an adaptive radius and so can afford it) rank them
    last — a truncating budget would shed exactly the blocks that matter.
    Clamped finite so sub-partition-less blocks still rank strictly ahead
    of non-union blocks in `truncate_union`.
    """
    q_norm = jnp.sqrt(jnp.sum(q_proj * q_proj, axis=1))            # (B,)
    ub = (q_proj @ arrays.sp_center.T
          + q_norm[:, None] * arrays.sp_radius[None, :])           # (B, S)
    ub = jnp.max(ub, axis=0)                                       # (S,)
    gathered = jnp.where(arrays.block_sp_idx >= 0,
                         ub[jnp.maximum(arrays.block_sp_idx, 0)], -jnp.inf)
    return jnp.minimum(-jnp.max(gathered, axis=1), jnp.float32(1e30))


def truncate_union(union, prio, cap: int):
    """Blocks surviving a ``cap``-slot verification tile.

    With ``prio=None`` (full budget — no ranking computed) the union is
    returned unchanged, preserving the historical semantics bit-for-bit.
    With a priority vector, the ``cap`` BEST union blocks survive (ties by
    layout index via the stable sort) instead of the first ``cap`` in
    layout order — a finite budget then sheds the least promising blocks,
    which is what makes it a quality ladder (DESIGN.md §16) rather than an
    arbitrary cut. Callers still lay the surviving set out in layout order,
    so the Condition-A sequential-scan reconstruction is untouched.
    """
    if prio is None:
        return union
    key = jnp.where(union, prio, jnp.inf)
    best = jnp.argsort(key, stable=True)[:cap]
    return jnp.zeros(union.shape[0], bool).at[best].set(True) & union


def adaptive_radii(arrays: IndexArrays, meta: IndexMeta, s_k, q_l2sq, cs_prune: bool):
    """Per-sub-partition norm-adaptive radii (delegates to `search_common`)."""
    return sc.adaptive_radii(arrays.sp_max_l2sq, s_k, q_l2sq, meta.c, meta.x_p,
                             cs_prune=cs_prune, xp=jnp)


# ---------------------------------------------------------------------------
# Batch-native selection frontend (shared by fused / batched / scan)
# ---------------------------------------------------------------------------

def select_frontend(arrays: IndexArrays, meta: IndexMeta, queries):
    """Phase 1 of the two-phase runtime for a whole (B, d) batch at once:
    projection, batched Quick-Probe, Condition-A thresholds and the round-1
    block selection — no per-query `vmap` anywhere.

    Returns (q_proj (B, m), q_l2sq (B,), d_sp (B, S), r0 (B,), probe_ok (B,),
    c_half (B,), mask0 (B, NB)); ``d_sp`` is reused by the compensation
    round so the center-distance matmul runs once per search.

    The `jax.named_scope` labels cost nothing at runtime; they tag the HLO
    so these phases are identifiable in XLA profiles / `jax.profiler.trace`
    captures even for the fully-traced drivers (DESIGN.md §14).
    """
    with jax.named_scope("select_frontend"):
        q_proj = queries @ arrays.a
        q_l1 = jnp.sum(jnp.abs(queries), axis=1)
        q_l2sq = jnp.sum(queries * queries, axis=1)
        with jax.named_scope("quick_probe_batch"):
            _, r0, probe_ok = quick_probe_batch(_group_table(arrays), q_proj,
                                                q_l1, meta.c, meta.x_p)
        c_half = sc.condition_a_threshold(arrays.max_l2sq, q_l2sq, meta.c)
        d_sp = subpart_distances(arrays, q_proj)
        mask0 = blocks_from_radii(arrays, d_sp, r0)
    return q_proj, q_l2sq, d_sp, r0, probe_ok, c_half, mask0


def compensation_masks(arrays: IndexArrays, meta: IndexMeta, d_sp, q_l2sq,
                       s_k, r0, done_a, mask0, norm_adaptive: bool,
                       cs_prune: bool):
    """Condition-B test + compensation-round selection (Algorithm 3 line 12)
    for the whole batch. ``d_sp`` is the frontend's (B, S) center-distance
    matrix. Returns (need2 (B,), r1 (B,), mask1 (B, NB)) with ``mask1``
    already restricted to blocks NOT scanned in round 1.
    """
    with jax.named_scope("compensation_masks"):
        cond_b = sc.condition_b(r0 * r0, s_k, arrays.max_l2sq, q_l2sq,
                                meta.c, meta.x_p, xp=jnp)
        r1 = sc.compensation_radius(s_k, arrays.max_l2sq, q_l2sq,
                                    meta.c, meta.x_p, xp=jnp)
        need2 = ~(cond_b | done_a)
        if norm_adaptive:
            r_comp = sc.adaptive_radii(arrays.sp_max_l2sq[None, :],
                                       s_k[:, None], q_l2sq[:, None], meta.c,
                                       meta.x_p, cs_prune=cs_prune,
                                       xp=jnp)                    # (B, S)
            r_comp = jnp.where(need2[:, None], r_comp, -1.0)
        else:
            r_comp = jnp.where(need2, r1, -1.0)[:, None]          # (B, 1)
        mask1 = blocks_from_radii(arrays, d_sp, r_comp) & ~mask0
    return need2, r1, mask1


def prefilter_round1(arrays: IndexArrays, queries, mask0, k: int,
                     page_rows: int, eps: float,
                     use_pallas: Optional[bool]):
    """Quantized-sketch prefilter, round 1 (DESIGN.md §13): score the block
    sketch for EVERY candidate block before any page is fetched and keep
    only blocks whose upper bound clears the group-max tau. Returns
    (surv (B, NB), est, bnd, bvalid) — est/bnd/bvalid are carried to
    `prefilter_round2` so the compensation round reuses the one sketch
    evaluation. Shared by every backend (host fused driver jit-wraps it,
    the in-graph driver and batched/scan paths call it in-trace), which is
    what keeps all of them bit-identical with the prefilter on."""
    with jax.named_scope("prefilter_round1"):
        est = ops.sketch_scores(queries, arrays.sk_mu, arrays.sk_codebooks,
                                arrays.sk_codes, use_pallas=use_pallas)
        bnd = sc.sketch_margin(queries, arrays.sk_err, eps)
        bvalid = sc.block_valid_from_ids(arrays.ids, page_rows)
        surv = sc.sketch_survivors_round1(mask0, est, bnd, bvalid, k)
    return surv, est, bnd, bvalid


def prefilter_round2(mask1, est, bnd, bvalid, s_k):
    """Compensation-round sketch pruning against the realized k-th score."""
    with jax.named_scope("prefilter_round2"):
        return sc.sketch_survivors_round2(mask1, est, bnd, bvalid, s_k)


def _merge_topk(top: TopK, scores, rows, k: int) -> TopK:
    s, r = sc.topk_merge(top.scores, top.rows, scores, rows, k, xp=jnp)
    return TopK(scores=s, rows=r)


# ---------------------------------------------------------------------------
# Batched two-phase verification (DESIGN.md §3.2)
# ---------------------------------------------------------------------------

def _verify_batched(arrays: IndexArrays, meta: IndexMeta, queries, block_masks,
                    tops: TopK, c_half, k: int, budget: int, use_pallas,
                    prio=None):
    """One verification round for the whole query batch.

    queries: (B, d); block_masks: (B, NB) per-query selected blocks;
    tops: carried-in running top-k, (B, k) leaves; c_half: (B,) Condition-A
    thresholds. Returns (tops', pages (B,), candidates (B,), done_a (B,),
    lost (B,)) with the exact sequential-scan semantics (see module
    docstring); ``lost`` flags queries whose selection did not fit the
    ``budget``-block union tile. ``prio`` (NB,), when given, decides WHICH
    union blocks survive a truncating budget (`truncate_union` — best
    blocks first instead of first-in-layout); the surviving set is still
    walked in layout order.
    """
    n_batch = queries.shape[0]
    page_rows = meta.page_rows
    n_blocks = arrays.block_sp_lo.shape[0]
    budget = min(budget, n_blocks)

    # Union tile: blocks selected by ANY query, in layout order (the
    # sequential-disk pattern the sub-partition layout is designed for).
    union = jnp.any(block_masks, axis=0)                      # (NB,)
    keep = truncate_union(union, prio, budget)
    order = jnp.argsort(~keep, stable=True)                   # kept first
    slots = order[:budget]                                    # (budget,)
    slot_valid = jnp.arange(budget) < jnp.sum(keep.astype(jnp.int32))
    in_tile = jnp.zeros(n_blocks, bool).at[slots].set(slot_valid)

    # Gather candidate rows once and score all queries in one kernel call.
    rows = (slots[:, None] * page_rows + jnp.arange(page_rows)[None, :]).reshape(-1)
    x_tile = jnp.take(arrays.x, rows, axis=0)                 # (R, d)
    ids_tile = jnp.take(arrays.ids, rows)                     # (R,)
    row_valid = (ids_tile >= 0) & jnp.repeat(slot_valid, page_rows)
    scores = ops.mips_score(x_tile, queries, row_valid,
                            use_pallas=use_pallas).T          # (B, R)

    # Reconstruct the sequential Condition-A stop block from the scores:
    # running k-th best >= c_half after block t  <=>  at least k rows
    # (including the carried-in top) score >= c_half within blocks <= t.
    sel_slots = block_masks[:, slots] & slot_valid[None, :]   # (B, budget)
    row_sel = jnp.repeat(sel_slots, page_rows, axis=1)        # (B, R)
    ge = (scores >= c_half[:, None]) & row_sel & row_valid[None, :]
    cnt = ge.reshape(n_batch, budget, page_rows).sum(axis=2)  # (B, budget)
    n0 = jnp.sum(tops.scores >= c_half[:, None], axis=1)      # carried-in hits
    ex_cum = jnp.cumsum(cnt, axis=1) - cnt                    # exclusive cumsum
    done_before = (n0[:, None] + ex_cum) >= k
    live = sel_slots & ~done_before                           # logically-scanned
    pages = jnp.sum(live.astype(jnp.int32), axis=1)

    row_live = jnp.repeat(live, page_rows, axis=1) & row_valid[None, :]
    cand = jnp.sum(row_live.astype(jnp.int32), axis=1)
    done_a = (n0 + jnp.sum(jnp.where(live, cnt, 0), axis=1)) >= k

    masked = jnp.where(row_live, scores, -jnp.inf)            # (B, R)
    row_ids = jnp.where(row_live, rows[None, :], -1)
    merged_s = jnp.concatenate([tops.scores, masked], axis=1)
    merged_r = jnp.concatenate([tops.rows, row_ids], axis=1)
    best_s, idx = jax.lax.top_k(merged_s, k)
    best_r = jnp.take_along_axis(merged_r, idx, axis=1)

    lost = jnp.any(block_masks & ~in_tile[None, :], axis=1)
    return TopK(scores=best_s, rows=best_r), pages, cand, done_a, lost


def _search_batch_batched(arrays, meta, queries, k, budget, budget2,
                          norm_adaptive, cs_prune, use_pallas,
                          prefilter=False, prefilter_eps=1.0):
    """Two-phase runtime: batched selection + one mips_score call per round."""
    n_batch = queries.shape[0]
    n_blocks = arrays.block_sp_lo.shape[0]
    q_proj, q_l2sq, d_sp, r0, probe_ok, c_half, mask0 = select_frontend(
        arrays, meta, queries)
    # best-first truncation key, only materialized when a finite budget can
    # actually truncate (the full-budget graph stays byte-identical)
    prio = (block_priority(arrays, q_proj)
            if min(budget, budget2) < n_blocks else None)
    mask_r1 = mask0
    sk_est = sk_bnd = sk_bvalid = None
    if prefilter:
        mask_r1, sk_est, sk_bnd, sk_bvalid = prefilter_round1(
            arrays, queries, mask0, k, meta.page_rows, prefilter_eps,
            use_pallas)
    empty = TopK(scores=jnp.full((n_batch, k), -jnp.inf),
                 rows=jnp.full((n_batch, k), -1, jnp.int32))
    top, pages1, cand1, done_a, lost1 = _verify_batched(
        arrays, meta, queries, mask_r1, empty, c_half, k, budget, use_pallas,
        prio=prio)
    # Without this barrier XLA CPU re-materializes round-1 fusions inside the
    # round-2 consumers (~2x wall clock); semantically an identity.
    top, done_a, mask0 = jax.lax.optimization_barrier((top, done_a, mask0))

    # Condition B + compensation selection over blocks newly chosen by r'.
    s_k = top.scores[:, k - 1]
    need2, r1, mask1 = compensation_masks(arrays, meta, d_sp, q_l2sq, s_k,
                                          r0, done_a, mask0, norm_adaptive,
                                          cs_prune)
    mask_r2 = mask1
    if prefilter:
        mask_r2 = prefilter_round2(mask1, sk_est, sk_bnd, sk_bvalid, s_k)

    # With an all-False mask1 (every query stopped by A/B in round 1 — the
    # common case) the verification round is an identity on `top` with zero
    # pages/candidates; skip the full tile gather + matmul it would burn.
    def round2(args):
        mask_r2, top = args
        return _verify_batched(arrays, meta, queries, mask_r2, top, c_half, k,
                               budget2, use_pallas, prio=prio)

    def skip2(args):
        _, top = args
        zero = jnp.zeros(top.scores.shape[0], jnp.int32)
        false = jnp.zeros(top.scores.shape[0], bool)
        return top, zero, zero, false, false

    top, pages2, cand2, _, lost2 = jax.lax.cond(
        jnp.any(need2), round2, skip2, (mask_r2, top))

    stats = SearchStats(
        pages=pages1 + pages2,
        candidates=cand1 + cand2,
        probe_passed=probe_ok,
        used_round2=need2,
        radius0=r0,
        radius1=jnp.where(need2, r1, 0.0),
        exhausted=lost1 | (need2 & lost2),
        rows=top.rows,
    )
    ids = jnp.where(top.rows >= 0, arrays.ids[jnp.maximum(top.rows, 0)], -1)
    return ids, top.scores, stats


# ---------------------------------------------------------------------------
# Legacy scan verification (per-query lax.scan of per-block matvecs)
# ---------------------------------------------------------------------------

def _scan_blocks(arrays, meta, q, q_l2sq, block_mask, top: TopK, k: int, budget: int):
    """Budgeted scoring pass over the selected blocks (one while-round).

    Returns (top, pages, candidates, done_a). Blocks are visited in layout
    order (selected-first via stable argsort), matching the sequential-disk
    read pattern the paper's sub-partition layout is designed for.
    """
    page_rows = meta.page_rows
    order = jnp.argsort(~block_mask, stable=True)  # selected block ids first
    n_sel = jnp.sum(block_mask.astype(jnp.int32))
    c_half = sc.condition_a_threshold(arrays.max_l2sq, q_l2sq, meta.c)

    def body(carry, t):
        top, pages, cand, done_a = carry
        blk = order[t]
        live = (t < n_sel) & ~done_a
        base = blk * page_rows
        rows_x = jax.lax.dynamic_slice(arrays.x, (base, 0), (page_rows, arrays.x.shape[1]))
        rows_id = jax.lax.dynamic_slice(arrays.ids, (base,), (page_rows,))
        scores = rows_x @ q  # (page_rows,) — the MXU verification matvec
        valid = live & (rows_id >= 0)
        scores = jnp.where(valid, scores, -jnp.inf)
        row_idx = jnp.where(valid, base + jnp.arange(page_rows), -1)
        top = jax.tree.map(
            lambda new, old: jnp.where(live, new, old),
            _merge_topk(top, scores, row_idx, k),
            top,
        )
        pages = pages + live.astype(jnp.int32)
        cand = cand + jnp.sum(valid.astype(jnp.int32))
        # Condition A on the running k-th best (Theorem 1, c-k-AMIP form).
        done_a = done_a | (top.scores[k - 1] >= c_half)
        return (top, pages, cand, done_a), None

    init = (top, jnp.int32(0), jnp.int32(0), top.scores[k - 1] >= c_half)
    (top, pages, cand, done_a), _ = jax.lax.scan(body, init, jnp.arange(budget))
    return top, pages, cand, done_a


def _search_batch_scan(arrays, meta, queries, k, budget, budget2,
                       norm_adaptive, cs_prune,
                       prefilter=False, prefilter_eps=1.0):
    n_batch = queries.shape[0]
    q_proj, q_l2sq, d_sp, r0, probe_ok, c_half, mask0 = select_frontend(
        arrays, meta, queries)
    mask_r1 = mask0
    sk_est = sk_bnd = sk_bvalid = None
    if prefilter:
        mask_r1, sk_est, sk_bnd, sk_bvalid = prefilter_round1(
            arrays, queries, mask0, k, meta.page_rows, prefilter_eps, None)

    empty = TopK(scores=jnp.full((n_batch, k), -jnp.inf),
                 rows=jnp.full((n_batch, k), -1, jnp.int32))
    top, pages1, cand1, done_a = jax.vmap(
        lambda q, ql2, m, t: _scan_blocks(arrays, meta, q, ql2, m, t, k, budget)
    )(queries, q_l2sq, mask_r1, empty)

    # Condition B + compensation selection (same batch-native functions as
    # the batched/fused backends, so the masks agree bit-for-bit).
    s_k = top.scores[:, k - 1]
    need2, r1, mask1 = compensation_masks(arrays, meta, d_sp, q_l2sq, s_k,
                                          r0, done_a, mask0, norm_adaptive,
                                          cs_prune)
    mask_r2 = mask1
    if prefilter:
        mask_r2 = prefilter_round2(mask1, sk_est, sk_bnd, sk_bvalid, s_k)
    top, pages2, cand2, _ = jax.vmap(
        lambda q, ql2, m, t: _scan_blocks(arrays, meta, q, ql2, m, t, k, budget2)
    )(queries, q_l2sq, mask_r2, top)

    exhausted = (jnp.sum(mask_r1.astype(jnp.int32), axis=1) > budget) | (
        need2 & (jnp.sum(mask_r2.astype(jnp.int32), axis=1) > budget2)
    )
    stats = SearchStats(
        pages=pages1 + pages2,
        candidates=cand1 + cand2,
        probe_passed=probe_ok,
        used_round2=need2,
        radius0=r0,
        radius1=jnp.where(need2, r1, 0.0),
        exhausted=exhausted,
        rows=top.rows,
    )
    ids = jnp.where(top.rows >= 0, arrays.ids[jnp.maximum(top.rows, 0)], -1)
    return ids, top.scores, stats


@functools.partial(
    jax.jit,
    static_argnames=("meta", "k", "budget", "budget2", "norm_adaptive",
                     "cs_prune", "verification", "use_pallas", "prefilter",
                     "prefilter_eps", "dense_frac", "tile_cap"),
)
def search_batch(
    arrays: IndexArrays,
    meta: IndexMeta,
    queries: jnp.ndarray,
    k: int = 10,
    budget: int = 64,
    budget2: int = 64,
    norm_adaptive: bool = False,
    cs_prune: bool = False,
    verification: str = "batched",
    use_pallas: Optional[bool] = None,
    prefilter: bool = False,
    prefilter_eps: float = 1.0,
    dense_frac: float = sc.DENSE_FRAC,
    tile_cap: Optional[int] = None,
):
    """c-k-AMIP search for a batch of queries. queries: (B, d).

    Returns (ids (B, k) original row ids, scores (B, k), SearchStats).
    ``verification`` selects the candidate-scoring backend (module docstring);
    identical results at full budget, "batched" amortizes the whole batch
    into one Pallas matmul per round (budget semantics differ when finite —
    see module docstring). ``prefilter`` enables the quantized-sketch block
    prefilter on every backend (`prefilter_round1/2`, DESIGN.md §13).
    ``dense_frac`` / ``tile_cap`` are the fused tile knobs the offline tuner
    (`repro.tune`) adjusts; the other backends ignore them (their tile is
    always the budget rule).
    """
    if verification == "fused":
        # the in-graph fused driver: pow2 tile buckets as lax.switch
        # branches, so the same block_mips kernel traces under jit and
        # shard_map (the eager host-orchestrated driver lives in
        # `core/search_fused.py` and is dispatched by `core/runtime.search`
        # before this point). Lazy import: search_graph imports this module.
        from .search_graph import search_batch_fused_graph
        return search_batch_fused_graph(arrays, meta, queries, k, budget,
                                        budget2, norm_adaptive, cs_prune,
                                        use_pallas, prefilter, prefilter_eps,
                                        dense_frac, tile_cap)
    if verification == "batched":
        return _search_batch_batched(arrays, meta, queries, k, budget, budget2,
                                     norm_adaptive, cs_prune, use_pallas,
                                     prefilter, prefilter_eps)
    if verification == "scan":
        return _search_batch_scan(arrays, meta, queries, k, budget, budget2,
                                  norm_adaptive, cs_prune,
                                  prefilter, prefilter_eps)
    raise ValueError(f"unknown verification backend: {verification!r}")


@functools.partial(jax.jit, static_argnames=("meta", "k", "budget", "cs_prune"))
def search_batch_progressive(
    arrays: IndexArrays,
    meta: IndexMeta,
    queries: jnp.ndarray,
    k: int = 10,
    budget: int = 64,
    cs_prune: bool = True,
):
    """Beyond-paper progressive device search (see HostSearcher.search_progressive).

    Blocks are visited in ascending "gap" order (projected distance to the
    block's nearest sub-partition surface); each step re-tests the block
    against the CURRENT norm-adaptive radius, so the frontier tightens as the
    running k-th score grows. Per-block tests are conservative (block-level
    max norm / min gap), so no qualified sub-partition is ever skipped.
    """
    page_rows = meta.page_rows

    def one(q):
        q_proj = q @ arrays.a
        q_l2sq = jnp.sum(q * q)

        d_sp = jnp.sqrt(jnp.sum((arrays.sp_center - q_proj[None, :]) ** 2, axis=-1))
        gap_sp = d_sp - arrays.sp_radius  # distance to sub-partition surface
        gathered = jnp.where(
            arrays.block_sp_idx >= 0,
            gap_sp[jnp.maximum(arrays.block_sp_idx, 0)],
            jnp.inf,
        )
        block_gap = jnp.min(gathered, axis=1)  # (NB,)
        order = jnp.argsort(block_gap, stable=True)
        c_half = sc.condition_a_threshold(arrays.max_l2sq, q_l2sq, meta.c)

        def qualify(blk, s_k):
            r_blk = sc.adaptive_radii(arrays.block_max_l2sq[blk], s_k, q_l2sq,
                                      meta.c, meta.x_p, cs_prune=cs_prune, xp=jnp)
            return sc.gap_select(block_gap[blk], r_blk)

        def body(carry, t):
            top, pages, cand, done_a = carry
            blk = order[t]
            live = qualify(blk, top.scores[k - 1]) & ~done_a
            base = blk * page_rows
            rows_x = jax.lax.dynamic_slice(arrays.x, (base, 0), (page_rows, arrays.x.shape[1]))
            rows_id = jax.lax.dynamic_slice(arrays.ids, (base,), (page_rows,))
            scores = rows_x @ q
            valid = live & (rows_id >= 0)
            scores = jnp.where(valid, scores, -jnp.inf)
            row_idx = jnp.where(valid, base + jnp.arange(page_rows), -1)
            top = jax.tree.map(
                lambda new, old: jnp.where(live, new, old),
                _merge_topk(top, scores, row_idx, k),
                top,
            )
            pages = pages + live.astype(jnp.int32)
            cand = cand + jnp.sum(valid.astype(jnp.int32))
            done_a = done_a | (top.scores[k - 1] >= c_half)
            return (top, pages, cand, done_a), None

        empty = TopK(scores=jnp.full((k,), -jnp.inf), rows=jnp.full((k,), -1, jnp.int32))
        init = (empty, jnp.int32(0), jnp.int32(0), jnp.bool_(False))
        (top, pages, cand, done_a), _ = jax.lax.scan(body, init, jnp.arange(budget))

        # any still-qualified block beyond the budget frontier?
        s_k = top.scores[k - 1]
        qual_all = jax.vmap(lambda b: qualify(b, s_k))(jnp.arange(arrays.block_sp_lo.shape[0]))
        visited = jnp.zeros(arrays.block_sp_lo.shape[0], bool).at[order[:budget]].set(True)
        exhausted = jnp.any(qual_all & ~visited) & ~done_a

        stats = SearchStats(
            pages=pages, candidates=cand,
            probe_passed=jnp.bool_(False), used_round2=jnp.bool_(False),
            radius0=jnp.float32(0.0), radius1=jnp.float32(0.0),
            exhausted=exhausted, rows=top.rows,
        )
        ids = jnp.where(top.rows >= 0, arrays.ids[jnp.maximum(top.rows, 0)], -1)
        return ids, top.scores, stats

    return jax.vmap(one)(queries)
