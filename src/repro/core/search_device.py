"""Device-mode ProMIPS search: jit-compiled, batched, fixed-budget.

Implements MIP-Search-II (Algorithm 3) with the block-granular TPU
adaptation (DESIGN.md §3):

  quick-probe -> radius r -> sub-partition sphere filter -> block selection
  -> budgeted block scoring scan (MXU matvecs + running top-k + Condition A)
  -> Condition B test -> compensation round with radius r' over the blocks
     NOT already scanned (the r'-selection strictly contains the r-selection,
     so scanning the difference reproduces Algorithm 3's "extend the range").

Shapes are static: `budget` blocks per round. Work for logically-unneeded
blocks is masked rather than skipped (fixed-shape SPMD); `stats.pages`
reports the *logical* page accesses — the number the paper's Fig. 7 counts —
and is what the benchmark harness records.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .index import IndexArrays, IndexMeta
from .quick_probe import GroupTable, quick_probe


class SearchStats(NamedTuple):
    pages: jnp.ndarray          # logical data-page accesses per query
    candidates: jnp.ndarray     # verified candidate rows per query
    probe_passed: jnp.ndarray   # Quick-Probe Test A hit (bool)
    used_round2: jnp.ndarray    # compensation round triggered (bool)
    radius0: jnp.ndarray        # Quick-Probe radius
    radius1: jnp.ndarray        # compensation radius (0 if unused)
    exhausted: jnp.ndarray      # budget ran out before Condition B held


class TopK(NamedTuple):
    scores: jnp.ndarray  # (k,) descending inner products
    rows: jnp.ndarray    # (k,) rows in the sorted layout (-1 = empty)


def _select_blocks(arrays: IndexArrays, q_proj, radius):
    """Sphere-overlap filter: sub-partitions -> fixed-size blocks.

    ``radius`` may be a scalar (paper-faithful, global radius) or a (S,)
    vector of per-sub-partition radii (beyond-paper norm-adaptive mode —
    see `adaptive_radii`). Entries < 0 deselect the sub-partition outright
    (Cauchy-Schwarz pruning).
    """
    d_sp = jnp.sqrt(jnp.sum((arrays.sp_center - q_proj[None, :]) ** 2, axis=-1))
    radius = jnp.broadcast_to(radius, d_sp.shape)
    sel_sp = (d_sp <= radius + arrays.sp_radius) & (radius >= 0.0)  # (S,)
    csum = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(sel_sp.astype(jnp.int32))])
    touched = csum[arrays.block_sp_hi] - csum[arrays.block_sp_lo]
    return touched > 0  # (NB,)


def adaptive_radii(arrays: IndexArrays, meta: IndexMeta, s_k, q_l2sq, cs_prune: bool):
    """Beyond-paper norm-adaptive per-sub-partition Condition-B radii.

    Theorem 2's denominator upper-bounds ||o*||^2 by the GLOBAL max norm
    ||o_M||^2; but if o* lives in sub-partition sp, ||o*||^2 <= M_sp^2, so
    searching each sp out to  r_sp = sqrt(x_p * (M_sp^2 + ||q||^2 - 2 s_k / c))
    preserves P[miss] <= 1-p by the identical argument (the bound is applied
    in the one sub-partition that actually contains o*). On long-tail norm
    distributions only the few high-norm sub-partitions get the big radius.

    With ``cs_prune``, sub-partitions where even Cauchy-Schwarz's best case
    M_sp * ||q|| cannot beat the running k-th score are deselected entirely
    (deterministic: such a sp can contain neither o* nor a top-k improver).
    """
    s_k = jnp.maximum(s_k, -1e30)
    denom = arrays.sp_max_l2sq + q_l2sq - 2.0 * s_k / meta.c
    r_sp = jnp.sqrt(jnp.maximum(meta.x_p * denom, 0.0))
    if cs_prune:
        ok = jnp.sqrt(arrays.sp_max_l2sq) * jnp.sqrt(q_l2sq) >= s_k
        r_sp = jnp.where(ok, r_sp, -1.0)
    return r_sp


def _merge_topk(top: TopK, scores, rows, k: int) -> TopK:
    s = jnp.concatenate([top.scores, scores])
    r = jnp.concatenate([top.rows, rows])
    best_s, idx = jax.lax.top_k(s, k)
    return TopK(scores=best_s, rows=r[idx])


def _scan_blocks(arrays, meta, q, q_l2sq, block_mask, top: TopK, k: int, budget: int):
    """Budgeted scoring pass over the selected blocks (one while-round).

    Returns (top, pages, candidates, done_a). Blocks are visited in layout
    order (selected-first via stable argsort), matching the sequential-disk
    read pattern the paper's sub-partition layout is designed for.
    """
    page_rows = meta.page_rows
    order = jnp.argsort(~block_mask, stable=True)  # selected block ids first
    n_sel = jnp.sum(block_mask.astype(jnp.int32))
    c_half = 0.5 * meta.c * (arrays.max_l2sq + q_l2sq)  # Condition A threshold on <o,q>

    def body(carry, t):
        top, pages, cand, done_a = carry
        blk = order[t]
        live = (t < n_sel) & ~done_a
        base = blk * page_rows
        rows_x = jax.lax.dynamic_slice(arrays.x, (base, 0), (page_rows, arrays.x.shape[1]))
        rows_id = jax.lax.dynamic_slice(arrays.ids, (base,), (page_rows,))
        scores = rows_x @ q  # (page_rows,) — the MXU verification matvec
        valid = live & (rows_id >= 0)
        scores = jnp.where(valid, scores, -jnp.inf)
        row_idx = jnp.where(valid, base + jnp.arange(page_rows), -1)
        top = jax.tree.map(
            lambda new, old: jnp.where(live, new, old),
            _merge_topk(top, scores, row_idx, k),
            top,
        )
        pages = pages + live.astype(jnp.int32)
        cand = cand + jnp.sum(valid.astype(jnp.int32))
        # Condition A on the running k-th best (Theorem 1, c-k-AMIP form).
        done_a = done_a | (top.scores[k - 1] >= c_half)
        return (top, pages, cand, done_a), None

    init = (top, jnp.int32(0), jnp.int32(0), top.scores[k - 1] >= c_half)
    (top, pages, cand, done_a), _ = jax.lax.scan(body, init, jnp.arange(budget))
    return top, pages, cand, done_a


@functools.partial(
    jax.jit, static_argnames=("meta", "k", "budget", "budget2", "norm_adaptive", "cs_prune")
)
def search_batch(
    arrays: IndexArrays,
    meta: IndexMeta,
    queries: jnp.ndarray,
    k: int = 10,
    budget: int = 64,
    budget2: int = 64,
    norm_adaptive: bool = False,
    cs_prune: bool = False,
):
    """c-k-AMIP search for a batch of queries. queries: (B, d).

    Returns (ids (B, k) original row ids, scores (B, k), SearchStats).
    """
    table = GroupTable(
        code=arrays.g_code,
        min_l1=arrays.g_min_l1,
        rep_proj=arrays.g_rep_proj,
        rep_row=arrays.g_rep_row,
        count=arrays.g_count,
    )

    def one(q):
        q_proj = q @ arrays.a
        q_l1 = jnp.sum(jnp.abs(q))
        q_l2sq = jnp.sum(q * q)
        _, r0, probe_ok = quick_probe(table, q_proj, q_l1, meta.c, meta.x_p)

        empty = TopK(scores=jnp.full((k,), -jnp.inf), rows=jnp.full((k,), -1, jnp.int32))
        mask0 = _select_blocks(arrays, q_proj, r0)
        top, pages1, cand1, done_a = _scan_blocks(
            arrays, meta, q, q_l2sq, mask0, empty, k, budget
        )

        # Condition B with the Quick-Probe radius (Algorithm 3 line 12).
        s_k = top.scores[k - 1]
        denom = arrays.max_l2sq + q_l2sq - 2.0 * jnp.maximum(s_k, -1e30) / meta.c
        cond_b = (denom <= 0.0) | (r0 * r0 >= meta.x_p * denom)
        r1 = jnp.sqrt(jnp.maximum(meta.x_p * denom, 0.0))
        need2 = ~(cond_b | done_a)

        # Compensation round over blocks newly selected by r' (r' > r0 here).
        if norm_adaptive:
            r_comp = adaptive_radii(arrays, meta, s_k, q_l2sq, cs_prune)
            r_comp = jnp.where(need2, r_comp, -1.0)
        else:
            r_comp = jnp.where(need2, r1, -1.0)
        mask1 = _select_blocks(arrays, q_proj, r_comp) & ~mask0
        top, pages2, cand2, _ = _scan_blocks(
            arrays, meta, q, q_l2sq, mask1, top, k, budget2
        )
        exhausted = (jnp.sum(mask0.astype(jnp.int32)) > budget) | (
            need2 & (jnp.sum(mask1.astype(jnp.int32)) > budget2)
        )
        stats = SearchStats(
            pages=pages1 + pages2,
            candidates=cand1 + cand2,
            probe_passed=probe_ok,
            used_round2=need2,
            radius0=r0,
            radius1=jnp.where(need2, r1, 0.0),
            exhausted=exhausted,
        )
        ids = jnp.where(top.rows >= 0, arrays.ids[jnp.maximum(top.rows, 0)], -1)
        return ids, top.scores, stats

    return jax.vmap(one)(queries)


@functools.partial(jax.jit, static_argnames=("meta", "k", "budget", "cs_prune"))
def search_batch_progressive(
    arrays: IndexArrays,
    meta: IndexMeta,
    queries: jnp.ndarray,
    k: int = 10,
    budget: int = 64,
    cs_prune: bool = True,
):
    """Beyond-paper progressive device search (see HostSearcher.search_progressive).

    Blocks are visited in ascending "gap" order (projected distance to the
    block's nearest sub-partition surface); each step re-tests the block
    against the CURRENT norm-adaptive radius, so the frontier tightens as the
    running k-th score grows. Per-block tests are conservative (block-level
    max norm / min gap), so no qualified sub-partition is ever skipped.
    """
    page_rows = meta.page_rows

    def one(q):
        q_proj = q @ arrays.a
        q_l2sq = jnp.sum(q * q)
        q_norm = jnp.sqrt(q_l2sq)

        d_sp = jnp.sqrt(jnp.sum((arrays.sp_center - q_proj[None, :]) ** 2, axis=-1))
        gap_sp = d_sp - arrays.sp_radius  # distance to sub-partition surface
        gathered = jnp.where(
            arrays.block_sp_idx >= 0,
            gap_sp[jnp.maximum(arrays.block_sp_idx, 0)],
            jnp.inf,
        )
        block_gap = jnp.min(gathered, axis=1)  # (NB,)
        order = jnp.argsort(block_gap, stable=True)
        c_half = 0.5 * meta.c * (arrays.max_l2sq + q_l2sq)

        def qualify(blk, s_k):
            m2 = arrays.block_max_l2sq[blk]
            denom = m2 + q_l2sq - 2.0 * jnp.maximum(s_k, -1e30) / meta.c
            r_blk = jnp.sqrt(jnp.maximum(meta.x_p * denom, 0.0))
            ok = block_gap[blk] <= r_blk
            if cs_prune:
                ok &= jnp.sqrt(m2) * q_norm >= s_k
            return ok

        def body(carry, t):
            top, pages, cand, done_a = carry
            blk = order[t]
            live = qualify(blk, top.scores[k - 1]) & ~done_a
            base = blk * page_rows
            rows_x = jax.lax.dynamic_slice(arrays.x, (base, 0), (page_rows, arrays.x.shape[1]))
            rows_id = jax.lax.dynamic_slice(arrays.ids, (base,), (page_rows,))
            scores = rows_x @ q
            valid = live & (rows_id >= 0)
            scores = jnp.where(valid, scores, -jnp.inf)
            row_idx = jnp.where(valid, base + jnp.arange(page_rows), -1)
            top = jax.tree.map(
                lambda new, old: jnp.where(live, new, old),
                _merge_topk(top, scores, row_idx, k),
                top,
            )
            pages = pages + live.astype(jnp.int32)
            cand = cand + jnp.sum(valid.astype(jnp.int32))
            done_a = done_a | (top.scores[k - 1] >= c_half)
            return (top, pages, cand, done_a), None

        empty = TopK(scores=jnp.full((k,), -jnp.inf), rows=jnp.full((k,), -1, jnp.int32))
        init = (empty, jnp.int32(0), jnp.int32(0), jnp.bool_(False))
        (top, pages, cand, done_a), _ = jax.lax.scan(body, init, jnp.arange(budget))

        # any still-qualified block beyond the budget frontier?
        s_k = top.scores[k - 1]
        qual_all = jax.vmap(lambda b: qualify(b, s_k))(jnp.arange(arrays.block_sp_lo.shape[0]))
        visited = jnp.zeros(arrays.block_sp_lo.shape[0], bool).at[order[:budget]].set(True)
        exhausted = jnp.any(qual_all & ~visited) & ~done_a

        stats = SearchStats(
            pages=pages, candidates=cand,
            probe_passed=jnp.bool_(False), used_round2=jnp.bool_(False),
            radius0=jnp.float32(0.0), radius1=jnp.float32(0.0),
            exhausted=exhausted,
        )
        ids = jnp.where(top.rows >= 0, arrays.ids[jnp.maximum(top.rows, 0)], -1)
        return ids, top.scores, stats

    return jax.vmap(one)(queries)
