"""Host-mode ProMIPS search: faithful sequential semantics + page accounting.

NumPy implementations of the paper's Algorithm 1 (MIP-Search-I, incremental
NN with per-point condition tests) and Algorithms 2+3 (Quick-Probe +
range-search MIP-Search-II). This is the reference the accuracy benchmarks
(Figs. 5-11) and the unit tests run against, and the path that reproduces
the paper's *page access* metric exactly: a page = `page_rows` contiguous
rows of the sorted layout (4 KB by default), and every fetch of a row whose
page is not already resident counts one access.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import search_common as sc
from .chi2 import chi2_ppf_host
from .idistance import ring_key_range
from .index import ProMIPSIndex
from .quick_probe import pack_codes_np


@dataclass
class HostStats:
    pages: int = 0
    candidates: int = 0
    probe_passed: bool = False
    used_round2: bool = False
    rounds: int = 1
    stopped_by: str = "exhausted"  # "A" | "B" | "exhausted"
    radius0: float = 0.0
    radius1: float = 0.0
    _resident: set = field(default_factory=set)

    def touch_rows(self, rows: np.ndarray, page_rows: int):
        fresh = set(np.unique(rows // page_rows).tolist()) - self._resident
        self._resident |= fresh
        self.pages += len(fresh)

    def to_dict(self) -> dict:
        """Normalized accounting (`core/stats.stats_totals` contract).
        Host search is single-query, so ``queries`` is 1."""
        from .stats import stats_totals
        return stats_totals(self.pages, self.candidates,
                            self.stopped_by == "exhausted")


class HostSearcher:
    """Shared state for the three search algorithms over one index."""

    def __init__(self, index: ProMIPSIndex):
        self.idx = index
        a = index.arrays
        self.meta = index.meta
        self.layout = index.layout
        n = self.meta.n
        self.x = np.asarray(a.x[:n])
        self.p = np.asarray(a.p[:n])
        self.ids = np.asarray(a.ids[:n])
        self.max_l2sq = float(a.max_l2sq)
        self.g_code = np.asarray(a.g_code)
        self.g_min_l1 = np.asarray(a.g_min_l1)
        self.g_rep_proj = np.asarray(a.g_rep_proj)
        self.g_rep_row = np.asarray(a.g_rep_row)
        self.sp_center = np.asarray(a.sp_center)
        self.sp_radius = np.asarray(a.sp_radius)
        self.sp_start = np.asarray(a.sp_start)
        self.sp_max_l2sq = np.asarray(a.sp_max_l2sq)
        self.proj = np.asarray(a.a)
        self._chi2_cache: dict[float, float] = {}

    # -- shared helpers (all math from search_common, numpy backend) --------
    def _x_p(self, p: float) -> float:
        if p not in self._chi2_cache:
            self._chi2_cache[p] = chi2_ppf_host(p, self.meta.m)
        return self._chi2_cache[p]

    def _condition_a(self, best_ip: float, q_l2sq: float, c: float) -> bool:
        return bool(sc.condition_a(best_ip, self.max_l2sq, q_l2sq, c))

    def _condition_b(self, proj_d2: float, best_ip: float, q_l2sq: float,
                     c: float, x_p: float) -> bool:
        return bool(sc.condition_b(proj_d2, best_ip, self.max_l2sq, q_l2sq,
                                   c, x_p, xp=np))

    # -- Algorithm 2: Quick-Probe ------------------------------------------
    def quick_probe(self, q: np.ndarray, c: float, p: float, stats: HostStats):
        """Sequential ascending-LB group scan, faithful to Algorithm 2."""
        m = self.meta.m
        q_proj = q @ self.proj
        q_code = pack_codes_np(q_proj[None, :])[0]
        q_l1 = float(np.abs(q).sum())
        x_p = self._x_p(p)

        xor = self.g_code ^ q_code
        bits = ((xor[:, None] >> np.arange(m, dtype=np.uint32)) & 1).astype(np.float32)
        lb = bits @ np.abs(q_proj).astype(np.float32) / np.sqrt(m)

        order = np.argsort(lb, kind="stable")  # ascending lower bound
        best_val, best_g = -np.inf, order[0]
        chosen = -1
        for g in order:
            val = lb[g] ** 2 / max(c * (self.g_min_l1[g] + q_l1) ** 2, 1e-30)
            if val >= x_p:  # Test A
                chosen = g
                stats.probe_passed = True
                break
            if val > best_val:
                best_val, best_g = val, g
        if chosen < 0:
            chosen = best_g
        rep_row = int(self.g_rep_row[chosen])
        # fetching the representative's projected point costs one page access
        stats.touch_rows(np.asarray([rep_row]), self.meta.page_rows)
        radius = float(np.linalg.norm(self.p[rep_row] - q_proj))
        stats.radius0 = radius
        return q_proj, radius

    # -- Algorithm 3: MIP-Search-II ------------------------------------------
    def search(self, q: np.ndarray, k: int = 10, c: float | None = None,
               p: float | None = None, norm_adaptive: bool = False,
               cs_prune: bool = False):
        """Quick-Probe + range search + compensation round.

        ``norm_adaptive`` / ``cs_prune`` enable the beyond-paper
        per-sub-partition radii and Cauchy-Schwarz pruning (see
        search_common.adaptive_radii for the guarantee argument); defaults
        reproduce the paper exactly.
        """
        meta = self.meta
        c = meta.c if c is None else c
        p = meta.p if p is None else p
        x_p = self._x_p(p)
        stats = HostStats()
        q = np.asarray(q, np.float32)
        q_l2sq = float(q @ q)
        q_proj, r = self.quick_probe(q, c, p, stats)

        top_s = np.full(k, -np.inf)
        top_r = np.full(k, -1, np.int64)

        def run_round(radius, skip_sp: set[int]):
            nonlocal top_s, top_r
            d_sp = np.linalg.norm(self.sp_center - q_proj[None, :], axis=1)
            radius = np.broadcast_to(np.asarray(radius, np.float64), d_sp.shape)
            sel = np.nonzero(sc.sphere_select(d_sp, self.sp_radius, radius))[0]
            done_a = False
            visited = set()
            for s in sel:
                if s in skip_sp:
                    continue
                visited.add(int(s))
                lo, hi = int(self.sp_start[s]), int(self.sp_start[s + 1])
                rows = np.arange(lo, hi)
                stats.touch_rows(rows, meta.page_rows)
                scores = self.x[lo:hi] @ q
                stats.candidates += hi - lo
                top_s, top_r = sc.topk_merge(top_s, top_r, scores, rows, k, xp=np)
                if self._condition_a(top_s[k - 1], q_l2sq, c):
                    done_a = True
                    break
            return done_a, visited

        done_a, visited = run_round(r, set())
        if done_a:
            stats.stopped_by = "A"
        else:
            # Condition B with the Quick-Probe radius (Algorithm 3 line 12).
            if self._condition_b(r * r, top_s[k - 1], q_l2sq, c, x_p):
                stats.stopped_by = "B"
            else:
                s_k = top_s[k - 1]
                if norm_adaptive:
                    r1 = sc.adaptive_radii(self.sp_max_l2sq, s_k, q_l2sq, c,
                                           x_p, cs_prune=cs_prune, xp=np)
                    stats.radius1 = float(np.max(r1))
                else:
                    r1 = float(sc.compensation_radius(s_k, self.max_l2sq,
                                                      q_l2sq, c, x_p, xp=np))
                    stats.radius1 = r1
                stats.used_round2, stats.rounds = True, 2
                done_a, _ = run_round(r1, visited)
                stats.stopped_by = "A" if done_a else "B"
        valid = top_r >= 0
        ids = np.where(valid, self.ids[np.maximum(top_r, 0)], -1)
        return ids, np.where(valid, top_s, -np.inf), stats

    # -- Beyond-paper: progressive norm-adaptive search ----------------------
    def search_progressive(self, q: np.ndarray, k: int = 10,
                           c: float | None = None, p: float | None = None,
                           cs_prune: bool = True):
        """Single-pass sub-partition scan in ascending projected distance with
        per-sub-partition norm-adaptive Condition-B radii that tighten as the
        running k-th score grows.

        Guarantee: sub-partitions are visited in ascending d_sp; a sp
        disqualified at visit time (d_sp > r_sp(s_k) + radius_sp, or
        CS-pruned) stays disqualified because s_k only grows and radii only
        shrink. At termination every unvisited sp satisfies the per-sp
        Condition B (see search_common.adaptive_radii), so
        P[o* missed] <= 1 - p exactly as in Theorem 2. Condition A still
        short-circuits deterministically.
        """
        meta = self.meta
        c = meta.c if c is None else c
        p = meta.p if p is None else p
        x_p = self._x_p(p)
        stats = HostStats()
        q = np.asarray(q, np.float32)
        q_l2sq = float(q @ q)
        q_proj = q @ self.proj
        stats.probe_passed = False  # progressive mode does not use Quick-Probe

        d_sp = np.linalg.norm(self.sp_center - q_proj[None, :], axis=1)
        order = np.argsort(d_sp, kind="stable")
        top_s = np.full(k, -np.inf)
        top_r = np.full(k, -1, np.int64)
        for s in order:
            s_k = top_s[k - 1]
            m_sp = float(self.sp_max_l2sq[s])
            r_sp = sc.adaptive_radii(m_sp, s_k, q_l2sq, c, x_p,
                                     cs_prune=cs_prune, xp=np)
            if not sc.sphere_select(d_sp[s], self.sp_radius[s], r_sp):
                continue
            lo, hi = int(self.sp_start[s]), int(self.sp_start[s + 1])
            rows = np.arange(lo, hi)
            stats.touch_rows(rows, meta.page_rows)
            scores = self.x[lo:hi] @ q
            stats.candidates += hi - lo
            top_s, top_r = sc.topk_merge(top_s, top_r, scores, rows, k, xp=np)
            if self._condition_a(top_s[k - 1], q_l2sq, c):
                stats.stopped_by = "A"
                break
        else:
            stats.stopped_by = "B"
        valid = top_r >= 0
        ids = np.where(valid, self.ids[np.maximum(top_r, 0)], -1)
        return ids, np.where(valid, top_s, -np.inf), stats

    # -- Algorithm 1: MIP-Search-I (incremental NN baseline) ----------------
    def search_incremental(self, q: np.ndarray, k: int = 10,
                           c: float | None = None, p: float | None = None):
        """Faithful Algorithm 1: incremental NN in projected space with
        per-point Condition A/B tests. Used to reproduce the paper's claim
        that Quick-Probe avoids its per-point testing cost."""
        meta = self.meta
        c = meta.c if c is None else c
        p = meta.p if p is None else p
        x_p = self._x_p(p)
        stats = HostStats()
        q = np.asarray(q, np.float32)
        q_l2sq = float(q @ q)
        q_proj = q @ self.proj
        d2 = ((self.p - q_proj[None, :]) ** 2).sum(axis=1)
        order = np.argsort(d2, kind="stable")  # idealized incremental NN

        top_s = np.full(k, -np.inf)
        top_r = np.full(k, -1, np.int64)
        for i, row in enumerate(order):
            # fetching the point (projected for the test + original for the
            # inner product) touches its page
            stats.touch_rows(np.asarray([row]), meta.page_rows)
            s = float(self.x[row] @ q)
            stats.candidates += 1
            if s > top_s[k - 1]:
                j = int(np.searchsorted(-top_s, -s))
                top_s = np.insert(top_s, j, s)[:k]
                top_r = np.insert(top_r, j, row)[:k]
            if self._condition_a(top_s[k - 1], q_l2sq, c):
                stats.stopped_by = "A"
                break
            if self._condition_b(float(d2[row]), top_s[k - 1], q_l2sq, c, x_p):
                stats.stopped_by = "B"
                break
        valid = top_r >= 0
        ids = np.where(valid, self.ids[np.maximum(top_r, 0)], -1)
        return ids, np.where(valid, top_s, -np.inf), stats

    # -- B+-tree accounting helper ------------------------------------------
    def btree_key_windows(self, q: np.ndarray, radius: float):
        """Key windows the B+-tree descent would touch (index-page metric)."""
        return ring_key_range(self.layout, q @ self.proj, radius)
