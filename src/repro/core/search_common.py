"""Backend-neutral ProMIPS search math — the single source of truth.

Every stopping condition, radius formula and merge rule the three search
paths share (``HostSearcher`` on numpy, ``search_batch`` and
``search_batch_progressive`` on jnp) lives here exactly once, parameterized
over the array namespace ``xp`` (``numpy`` or ``jax.numpy``). The functions
are pure elementwise/broadcastable arithmetic, so the SAME code path traces
under jit and executes eagerly on host — the numpy-vs-jnp agreement test in
``tests/test_search_runtime.py`` asserts bit-for-bit f32 equality.

Paper mapping (arXiv:2104.04406):
  condition_a / condition_a_threshold   Theorem 1 (deterministic stop)
  condition_b_denominator / condition_b Theorem 2, Formula 2/3
  compensation_radius                   Algorithm 3 line 12 (range r')
  adaptive_radii                        beyond-paper per-sub-partition radii
                                        (Theorem 2 applied with the LOCAL
                                        max norm; see DESIGN.md §4)
  sphere_select                         sub-partition sphere-overlap filter
  topk_merge                            running c-k-AMIP top-k merge
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Scores below this are treated as "no candidate yet" when clamping the
# Condition-B denominator (matches the device paths' -inf guard).
MIN_SCORE = -1e30

# Hand-picked default for the fused drivers' dense-path threshold: unions
# covering at least this fraction of all blocks take the dense in-place
# tile. Promoted to a `RuntimeConfig` field (PR 8) so the offline tuner
# (`repro.tune`) can override it per shape without monkeypatching; this
# module-level value is the fallback when no tuned entry exists.
DENSE_FRAC = 0.9


def next_pow2(t: int) -> int:
    """Shared jit-shape-bucketing quantizer: the fused verification tiles
    (`search_fused`), the streaming segment over-fetch (`runtime`) and the
    snapshot delta-prefix (`stream/mutable.py`) all use it, keeping the
    compiled-shape strategy in one place."""
    return 1 << max(0, int(t) - 1).bit_length()


def condition_a_threshold(max_l2sq, q_l2sq, c: float):
    """Condition A rewritten as a threshold on the inner product itself:

        ||o_M||^2 + ||q||^2 - 2<o,q>/c <= 0   <=>   <o,q> >= c/2 (||o_M||^2 + ||q||^2)

    The device paths compare the running k-th best against this constant.
    """
    return 0.5 * c * (max_l2sq + q_l2sq)


def condition_a(best_ip, max_l2sq, q_l2sq, c: float):
    """Theorem 1 test. True => terminate, result is exact-guaranteed."""
    return max_l2sq + q_l2sq - 2.0 * best_ip / c <= 0.0


def condition_b_denominator(best_ip, max_l2sq, q_l2sq, c: float, xp=jnp):
    """||o_M||^2 + ||q||^2 - 2<o_max,q>/c (the Formula 2 denominator).

    ``best_ip`` is clamped to ``MIN_SCORE`` so an empty running top-k
    (-inf sentinel) yields a huge-but-finite denominator.
    """
    return max_l2sq + q_l2sq - 2.0 * xp.maximum(best_ip, MIN_SCORE) / c


def condition_b(proj_dist_sq, best_ip, max_l2sq, q_l2sq, c: float, x_p, xp=jnp):
    """Theorem 2 test via the static threshold x_p = Psi_m^{-1}(p).

    Psi_m(t) >= p  <=>  t >= x_p (Psi_m is monotone). A non-positive
    denominator is exactly Condition A — already guaranteed.
    """
    denom = condition_b_denominator(best_ip, max_l2sq, q_l2sq, c, xp=xp)
    return (denom <= 0.0) | (proj_dist_sq >= x_p * denom)


def compensation_radius(best_ip, max_l2sq, q_l2sq, c: float, x_p, xp=jnp):
    """r' = sqrt(x_p * (||o_M||^2 + ||q||^2 - 2<o_max,q>/c)).

    The Algorithm 3 expanded range when the Quick-Probe radius failed
    Condition B. Non-positive denominators (Condition A territory) map to 0.
    """
    denom = condition_b_denominator(best_ip, max_l2sq, q_l2sq, c, xp=xp)
    return xp.sqrt(xp.maximum(x_p * denom, 0.0))


def adaptive_radii(local_max_l2sq, best_ip, q_l2sq, c: float, x_p,
                   cs_prune: bool = False, xp=jnp):
    """Beyond-paper norm-adaptive Condition-B radii (DESIGN.md §4).

    Theorem 2's denominator upper-bounds ||o*||^2 by the GLOBAL max norm
    ||o_M||^2; but if o* lives in a region (sub-partition / block) with max
    norm M_loc, searching that region out to

        r_loc = sqrt(x_p * (M_loc^2 + ||q||^2 - 2 best_ip / c))

    preserves P[miss] <= 1-p by the identical argument (the bound is applied
    in the one region that actually contains o*). ``local_max_l2sq`` may be
    a scalar or a vector of per-region max squared norms.

    With ``cs_prune``, regions where even Cauchy-Schwarz's best case
    M_loc * ||q|| cannot beat the running k-th score get radius -1
    (deterministically deselected: such a region can contain neither o* nor
    a top-k improver).
    """
    denom = condition_b_denominator(best_ip, local_max_l2sq, q_l2sq, c, xp=xp)
    r = xp.sqrt(xp.maximum(x_p * denom, 0.0))
    if cs_prune:
        ok = xp.sqrt(local_max_l2sq) * xp.sqrt(q_l2sq) >= best_ip
        r = xp.where(ok, r, -1.0)
    return r


def sphere_select(center_dist, region_radius, radius):
    """Sphere-overlap filter: does the search ball of ``radius`` intersect a
    region at center distance ``center_dist`` with radius ``region_radius``?
    Entries with radius < 0 deselect the region outright (CS pruning)."""
    return (center_dist <= radius + region_radius) & (radius >= 0.0)


def gap_select(gap, radius):
    """`sphere_select` with a precomputed surface gap = center_dist - region_radius."""
    return (gap <= radius) & (radius >= 0.0)


def block_valid_from_ids(ids, page_rows: int, xp=jnp):
    """(NB,) bool: does block b hold at least one real (non-padding) row?

    Derived from ids rather than stored so tombstoning/sharding layers that
    rewrite ids (padding rows carry -1) stay consistent automatically.
    """
    nb = ids.shape[0] // page_rows
    return xp.any(ids.reshape(nb, page_rows) >= 0, axis=1)


def sketch_margin(queries, sk_err, eps: float, xp=jnp):
    """(B, NB) sketch error band: bnd = eps * ||q|| * err_b.

    Paired with est[b_q, b] = <q, mu~_b> (`kernels.ops.sketch_scores`), at
    eps = 1 every valid row o_r of block b satisfies
    <q, o_r> in [est - bnd, est + bnd] (Cauchy-Schwarz on
    ||o_r - mu~_b|| <= err_b); eps < 1 shrinks the interval as a calibrated
    tightness knob (DESIGN.md §13).
    """
    q_norm = xp.sqrt(xp.sum(queries * queries, axis=1))
    return eps * q_norm[:, None] * sk_err[None, :]


def sketch_survivors_round1(mask, est, bnd, bvalid, k: int, xp=jnp):
    """Round-1 survivor rule: keep candidate blocks whose upper bound clears
    a per-query threshold tau <= (kth-largest lower bound over candidates).

    tau comes from G = min(2k, NB) strided groups: the kth-largest per-group
    max of lb. The top-k group maxes are k DISTINCT lb entries all >= tau, so
    tau lower-bounds the true kth-largest lb — pruning ub < tau is therefore
    lossless at eps = 1 (every pruned block's rows score strictly below k
    candidate rows that survive). Group-max instead of lax.top_k because XLA
    CPU's top_k with dead indices is pathologically slow (~30x).

    When NB < G (tiny index) or fewer than k groups hold a candidate, tau
    degrades to -inf and nothing is pruned — k >= n_alive stays exact.
    """
    nb = est.shape[1]
    g = min(2 * k, nb)
    cand = mask & bvalid[None, :]
    if g < k:
        return cand
    lb = xp.where(cand, est - bnd, -xp.inf)
    pad = (-nb) % g
    if pad:
        fill = xp.full(lb.shape[:1] + (pad,), -xp.inf, lb.dtype)
        lb = xp.concatenate([lb, fill], axis=1)
    gm = xp.max(lb.reshape(lb.shape[0], -1, g), axis=1)
    tau = xp.sort(gm, axis=1)[:, g - k]
    return cand & (est + bnd >= tau[:, None])


def sketch_survivors_round2(mask, est, bnd, bvalid, s_k, xp=jnp):
    """Compensation-round survivor rule: after round 1 the running kth score
    s_k is a realized lower bound, so any block whose upper bound est + bnd
    falls below it cannot improve the top-k. Lossless at eps = 1; queries
    with an empty top-k carry s_k = -inf and keep everything.
    """
    return mask & bvalid[None, :] & (est + bnd >= s_k[:, None])


def topk_merge(top_scores, top_rows, scores, rows, k: int, xp=jnp):
    """Merge new (scores, rows) candidates into a running descending top-k.

    Ties break toward the earlier entry (carried-in top first, then new rows
    in order) on BOTH backends: numpy uses a stable descending argsort,
    jax.lax.top_k picks the lowest index among equals — so host and device
    produce identical ranked ids, and the device hot loop keeps a top-k
    selection instead of a full sort.
    """
    s = xp.concatenate([top_scores, scores])
    r = xp.concatenate([top_rows, rows])
    if xp is np:
        idx = np.argsort(-s, kind="stable")[:k]
        return s[idx], r[idx]
    import jax

    best, idx = jax.lax.top_k(s, k)
    return best, r[idx]
