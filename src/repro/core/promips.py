"""High-level ProMIPS API.

>>> idx = ProMIPS.build(x, c=0.9, p=0.5)
>>> ids, scores, stats = idx.search(queries, k=10)            # device mode
>>> ids, scores, stats = idx.search_host(q, k=10)             # paper-faithful
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from .index import IndexArrays, IndexMeta, ProMIPSIndex, build_index
from .search_device import search_batch, search_batch_progressive
from .search_host import HostSearcher, HostStats


class ProMIPS:
    """Owns one built index; exposes device-mode and host-mode search."""

    def __init__(self, index: ProMIPSIndex):
        self.index = index
        self._host: Optional[HostSearcher] = None
        self._device_arrays: Optional[IndexArrays] = None

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, x: np.ndarray, **kwargs) -> "ProMIPS":
        return cls(build_index(x, **kwargs))

    @property
    def meta(self) -> IndexMeta:
        return self.index.meta

    @property
    def arrays(self) -> IndexArrays:
        if self._device_arrays is None:
            self._device_arrays = jax.tree.map(jax.numpy.asarray, self.index.arrays)
        return self._device_arrays

    # -- search -------------------------------------------------------------
    def search(self, queries: np.ndarray, k: int = 10,
               budget: Optional[int] = None, budget2: Optional[int] = None,
               norm_adaptive: bool = False, cs_prune: bool = False):
        """Batched device-mode c-k-AMIP search. queries: (B, d)."""
        meta = self.meta
        if budget is None:
            budget = meta.n_blocks
        if budget2 is None:
            budget2 = meta.n_blocks
        budget = int(min(budget, meta.n_blocks))
        budget2 = int(min(budget2, meta.n_blocks))
        q = jax.numpy.asarray(np.atleast_2d(queries), jax.numpy.float32)
        return search_batch(self.arrays, meta, q, k=k, budget=budget, budget2=budget2,
                            norm_adaptive=norm_adaptive, cs_prune=cs_prune)

    def search_progressive(self, queries: np.ndarray, k: int = 10,
                           budget: Optional[int] = None, cs_prune: bool = True):
        """Beyond-paper progressive device search (norm-adaptive frontier)."""
        meta = self.meta
        if budget is None:
            budget = meta.n_blocks
        budget = int(min(budget, meta.n_blocks))
        q = jax.numpy.asarray(np.atleast_2d(queries), jax.numpy.float32)
        return search_batch_progressive(self.arrays, meta, q, k=k, budget=budget,
                                        cs_prune=cs_prune)

    def search_host_progressive(self, q: np.ndarray, k: int = 10,
                                c: float | None = None, p: float | None = None,
                                cs_prune: bool = True):
        if self._host is None:
            self._host = HostSearcher(self.index)
        return self._host.search_progressive(q, k=k, c=c, p=p, cs_prune=cs_prune)

    def search_host(self, q: np.ndarray, k: int = 10, c: float | None = None,
                    p: float | None = None, norm_adaptive: bool = False,
                    cs_prune: bool = False):
        """Paper-faithful single-query search (Algorithms 2+3)."""
        if self._host is None:
            self._host = HostSearcher(self.index)
        return self._host.search(q, k=k, c=c, p=p, norm_adaptive=norm_adaptive,
                                 cs_prune=cs_prune)

    def search_incremental(self, q: np.ndarray, k: int = 10,
                           c: float | None = None, p: float | None = None):
        """Paper's Algorithm 1 (MIP-Search-I) baseline."""
        if self._host is None:
            self._host = HostSearcher(self.index)
        return self._host.search_incremental(q, k=k, c=c, p=p)


__all__ = ["ProMIPS", "ProMIPSIndex", "IndexArrays", "IndexMeta", "HostStats"]
