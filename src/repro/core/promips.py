"""High-level ProMIPS API.

Preferred entry point — the unified facade (`repro.api`, DESIGN.md §9),
which derives m / radii / budgets from the declarative (c, p0, k) contract
and gives you save/load plus every other backend behind one interface:

>>> from repro import api
>>> s = api.build(x, backend="promips",
...               guarantee=api.GuaranteeConfig(c=0.9, p0=0.5, k=10))
>>> res = s.search(queries)         # SearchResult(ids, scores, stats)
>>> s.save("idx"); s2 = api.load("idx")   # bit-identical round trip

Legacy direct handle (kept working, same engine underneath):

>>> idx = ProMIPS.build(x, c=0.9, p=0.5)
>>> ids, scores, stats = idx.search(queries, k=10)            # device mode
>>> ids, scores, stats = idx.search_host(q, k=10)             # paper-faithful
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from .index import IndexArrays, IndexMeta, ProMIPSIndex, build_index
from .runtime import RuntimeConfig
from .runtime import search as runtime_search
from .search_host import HostSearcher, HostStats


class ProMIPS:
    """Owns one built index; exposes device-mode and host-mode search."""

    def __init__(self, index: ProMIPSIndex):
        self.index = index
        self._host: Optional[HostSearcher] = None
        self._device_arrays: Optional[IndexArrays] = None

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, x: np.ndarray, *, seed: int = 0, **kwargs) -> "ProMIPS":
        """Build the index. ``seed`` is threaded explicitly through
        `build_index` -> `build_idistance` -> `kmeans_np` (and the projection
        draw), so the same rows + seed give a bit-identical index — the
        contract streaming compaction relies on for reproducible rebuilds."""
        return cls(build_index(x, seed=seed, **kwargs))

    @property
    def meta(self) -> IndexMeta:
        return self.index.meta

    @property
    def arrays(self) -> IndexArrays:
        if self._device_arrays is None:
            self._device_arrays = jax.tree.map(jax.numpy.asarray, self.index.arrays)
        return self._device_arrays

    # -- search (device paths route through the unified runtime) ------------
    def search(self, queries: np.ndarray, k: int = 10,
               budget: Optional[int] = None, budget2: Optional[int] = None,
               norm_adaptive: bool = False, cs_prune: bool = False,
               verification: str = "fused", prefilter: bool = False,
               prefilter_eps: float = 1.0, obs: bool = False,
               dense_frac: Optional[float] = None,
               tile_cap: Optional[int] = None):
        """Batched device-mode c-k-AMIP search. queries: (B, d).

        ``verification`` picks the candidate-scoring backend ("fused" =
        block-sparse rounds over the `kernels/block_mips` kernel with
        pow2-bucketed tiles — host-orchestrated eagerly, the in-graph
        `core/search_graph.py` driver under jit/shard_map, "batched" = one
        full-tile Pallas
        matmul per round over the unioned block selection, "scan" = legacy
        per-query lax.scan). "fused" and "batched" are bit-identical at
        every budget and identical to "scan" at the default full budget; a
        finite ``budget`` caps the shared union tile under "fused"/"batched"
        vs each query's own selection under "scan". ``obs=True`` records
        per-phase spans + metrics for this call (DESIGN.md §14); results are
        bit-identical either way.
        """
        cfg = RuntimeConfig(k=k, budget=budget, budget2=budget2,
                            mode="two_phase", verification=verification,
                            norm_adaptive=norm_adaptive, cs_prune=cs_prune,
                            prefilter=prefilter, prefilter_eps=prefilter_eps,
                            obs=obs, dense_frac=dense_frac, tile_cap=tile_cap)
        return runtime_search(self.arrays, self.meta, queries, cfg)

    def search_progressive(self, queries: np.ndarray, k: int = 10,
                           budget: Optional[int] = None, cs_prune: bool = True):
        """Beyond-paper progressive device search (norm-adaptive frontier)."""
        cfg = RuntimeConfig(k=k, budget=budget, mode="progressive",
                            cs_prune=cs_prune)
        return runtime_search(self.arrays, self.meta, queries, cfg)

    def search_host_progressive(self, q: np.ndarray, k: int = 10,
                                c: float | None = None, p: float | None = None,
                                cs_prune: bool = True):
        if self._host is None:
            self._host = HostSearcher(self.index)
        return self._host.search_progressive(q, k=k, c=c, p=p, cs_prune=cs_prune)

    def search_host(self, q: np.ndarray, k: int = 10, c: float | None = None,
                    p: float | None = None, norm_adaptive: bool = False,
                    cs_prune: bool = False):
        """Paper-faithful single-query search (Algorithms 2+3)."""
        if self._host is None:
            self._host = HostSearcher(self.index)
        return self._host.search(q, k=k, c=c, p=p, norm_adaptive=norm_adaptive,
                                 cs_prune=cs_prune)

    def search_incremental(self, q: np.ndarray, k: int = 10,
                           c: float | None = None, p: float | None = None):
        """Paper's Algorithm 1 (MIP-Search-I) baseline."""
        if self._host is None:
            self._host = HostSearcher(self.index)
        return self._host.search_incremental(q, k=k, c=c, p=p)


__all__ = ["ProMIPS", "ProMIPSIndex", "IndexArrays", "IndexMeta", "HostStats"]
