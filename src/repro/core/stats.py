"""The one normalized search-accounting contract (DESIGN.md §9).

Every stats type in the repo — `SearchStats` (device), `HostStats` (host),
`StreamStats` (segments), `ShardedStats` (fan-out) — implements `to_dict()`
by calling :func:`stats_totals`, so the keys `repro.api.SearchResult.stats`
carries are defined in exactly one place (`repro/api/types.STAT_KEYS` names
them plus the facade-stamped ``wall_time_s``).

Being the single choke point also makes it the one feed into the metrics
registry (DESIGN.md §14): when `repro.obs.metrics` is enabled, every batch's
pages/candidates/exhausted/queries totals land in the ``search.*`` counters;
disabled, the feed is one bool check.
"""
from __future__ import annotations

import numpy as np

from ..obs import metrics as _metrics


def stats_totals(pages, candidates, exhausted, queries=None) -> dict:
    """Batch totals as python ints. Accepts per-query arrays (device paths)
    or scalars (single-query host path — ``queries`` is then 1). Callers
    whose totals are pre-aggregated (`ShardedStats`) pass ``queries``
    explicitly so both the dict and the metrics feed stay accurate."""
    pages = np.asarray(pages)
    totals = {
        "pages": int(pages.sum()),
        "candidates": int(np.asarray(candidates).sum()),
        "exhausted": int(np.asarray(exhausted).sum()),
        "queries": int(pages.size) if queries is None else int(queries),
    }
    _metrics.observe_search(totals)
    return totals


__all__ = ["stats_totals"]
