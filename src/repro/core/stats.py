"""The one normalized search-accounting contract (DESIGN.md §9).

Every stats type in the repo — `SearchStats` (device), `HostStats` (host),
`StreamStats` (segments), `ShardedStats` (fan-out) — implements `to_dict()`
by calling :func:`stats_totals`, so the keys `repro.api.SearchResult.stats`
carries are defined in exactly one place (`repro/api/types.STAT_KEYS` names
them plus the facade-stamped ``wall_time_s``).
"""
from __future__ import annotations

import numpy as np


def stats_totals(pages, candidates, exhausted) -> dict:
    """Batch totals as python ints. Accepts per-query arrays (device paths)
    or scalars (single-query host path — ``queries`` is then 1)."""
    pages = np.asarray(pages)
    return {
        "pages": int(pages.sum()),
        "candidates": int(np.asarray(candidates).sum()),
        "exhausted": int(np.asarray(exhausted).sum()),
        "queries": int(pages.size),
    }


__all__ = ["stats_totals"]
