"""Block-level quantized sketch for the verification prefilter (DESIGN.md §13).

The sketch summarizes every data block (page) of the padded corpus by the
centroid of its valid rows, PQ-encodes the centroids so the whole summary
stays VMEM-resident, and records a per-block reconstruction-error radius.
At query time the decoded centroids give an estimated block score

    est_b = <q, mu~_b>          (mu~_b = PQ-decoded block centroid)

and, because every valid row o_r of block b satisfies
||o_r - mu~_b|| <= err_b, Cauchy-Schwarz bounds the true row scores:

    <q, o_r>  in  [est_b - ||q||*err_b,  est_b + ||q||*err_b].

Scaling the radius by a calibration knob eps in (0, 1] trades guaranteed
losslessness (eps = 1) for tighter pruning; see
``search_common.sketch_survivors_round1`` for the survivor rule and the
soundness argument.

The PQ train/assign/decode helpers here are the single implementation shared
with ``baselines/pq.py`` (which historically carried its own copy of the
loop): train per-subspace codebooks with ``kmeans_np(seed + s)``, zero-pad
each codebook to the full codeword count, then assign against the PADDED
codebook — the padding order matters for bit-compatibility with existing
baseline results (an all-zero codeword can win an assignment; that only
inflates ``err`` and never breaks the bound, since err is measured against
the actually-decoded centroids).
"""
from __future__ import annotations

import numpy as np

from .idistance import _pairwise_d2, kmeans_np


def pick_subspaces(d: int, target: int = 16) -> int:
    """Largest divisor of ``d`` that is <= ``target`` (PQ needs sub_d * M = d)."""
    for m in range(min(target, d), 0, -1):
        if d % m == 0:
            return m
    return 1


def pq_train(train: np.ndarray, n_subspaces: int, n_codewords: int, *,
             iters: int = 8, seed: int = 0) -> np.ndarray:
    """Per-subspace k-means codebooks, zero-padded to ``n_codewords`` rows.

    Returns (n_subspaces, n_codewords, sub_d) float32. Subspace ``s`` trains
    with ``seed + s`` — the exact loop ``PQBased.build`` always ran.
    """
    train = np.asarray(train, np.float32)
    d = train.shape[1]
    if d % n_subspaces:
        raise ValueError(f"d={d} not divisible by n_subspaces={n_subspaces}")
    sub_d = d // n_subspaces
    codebooks = np.zeros((n_subspaces, n_codewords, sub_d), np.float32)
    for s in range(n_subspaces):
        sl = slice(s * sub_d, (s + 1) * sub_d)
        cb, _ = kmeans_np(train[:, sl], min(n_codewords, len(train)),
                          iters=iters, seed=seed + s)
        codebooks[s, :cb.shape[0]] = cb
    return codebooks


def pq_assign(x: np.ndarray, codebooks: np.ndarray) -> np.ndarray:
    """Nearest-codeword assignment against the (padded) codebooks.

    Returns (n, n_subspaces) int32 codes.
    """
    x = np.asarray(x, np.float32)
    n_subspaces, _, sub_d = codebooks.shape
    codes = np.zeros((x.shape[0], n_subspaces), np.int32)
    for s in range(n_subspaces):
        sl = slice(s * sub_d, (s + 1) * sub_d)
        codes[:, s] = _pairwise_d2(x[:, sl], codebooks[s]).argmin(1)
    return codes


def pq_decode(codebooks: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """Decode (n, M) codes back to (n, d) float32 vectors."""
    n_subspaces = codebooks.shape[0]
    return np.concatenate(
        [codebooks[s][codes[:, s]] for s in range(n_subspaces)], axis=1)


def build_block_sketch(x_pad: np.ndarray, ids: np.ndarray, page_rows: int,
                       n_subspaces: int, n_codewords: int, seed: int = 0):
    """Build the per-block sketch over the padded/permuted corpus.

    Returns ``(sk_mu, sk_codebooks, sk_codes, sk_err)``:
      sk_mu        (n_blocks, d)                decoded centroids (what the
                                                query actually scores against;
                                                persisted decoded so scoring
                                                is one matmul, not gathers)
      sk_codebooks (n_subspaces, n_codewords, sub_d)
      sk_codes     (n_blocks, n_subspaces) int32
      sk_err       (n_blocks,)                  max_{valid r in b} ||o_r - mu~_b||

    Padding rows (ids < 0) are excluded from both the centroid mean and the
    error radius; a fully-padded block gets mu = 0, err = 0 and is dropped at
    query time by the derived block-validity mask, never by the sketch bound.
    """
    x = np.asarray(x_pad, np.float32)
    ids = np.asarray(ids)
    n_pad, d = x.shape
    nb = n_pad // page_rows
    xb = x.reshape(nb, page_rows, d)
    vb = (ids >= 0).reshape(nb, page_rows)
    cnt = np.maximum(vb.sum(1), 1)[:, None]
    mu = ((xb * vb[:, :, None]).sum(1) / cnt).astype(np.float32)
    codebooks = pq_train(mu, n_subspaces, n_codewords, iters=8, seed=seed)
    codes = pq_assign(mu, codebooks)
    mu_hat = pq_decode(codebooks, codes)
    diff = xb - mu_hat[:, None, :]
    dist = np.where(vb, np.sqrt((diff * diff).sum(-1)), 0.0)
    err = dist.max(1).astype(np.float32)
    return mu_hat, codebooks, codes, err
