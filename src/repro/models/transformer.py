"""Model assembly for the assigned-architecture pool.

One config-driven implementation with three entry points:

  loss_fn(params, cfg, batch)            — training loss (+ aux metrics)
  prefill(params, cfg, batch, cache_len) — build KV/state cache, last logits
  decode_step(params, cfg, cache, token) — one-token decode

Block patterns: "attn" (dense/MoE/GQA/SWA/qk-norm), "xlstm_7_1",
"zamba2" (Mamba2 + shared attention block), "encdec" (whisper).
Layers are stacked on a leading axis and executed with lax.scan (compact
HLO for the 512-device dry-run); remat policy is configurable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import moe as moe_lib
from . import ssm as ssm_lib
from . import xlstm as xlstm_lib
from .layers import dense_init, init_mlp, apply_mlp, rms_norm

Params = Dict[str, Any]

# Unroll switch lives in scan_util (shared by attention/ssm/xlstm inner
# scans); see that module for why (roofline FLOP accounting).
from .scan_util import scan as _scan  # noqa: E402


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block_attn(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_lib.init_attention(k1, cfg, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _stack_init(fn, key, n, *args):
    return jax.vmap(lambda k: fn(k, *args))(jax.random.split(key, n))


def init_params(key, cfg, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 8)
    vp = cfg.vocab_padded
    params: Params = {
        "embed": dense_init(keys[0], (vp, cfg.d_model), scale=0.02, dtype=dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (cfg.d_model, vp), dtype=dtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = dense_init(keys[2], (cfg.d_model, cfg.d_model), dtype=dtype)

    if cfg.block_pattern == "attn":
        params["blocks"] = _stack_init(_init_block_attn, keys[3], cfg.n_layers, cfg, dtype)
    elif cfg.block_pattern == "xlstm_7_1":
        n_groups = cfg.n_layers // 8
        params["mlstm"] = jax.vmap(
            lambda k: _stack_init(xlstm_lib.init_mlstm, k, 7, cfg, dtype)
        )(jax.random.split(keys[3], n_groups))
        params["slstm"] = _stack_init(xlstm_lib.init_slstm, keys[4], n_groups, cfg, dtype)
        params["ln_m"] = jnp.ones((n_groups, 7, cfg.d_model), dtype)
        params["ln_s"] = jnp.ones((n_groups, cfg.d_model), dtype)
    elif cfg.block_pattern == "zamba2":
        every = cfg.shared_attn_every
        n_groups, rem = cfg.n_layers // every, cfg.n_layers % every
        params["mamba"] = jax.vmap(
            lambda k: _stack_init(ssm_lib.init_mamba, k, every, cfg, dtype)
        )(jax.random.split(keys[3], n_groups))
        params["mamba_ln"] = jnp.ones((n_groups, every, cfg.d_model), dtype)
        if rem:
            params["mamba_tail"] = _stack_init(ssm_lib.init_mamba, keys[4], rem, cfg, dtype)
            params["mamba_tail_ln"] = jnp.ones((rem, cfg.d_model), dtype)
        params["shared"] = _init_block_attn(keys[5], cfg, dtype)
    elif cfg.block_pattern == "encdec":
        params["enc_blocks"] = _stack_init(_init_block_attn, keys[3], cfg.enc_layers, cfg, dtype)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)

        def _init_dec(k, cfg, dtype):
            k1, k2 = jax.random.split(k)
            p = _init_block_attn(k1, cfg, dtype)
            p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
            p["xattn"] = attn_lib.init_cross_attention(k2, cfg, dtype)
            return p

        params["blocks"] = _stack_init(_init_dec, keys[4], cfg.n_layers, cfg, dtype)
    else:
        raise ValueError(cfg.block_pattern)
    return params


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _logits(params, cfg, x):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def cross_entropy(logits, labels):
    """Stable CE with label -1 = ignore. logits (…,V) f32, labels (…,)."""
    valid = labels >= 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - ll, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def _maybe_remat(fn, policy: Optional[str]):
    if policy in (None, "none"):
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    raise ValueError(policy)


# ---------------------------------------------------------------------------
# forward passes per pattern
# ---------------------------------------------------------------------------

def _attn_backbone(params, cfg, x, positions, *, remat="full", bidirectional=False,
                   collect_kv=False, blocks_key="blocks"):
    """Scan over homogeneous attention blocks. Returns (x, aux, kv?)."""

    def block(carry, lp):
        x, aux = carry
        h, kv = attn_lib.attention_train(
            lp["attn"], cfg, rms_norm(x, lp["ln1"], cfg.norm_eps), positions,
            bidirectional=bidirectional,
        )
        x = x + h
        if cfg.moe is not None and "moe" in lp:
            h, a = moe_lib.apply_moe(lp["moe"], cfg, rms_norm(x, lp["ln2"], cfg.norm_eps),
                                     capacity_factor=cfg.moe.capacity_factor)
            aux = aux + a
        else:
            h = apply_mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        x = x + h
        out = kv if collect_kv else None
        return (x, aux), out

    (x, aux), kvs = _scan(_maybe_remat(block, remat), (x, jnp.float32(0.0)),
                                 params[blocks_key])
    return x, aux, kvs


def _xlstm_backbone(params, cfg, x, *, remat="full", states=None, collect_states=False):
    n_groups = cfg.n_layers // 8

    def group(carry, gp):
        x, _ = carry

        def mblock(carry2, lp):
            h, _ = xlstm_lib.mlstm_chunked(
                lp["p"], cfg, rms_norm(carry2, lp["ln"], cfg.norm_eps))
            return carry2 + h, None

        x, _ = _scan(mblock, x, {"p": gp["mlstm"], "ln": gp["ln_m"]})
        h, _ = xlstm_lib.slstm_scan(gp["slstm"], cfg, rms_norm(x, gp["ln_s"], cfg.norm_eps))
        return (x + h, jnp.float32(0.0)), None

    stacked = {"mlstm": params["mlstm"], "slstm": params["slstm"],
               "ln_m": params["ln_m"], "ln_s": params["ln_s"]}
    (x, _), _ = _scan(_maybe_remat(group, remat), (x, jnp.float32(0.0)), stacked)
    return x


def _zamba_backbone(params, cfg, x, positions, *, remat="full"):
    every = cfg.shared_attn_every

    def group(carry, gp):
        x, aux = carry

        def mblock(c, lp):
            h, _ = ssm_lib.mamba_chunked(lp["p"], cfg, rms_norm(c, lp["ln"], cfg.norm_eps))
            return c + h, None

        x, _ = _scan(mblock, x, {"p": gp["mamba"], "ln": gp["ln"]})
        sp = params["shared"]
        h, _ = attn_lib.attention_train(sp["attn"], cfg,
                                        rms_norm(x, sp["ln1"], cfg.norm_eps), positions)
        x = x + h
        x = x + apply_mlp(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
        return (x, aux), None

    stacked = {"mamba": params["mamba"], "ln": params["mamba_ln"]}
    (x, aux), _ = _scan(_maybe_remat(group, remat), (x, jnp.float32(0.0)), stacked)
    if "mamba_tail" in params:
        def tail(c, lp):
            h, _ = ssm_lib.mamba_chunked(lp["p"], cfg, rms_norm(c, lp["ln"], cfg.norm_eps))
            return c + h, None
        x, _ = _scan(tail, x, {"p": params["mamba_tail"], "ln": params["mamba_tail_ln"]})
    return x


def _embed_inputs(params, cfg, batch):
    """Token embedding + modality-stub prepend. Returns (x, label_offset)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    offset = 0
    if cfg.frontend == "vision":
        patches = batch["patches"] @ params["frontend_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        offset = cfg.frontend_len
    return x, offset


# ---------------------------------------------------------------------------
# public: training loss
# ---------------------------------------------------------------------------

def loss_fn(params, cfg, batch, *, remat: str = "full"):
    """batch: tokens (B,S) int32, labels (B,S) int32 (-1 ignored);
    + patches (B,F,d) for vlm; + frames (B,F,d) for audio enc-dec."""
    if cfg.block_pattern == "encdec":
        return _loss_encdec(params, cfg, batch, remat=remat)
    x, offset = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    aux = jnp.float32(0.0)
    if cfg.block_pattern == "attn":
        x, aux, _ = _attn_backbone(params, cfg, x, positions, remat=remat)
    elif cfg.block_pattern == "xlstm_7_1":
        x = _xlstm_backbone(params, cfg, x, remat=remat)
    elif cfg.block_pattern == "zamba2":
        x = _zamba_backbone(params, cfg, x, positions, remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if offset:
        x = x[:, offset:]
    logits = _logits(params, cfg, x)
    loss = cross_entropy(logits, batch["labels"])
    return loss + 0.01 * aux, {"loss": loss, "aux": aux}


def _loss_encdec(params, cfg, batch, *, remat="full"):
    frames = batch["frames"] @ params["frontend_proj"]
    b, f, _ = frames.shape
    enc_pos = jnp.broadcast_to(jnp.arange(f), (b, f))
    enc, _, _ = _attn_backbone(params, cfg, frames, enc_pos, remat=remat,
                               bidirectional=True, blocks_key="enc_blocks")
    enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps)
    enc_len = jnp.full((b,), f, jnp.int32)

    x = params["embed"][batch["tokens"]]
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def block(carry, lp):
        x, aux = carry
        h, _ = attn_lib.attention_train(lp["attn"], cfg,
                                        rms_norm(x, lp["ln1"], cfg.norm_eps), positions)
        x = x + h
        x = x + attn_lib.cross_attention(
            lp["xattn"], cfg, rms_norm(x, lp["ln_x"], cfg.norm_eps),
            *attn_lib.encode_kv(lp["xattn"], cfg, enc), enc_len)
        x = x + apply_mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return (x, aux), None

    (x, aux), _ = _scan(_maybe_remat(block, remat), (x, jnp.float32(0.0)),
                               params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    loss = cross_entropy(_logits(params, cfg, x), batch["labels"])
    return loss, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# public: prefill + decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.float32) -> Params:
    kh, dh = cfg.n_kv_heads, cfg.head_dim_
    cache: Params = {"len": jnp.zeros((batch_size,), jnp.int32)}
    kv_len = min(max_len, cfg.window) if cfg.attn == "swa" else max_len
    if cfg.block_pattern == "attn":
        cache["k"] = jnp.zeros((cfg.n_layers, batch_size, kv_len, kh, dh), dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
    elif cfg.block_pattern == "xlstm_7_1":
        n_groups = cfg.n_layers // 8
        d, h, p = cfg.d_model, cfg.n_heads, cfg.d_model // cfg.n_heads
        cache["mlstm_c"] = jnp.zeros((n_groups, 7, batch_size, h, p, p), jnp.float32)
        cache["mlstm_n"] = jnp.zeros((n_groups, 7, batch_size, h, p), jnp.float32)
        cache["mlstm_m"] = jnp.full((n_groups, 7, batch_size, h), -jnp.inf, jnp.float32)
        cache["slstm"] = tuple(
            (jnp.full if i == 3 else jnp.zeros)((n_groups, batch_size, h, p), jnp.float32)
            if i != 3 else jnp.full((n_groups, batch_size, h, p), -jnp.inf, jnp.float32)
            for i in range(4)
        )
    elif cfg.block_pattern == "zamba2":
        every = cfg.shared_attn_every
        n_groups, rem = cfg.n_layers // every, cfg.n_layers % every
        d = cfg.d_model
        inner = cfg.ssm.expand * d
        h = inner // cfg.ssm.head_dim
        conv_c = inner + 2 * cfg.ssm.state_dim
        cache["mamba_h"] = jnp.zeros((n_groups, every, batch_size, h, cfg.ssm.head_dim,
                                      cfg.ssm.state_dim), jnp.float32)
        cache["mamba_conv"] = jnp.zeros((n_groups, every, batch_size,
                                         cfg.ssm.conv_dim - 1, conv_c), dtype)
        if rem:
            cache["tail_h"] = jnp.zeros((rem, batch_size, h, cfg.ssm.head_dim,
                                         cfg.ssm.state_dim), jnp.float32)
            cache["tail_conv"] = jnp.zeros((rem, batch_size, cfg.ssm.conv_dim - 1, conv_c), dtype)
        cache["shared_k"] = jnp.zeros((n_groups, batch_size, kv_len, kh, dh), dtype)
        cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
    elif cfg.block_pattern == "encdec":
        cache["k"] = jnp.zeros((cfg.n_layers, batch_size, kv_len, kh, dh), dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        cache["xk"] = jnp.zeros((cfg.n_layers, batch_size, cfg.frontend_len, kh, dh), dtype)
        cache["xv"] = jnp.zeros_like(cache["xk"])
        cache["enc_len"] = jnp.zeros((batch_size,), jnp.int32)
    return cache


def _write_kv(cache_k, k_new, pos):
    """Scatter one token's KV at per-sequence position. cache (B,S,KH,dh)."""
    def one(c, kn, p):
        return jax.lax.dynamic_update_slice(c, kn, (p, 0, 0))
    return jax.vmap(one)(cache_k, k_new, pos)


def decode_step(params, cfg, cache, token, *, return_hidden: bool = False):
    """token: (B, 1) int32. Returns (logits (B, vocab_padded), cache);
    with return_hidden=True returns the post-norm hidden state (B, d)
    instead of logits (the ProMIPS approximate-logits path queries the
    c-AMIP index with it — serve/engine.py)."""
    x = params["embed"][token]
    b = x.shape[0]
    new_len = cache["len"] + 1
    pos_write = new_len - 1
    if cfg.attn == "swa":
        pos_write = pos_write % cache["k"].shape[2] if "k" in cache else pos_write

    if cfg.block_pattern == "attn":
        def block(x, inputs):
            lp, kc, vc = inputs
            h_in = rms_norm(x, lp["ln1"], cfg.norm_eps)
            k_new, v_new = attn_lib.decode_kv(lp["attn"], cfg, h_in, new_len)
            kc = _write_kv(kc, k_new, pos_write)
            vc = _write_kv(vc, v_new, pos_write)
            q = (h_in @ lp["attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim_)
            if cfg.qk_norm:
                q = rms_norm(q, lp["attn"]["q_norm"], cfg.norm_eps)
            from .layers import apply_rope
            q = apply_rope(q, (new_len - 1)[:, None], cfg.rope_theta)
            att = attn_lib.flash_decode(q[:, 0], kc, vc, jnp.minimum(new_len, kc.shape[1]))
            x = x + att.reshape(b, 1, -1) @ lp["attn"]["wo"]
            if cfg.moe is not None and "moe" in lp:
                h, _ = moe_lib.apply_moe(lp["moe"], cfg, rms_norm(x, lp["ln2"], cfg.norm_eps),
                                         capacity_factor=cfg.moe.capacity_factor)
            else:
                h = apply_mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            return x + h, (kc, vc)

        x, (ks, vs) = _scan(block, x, (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs, len=new_len)
    elif cfg.block_pattern == "xlstm_7_1":
        def group(x, inputs):
            gp, c_st, n_st, m_st, sl_st = inputs

            def mblock(carry, inp):
                xx = carry
                lp, cs, ns, ms = inp
                h, (c2, n2, m2) = xlstm_lib.mlstm_step(
                    lp["p"], cfg, rms_norm(xx, lp["ln"], cfg.norm_eps), (cs, ns, ms))
                return xx + h, (c2, n2, m2)

            x, sts = _scan(mblock, x,
                                  ({"p": gp["mlstm"], "ln": gp["ln_m"]}, c_st, n_st, m_st))
            h, sl2 = xlstm_lib.slstm_step(gp["slstm"], cfg,
                                          rms_norm(x, gp["ln_s"], cfg.norm_eps), sl_st)
            return x + h, (sts, sl2)

        stacked = ({"mlstm": params["mlstm"], "slstm": params["slstm"],
                    "ln_m": params["ln_m"], "ln_s": params["ln_s"]},
                   cache["mlstm_c"], cache["mlstm_n"], cache["mlstm_m"], cache["slstm"])
        x, (msts, slst) = _scan(group, x, stacked)
        cache = dict(cache, mlstm_c=msts[0], mlstm_n=msts[1], mlstm_m=msts[2],
                     slstm=slst, len=new_len)
    elif cfg.block_pattern == "zamba2":
        sp = params["shared"]

        def group(x, inputs):
            gp, hs, convs, kc, vc = inputs

            def mblock(carry, inp):
                xx = carry
                lp, h_st, c_st = inp
                h, (h2, c2) = ssm_lib.mamba_step(
                    lp["p"], cfg, rms_norm(xx, lp["ln"], cfg.norm_eps), (h_st, c_st))
                return xx + h, (h2, c2)

            x, (h2, c2) = _scan(mblock, x,
                                       ({"p": gp["mamba"], "ln": gp["ln"]}, hs, convs))
            h_in = rms_norm(x, sp["ln1"], cfg.norm_eps)
            k_new, v_new = attn_lib.decode_kv(sp["attn"], cfg, h_in, new_len)
            kc = _write_kv(kc, k_new, pos_write)
            vc = _write_kv(vc, v_new, pos_write)
            q = (h_in @ sp["attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim_)
            from .layers import apply_rope
            q = apply_rope(q, (new_len - 1)[:, None], cfg.rope_theta)
            att = attn_lib.flash_decode(q[:, 0], kc, vc, jnp.minimum(new_len, kc.shape[1]))
            x = x + att.reshape(b, 1, -1) @ sp["attn"]["wo"]
            x = x + apply_mlp(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
            return x, (h2, c2, kc, vc)

        stacked = ({"mamba": params["mamba"], "ln": params["mamba_ln"]},
                   cache["mamba_h"], cache["mamba_conv"],
                   cache["shared_k"], cache["shared_v"])
        x, (h2, c2, ks, vs) = _scan(group, x, stacked)
        upd = dict(mamba_h=h2, mamba_conv=c2, shared_k=ks, shared_v=vs, len=new_len)
        if "tail_h" in cache:
            def tail(carry, inp):
                xx = carry
                lp, h_st, c_st = inp
                h, (h2, c2) = ssm_lib.mamba_step(
                    lp["p"], cfg, rms_norm(xx, lp["ln"], cfg.norm_eps), (h_st, c_st))
                return xx + h, (h2, c2)
            x, (th, tc) = _scan(
                tail, x, ({"p": params["mamba_tail"], "ln": params["mamba_tail_ln"]},
                          cache["tail_h"], cache["tail_conv"]))
            upd.update(tail_h=th, tail_conv=tc)
        cache = dict(cache, **upd)
    elif cfg.block_pattern == "encdec":
        def block(x, inputs):
            lp, kc, vc, xk, xv = inputs
            h_in = rms_norm(x, lp["ln1"], cfg.norm_eps)
            k_new, v_new = attn_lib.decode_kv(lp["attn"], cfg, h_in, new_len)
            kc = _write_kv(kc, k_new, pos_write)
            vc = _write_kv(vc, v_new, pos_write)
            q = (h_in @ lp["attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim_)
            from .layers import apply_rope
            q = apply_rope(q, (new_len - 1)[:, None], cfg.rope_theta)
            att = attn_lib.flash_decode(q[:, 0], kc, vc, new_len)
            x = x + att.reshape(b, 1, -1) @ lp["attn"]["wo"]
            x = x + attn_lib.cross_attention(
                lp["xattn"], cfg, rms_norm(x, lp["ln_x"], cfg.norm_eps),
                xk, xv, cache["enc_len"])
            x = x + apply_mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            return x, (kc, vc)

        x, (ks, vs) = _scan(
            block, x, (params["blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
        cache = dict(cache, k=ks, v=vs, len=new_len)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x[:, 0], cache
    return _logits(params, cfg, x)[:, 0], cache


def prefill(params, cfg, batch, max_len: int, *, remat: str = "none"):
    """Run the full prompt, build the cache, return last-position logits.

    batch: tokens (B, S) (+ patches/frames). Cache KV sized to max_len.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    dtype = params["embed"].dtype
    cache = init_cache(cfg, b, max_len, dtype)
    if cfg.block_pattern == "attn":
        x, offset = _embed_inputs(params, cfg, batch)
        st = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(st), (b, st))
        x, _, kvs = _attn_backbone(params, cfg, x, positions, remat=remat, collect_kv=True)
        ks, vs = kvs
        kv_len = cache["k"].shape[2]
        ks = ks[:, :, -kv_len:] if st > kv_len else jnp.pad(
            ks, ((0, 0), (0, 0), (0, kv_len - st), (0, 0), (0, 0)))
        vs = vs[:, :, -kv_len:] if st > kv_len else jnp.pad(
            vs, ((0, 0), (0, 0), (0, kv_len - st), (0, 0), (0, 0)))
        cache = dict(cache, k=ks.astype(dtype), v=vs.astype(dtype),
                     len=jnp.full((b,), st, jnp.int32))
    elif cfg.block_pattern == "encdec":
        frames = batch["frames"] @ params["frontend_proj"]
        f = frames.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(f), (b, f))
        enc, _, _ = _attn_backbone(params, cfg, frames, enc_pos, remat=remat,
                                   bidirectional=True, blocks_key="enc_blocks")
        enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps)
        xk = jax.vmap(lambda lp: attn_lib.encode_kv(lp["xattn"], cfg, enc)[0])(params["blocks"])
        xv = jax.vmap(lambda lp: attn_lib.encode_kv(lp["xattn"], cfg, enc)[1])(params["blocks"])
        cache = dict(cache, xk=xk.astype(dtype), xv=xv.astype(dtype),
                     enc_len=jnp.full((b,), f, jnp.int32), len=jnp.zeros((b,), jnp.int32))
        x = rms_norm(enc, params["final_norm"], cfg.norm_eps)
        return cache, _logits(params, cfg, x)[:, -1]
    else:
        # recurrent families: prefill = chunked scan re-using the train path,
        # then states are produced by stepping the last token (smoke-scale) —
        # production path would thread chunked final states; dry-run cells for
        # ssm/hybrid use decode_step which is the steady-state cost anyway.
        x, _ = _embed_inputs(params, cfg, batch)
        cache = dict(cache, len=jnp.full((b,), s, jnp.int32))
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        if cfg.block_pattern == "xlstm_7_1":
            x = _xlstm_backbone(params, cfg, x, remat=remat)
        else:
            x = _zamba_backbone(params, cfg, x, positions, remat=remat)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return cache, _logits(params, cfg, x)[:, -1]
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return cache, _logits(params, cfg, x)[:, 0]
