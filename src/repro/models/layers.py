"""Shared neural building blocks (pure-JAX, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM inits)."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * weight).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * weight + bias).astype(dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, dh), positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    return jax.nn.gelu(x @ w_in + b_in, approximate=True) @ w_out + b_out


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def apply_mlp(params, x):
    return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])
