"""GQA attention: blockwise-causal training path (flash-style lax.scan, no
S x S materialisation), sliding-window support, qk-norm, RoPE; decode path
against a KV cache (pure-jnp flash-decode; the Pallas `decode_attention`
kernel is the TPU production path and is numerically validated against the
same reference).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init, rms_norm
from .scan_util import scan as _scan

NEG_INF = -1e30


def init_attention(key, cfg, dtype=jnp.float32):
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], (d, h * dh), dtype=dtype),
        "wk": dense_init(ks[1], (d, kh * dh), dtype=dtype),
        "wv": dense_init(ks[2], (d, kh * dh), dtype=dtype),
        "wo": dense_init(ks[3], (h * dh, d), scale=(h * dh) ** -0.5, dtype=dtype),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((dh,), dtype)
        params["k_norm"] = jnp.ones((dh,), dtype)
    return params


def _project_qkv(params, cfg, x, positions):
    b, s, d = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, kh, dh)
    v = (x @ params["wv"]).reshape(b, s, kh, dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_for(s, block, blk_idx, *, window: int, bidirectional: bool):
    q_pos = jnp.arange(s)
    kv_pos = blk_idx * block + jnp.arange(block)
    if bidirectional:
        mask = jnp.broadcast_to(kv_pos[None, :] < s, (s, block))
    else:
        mask = kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask &= kv_pos[None, :] < s
    return mask


def _blocks(x, block):
    b, s, kh, dh = x.shape
    nblk = -(-s // block)
    sp = nblk * block
    if sp != s:
        x = jnp.pad(x, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    return jnp.moveaxis(x.reshape(b, nblk, block, kh, dh), 1, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_core(qg, k, v, window, bidirectional, block):
    out, _ = _flash_fwd_impl(qg, k, v, window, bidirectional, block)
    return out


def _flash_fwd_impl(qg, k, v, window, bidirectional, block):
    """qg: (B,S,KH,G,dh) pre-scaled f32; k,v: (B,S,KH,dh) f32.
    Online-softmax forward; returns (out (B,KH,G,S,dh), lse (B,KH,G,S))."""
    b, s, kh, g, dh = qg.shape
    qf = jnp.moveaxis(qg, 1, 3)  # (B,KH,G,S,dh)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = blk
        scores = jnp.einsum("bkgsd,btkd->bkgst", qf, k_blk)
        mask = _mask_for(s, block, blk_idx, window=window, bidirectional=bidirectional)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgst,btkd->bkgsd", p, v_blk)
        return (m_new, l_new, acc), None

    nblk = -(-s // block)
    init = (
        jnp.full((b, kh, g, s), NEG_INF, jnp.float32),
        jnp.zeros((b, kh, g, s), jnp.float32),
        jnp.zeros((b, kh, g, s, dh), jnp.float32),
    )
    (m, l, acc), _ = _scan(
        body, init, (_blocks(k, block), _blocks(v, block), jnp.arange(nblk)))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return out, lse


def _flash_fwd(qg, k, v, window, bidirectional, block):
    out, lse = _flash_fwd_impl(qg, k, v, window, bidirectional, block)
    return out, (qg, k, v, out, lse)


def _flash_bwd(window, bidirectional, block, res, d_out):
    """Flash-attention backward: recompute scores blockwise from (out, lse);
    memory is linear in S (no stacked softmax residuals — this is what keeps
    the train_4k cells inside v5e HBM, EXPERIMENTS.md §Perf iter 1)."""
    qg, k, v, out, lse = res
    b, s, kh, g, dh = qg.shape
    qf = jnp.moveaxis(qg, 1, 3)                        # (B,KH,G,S,dh)
    delta = jnp.sum(d_out * out, axis=-1)              # (B,KH,G,S)
    nblk = -(-s // block)

    def body(dq_acc, blk):
        k_blk, v_blk, blk_idx = blk
        scores = jnp.einsum("bkgsd,btkd->bkgst", qf, k_blk)
        mask = _mask_for(s, block, blk_idx, window=window, bidirectional=bidirectional)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        p = jnp.exp(scores - lse[..., None])           # (B,KH,G,S,t)
        dv_blk = jnp.einsum("bkgst,bkgsd->btkd", p, d_out)
        dp = jnp.einsum("bkgsd,btkd->bkgst", d_out, v_blk)
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + jnp.einsum("bkgst,btkd->bkgsd", ds, k_blk)
        dk_blk = jnp.einsum("bkgst,bkgsd->btkd", ds, qf)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros_like(qf)
    dq, (dk_blks, dv_blks) = _scan(
        body, dq0, (_blocks(k, block), _blocks(v, block), jnp.arange(nblk)))
    dk = jnp.moveaxis(dk_blks, 0, 1).reshape(b, nblk * block, kh, dh)[:, :s]
    dv = jnp.moveaxis(dv_blks, 0, 1).reshape(b, nblk * block, kh, dh)[:, :s]
    dq = jnp.moveaxis(dq, 3, 1)                        # back to (B,S,KH,G,dh)
    return dq, dk, dv


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def _flash_causal(q, k, v, *, window: int = 0, block: int = 512, bidirectional: bool = False):
    """Blockwise online-softmax attention. q:(B,S,H,dh) k,v:(B,S,KH,dh).

    window > 0 restricts attention to the trailing `window` positions (SWA).
    Forward and backward both stream KV blocks (custom_vjp): activation
    memory is O(S) — only (out, lse) are saved.
    """
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = dh ** -0.5
    qg = (q.reshape(b, s, kh, g, dh) * scale).astype(jnp.float32)
    out = _flash_core(qg, k.astype(jnp.float32), v.astype(jnp.float32),
                      window, bidirectional, block)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, dh).astype(q.dtype)


def attention_train(params, cfg, x, positions, *, bidirectional: bool = False):
    """Full training/prefill attention. x: (B, S, d) -> (B, S, d)."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    window = cfg.window if cfg.attn == "swa" else 0
    out = _flash_causal(q, k, v, window=window, bidirectional=bidirectional)
    b, s, _, _ = out.shape
    return out.reshape(b, s, -1) @ params["wo"], (k, v)


def flash_decode(q, k_cache, v_cache, cache_len, *, block: int = 1024):
    """One-token decode vs KV cache, pure-jnp online softmax over KV blocks.

    q: (B, H, dh); caches: (B, S, KH, dh); cache_len: (B,). Returns (B, H, dh).
    Mirrors kernels/decode_attention.py (the Pallas path).
    """
    b, h, dh = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = dh ** -0.5
    qg = q.reshape(b, kh, g, dh).astype(jnp.float32) * scale
    nblk = -(-s // block)
    sp = nblk * block
    if sp != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kb = jnp.moveaxis(k_cache.reshape(b, nblk, block, kh, dh), 1, 0).astype(jnp.float32)
    vb = jnp.moveaxis(v_cache.reshape(b, nblk, block, kh, dh), 1, 0).astype(jnp.float32)

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = blk
        pos = blk_idx * block + jnp.arange(block)
        scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_blk)
        mask = pos[None, :] < cache_len[:, None]
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        m_cur = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgt,btkd->bkgd", p, v_blk)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, kh, g), NEG_INF, jnp.float32),
        jnp.zeros((b, kh, g), jnp.float32),
        jnp.zeros((b, kh, g, dh), jnp.float32),
    )
    (m, l, acc), _ = _scan(body, init, (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, dh).astype(q.dtype)


def attention_decode(params, cfg, x, k_cache, v_cache, cache_len):
    """Single-token decode. x: (B, 1, d); caches hold previous K/V (this
    token's K/V must already be written at position cache_len - 1)."""
    b = x.shape[0]
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    positions = (cache_len - 1)[:, None]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    out = flash_decode(q.reshape(b, h, dh), k_cache, v_cache, cache_len)
    return out.reshape(b, 1, h * dh) @ params["wo"], (k_new, v_new)


def decode_kv(params, cfg, x, cache_len):
    """Project this token's K/V (for the cache write before attention)."""
    positions = (cache_len - 1)[:, None]
    _, k_new, v_new = _project_qkv(params, cfg, x, positions)
    return k_new, v_new


def init_cross_attention(key, cfg, dtype=jnp.float32):
    return init_attention(key, cfg, dtype=dtype)


def cross_attention(params, cfg, x, enc_k, enc_v, enc_len):
    """Decoder->encoder attention (whisper). x: (B, S, d); enc K/V cached."""
    b, s, d = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
    outs = []
    # loop-free: fold S into batch for flash_decode (each target position
    # attends the full encoder output — no causal structure)
    qf = q.reshape(b, s * h, dh).reshape(b, s, h, dh)
    scale = dh ** -0.5
    g = h // kh
    scores = jnp.einsum("bshd,btkd->bhst", qf.astype(jnp.float32) * scale,
                        enc_k.astype(jnp.float32).repeat(g, axis=2))
    mask = jnp.arange(enc_k.shape[1])[None, :] < enc_len[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,btkd->bshd", w, enc_v.astype(jnp.float32).repeat(g, axis=2))
    return out.reshape(b, s, h * dh).astype(x.dtype) @ params["wo"]


def encode_kv(params, cfg, enc_out):
    """Precompute encoder K/V for cross attention."""
    b, t, d = enc_out.shape
    kh, dh = cfg.n_kv_heads, cfg.head_dim_
    k = (enc_out @ params["wk"]).reshape(b, t, kh, dh)
    v = (enc_out @ params["wv"]).reshape(b, t, kh, dh)
    if cfg.qk_norm:
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return k, v
