"""Central lax.scan wrapper with a global unroll switch.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip count,
so launch/roofline.py measures small UNROLLED model variants and
extrapolates linearly in depth/microbatches. Every structural scan in the
model stack (layers, microbatches, flash-attention KV blocks, Mamba/mLSTM
chunks) routes through here; only the sLSTM time scan stays a real scan
(unrolling seq_len steps is infeasible) and gets an analytic correction in
the roofline (see launch/roofline.py::slstm_correction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

UNROLL = False


def set_unroll(value: bool) -> None:
    global UNROLL
    UNROLL = bool(value)


def scan(body, carry, xs, *, force_loop: bool = False):
    if not UNROLL or force_loop:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = None
    return carry, ys
