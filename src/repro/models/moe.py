"""Mixture-of-Experts layer: top-k softmax router + permutation-based
dispatch (sort tokens by expert, gather into (E, C, d) capacity buffers,
batched expert matmuls, scatter back). Compact HLO (sort/gather/dot/scatter)
that lowers to all-to-all under expert-parallel sharding, and FLOP-faithful
for the roofline (2 * 2 * T * topk * d * ff active FLOPs + capacity waste).

Supports shared experts (qwen2-moe: 4 shared + 60 routed) applied densely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_moe(key, cfg, dtype=jnp.float32):
    d, ff = cfg.d_model, cfg.d_ff
    e = cfg.moe.n_experts
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, e), scale=d ** -0.5, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype=dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype=dtype),
    }
    if cfg.moe.n_shared:
        sh = cfg.moe.n_shared * ff
        k1, k2, k3 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": dense_init(k1, (d, sh), dtype=dtype),
            "w_up": dense_init(k2, (d, sh), dtype=dtype),
            "w_down": dense_init(k3, (sh, d), dtype=dtype),
        }
    return params


def apply_moe(params, cfg, x, *, capacity_factor: float = 1.25):
    """x: (B, S, d) -> (B, S, d). Permutation-based top-k dispatch,
    PER SEQUENCE: the (token-slot -> expert) sort runs within each batch row,
    so with batch sharded over the data axes every sort/gather/scatter is
    local to its shard (a single global argsort forces GSPMD to replicate
    the full token stream — measured 184 s of collectives on
    moonshot train_4k; EXPERIMENTS.md §Perf iter 3). Capacity is therefore
    per-sequence: C = S * topk * cf / E."""
    b, s, d = x.shape
    e, topk = cfg.moe.n_experts, cfg.moe.top_k

    logits = (x.astype(jnp.float32) @ params["router"])            # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, choice = jax.lax.top_k(probs, topk)                      # (B, S, topk)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)   # renormalise

    # flatten (token, slot) pairs within each row and sort by expert
    flat_expert = choice.reshape(b, s * topk)
    flat_token = jnp.broadcast_to(jnp.repeat(jnp.arange(s), topk), (b, s * topk))
    flat_gate = gate.reshape(b, s * topk)
    order = jnp.argsort(flat_expert, axis=1, stable=True)          # per-row sort
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=1)
    sorted_token = jnp.take_along_axis(flat_token, order, axis=1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=1)

    # per-expert capacity: position within the expert's run (per row)
    capacity = max(1, int(capacity_factor * s * topk / e))
    pos = jnp.broadcast_to(jnp.arange(s * topk), (b, s * topk))
    run_start = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_expert)
    slot = pos - jnp.take_along_axis(run_start, sorted_expert, axis=1)
    keep = slot < capacity
    dest = jnp.where(keep, sorted_expert * capacity + slot, e * capacity)

    # gather tokens into per-row capacity buffers (trap row absorbs drops)
    def row_dispatch(xt_row, dest_row, tok_row, keep_row):
        buf = jnp.zeros((e * capacity + 1, d), xt_row.dtype)
        vals = xt_row[tok_row] * keep_row[:, None].astype(xt_row.dtype)
        return buf.at[dest_row].set(vals)[:-1]

    buf = jax.vmap(row_dispatch)(x, dest, sorted_token, keep)       # (B, E*C, d)
    buf = buf.reshape(b, e, capacity, d)

    # batched expert MLPs (B, E, C, d) x (E, d, ff): B on data, E on model
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    y = jnp.einsum("becf,efd->becd", g * u, params["w_down"])       # (B, E, C, d)

    # scatter back with gate weights (per row, local)
    def row_combine(y_row, dest_row, tok_row, keep_row, gate_row):
        y_flat = y_row.reshape(e * capacity, d)
        contrib = jnp.where(keep_row[:, None],
                            y_flat[jnp.minimum(dest_row, e * capacity - 1)], 0.0)
        out = jnp.zeros((s, d), y_row.dtype)
        return out.at[tok_row].add((contrib * gate_row[:, None]).astype(y_row.dtype))

    out = jax.vmap(row_combine)(y, dest, sorted_token, keep, sorted_gate)

    if cfg.moe.n_shared:
        sh = params["shared"]
        out = out + (jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_down"]

    # auxiliary load-balance loss (Switch-style), returned for the trainer
    density = jnp.mean(jax.nn.one_hot(choice[..., 0], e), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * router_prob)
    return out, aux
