"""Mamba2-style selective state-space block (SSD), chunked for training and
single-step for decode. Faithful to the block structure (in_proj -> short
depthwise conv -> per-head scalar decay a = exp(-softplus(A) dt) -> state
update h = a h + dt x B^T -> y = C h + D x -> gated out_proj); the chunked
scan replaces the authors' fused CUDA kernel (DESIGN.md §10).

State: (B, H, P, N) with P = head dim, N = cfg.ssm.state_dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm
from .scan_util import scan as _scan


def _dims(cfg):
    d = cfg.d_model
    inner = cfg.ssm.expand * d
    p = cfg.ssm.head_dim
    h = inner // p
    n = cfg.ssm.state_dim
    return d, inner, h, p, n


def init_mamba(key, cfg, dtype=jnp.float32):
    d, inner, h, p, n = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        # order: [z(inner) | x(inner) | B(n) | C(n) | dt(h)]
        "in_proj": dense_init(ks[0], (d, 2 * inner + 2 * n + h), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm.conv_dim, inner + 2 * n), scale=0.3, dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((inner,), dtype),
        "out_proj": dense_init(ks[2], (inner, d), dtype=dtype),
    }


def _split_proj(cfg, proj):
    d, inner, h, p, n = _dims(cfg)
    z = proj[..., :inner]
    xbc = proj[..., inner:2 * inner + 2 * n]
    dt = proj[..., 2 * inner + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_state=None):
    """Short depthwise causal conv. xbc: (B, S, Cd); conv_w: (K, Cd)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state  # (B, K-1, Cd)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(out), new_state


def mamba_chunked(params, cfg, x, *, chunk: int = 256):
    """Training/prefill pass. x: (B, S, d) -> (B, S, d), final state."""
    d, inner, h, p, n = _dims(cfg)
    b, s, _ = x.shape
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"])
    xs = xbc[..., :inner].reshape(b, s, h, p)
    bmat = xbc[..., inner:inner + n]
    cmat = xbc[..., inner + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = jnp.exp(-jnp.exp(params["a_log"])[None, None] * dt)           # (B,S,H) decay in (0,1)

    nchunk = -(-s // chunk)
    sp = nchunk * chunk
    if sp != s:
        pad = lambda t: jnp.pad(t, ((0, 0), (0, sp - s)) + ((0, 0),) * (t.ndim - 2))
        xs, bmat, cmat, dt, a = map(pad, (xs, bmat, cmat, dt, a))
    xs = xs.reshape(b, nchunk, chunk, h, p)
    bmat = bmat.reshape(b, nchunk, chunk, n)
    cmat = cmat.reshape(b, nchunk, chunk, n)
    dt = dt.reshape(b, nchunk, chunk, h)
    a = a.reshape(b, nchunk, chunk, h)

    log_a = jnp.log(jnp.maximum(a, 1e-20))
    cum = jnp.cumsum(log_a, axis=2)                                   # (B,NC,L,H)

    def body(hstate, blk):
        xs_c, b_c, c_c, dt_c, cum_c, la_c = blk
        # hstate: (B, H, P, N)
        total = cum_c[:, -1]                                          # (B,H)
        # inter-chunk: y_inter[t] = C_t . (decay(0..t) * h_in)
        decay_in = jnp.exp(cum_c)                                     # (B,L,H)
        y_inter = jnp.einsum("bln,bhpn,blh->blhp", c_c, hstate, decay_in)
        # intra-chunk: causal kernel G[t,s] = exp(cum[t]-cum[s]) dt[s]
        rel = cum_c[:, :, None, :] - cum_c[:, None, :, :]             # (B,L,L,H)
        causal = jnp.tril(jnp.ones((rel.shape[1], rel.shape[1]), bool))
        g = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0) * dt_c[:, None]
        scores = jnp.einsum("bln,bmn->blm", c_c, b_c)                 # (B,L,L)
        y_intra = jnp.einsum("blm,blmh,bmhp->blhp", scores, g, xs_c)
        # state update: h_out = decay_total h_in + sum_s decay(s..end) dt_s x_s b_s^T
        decay_out = jnp.exp(total[:, None] - cum_c)                   # (B,L,H)
        dx = dt_c[..., None] * xs_c                                   # (B,L,H,P)
        h_new = jnp.exp(total)[..., None, None] * hstate + jnp.einsum(
            "blh,blhp,bln->bhpn", decay_out, dx, b_c
        )
        return h_new, y_inter + y_intra

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    blks = tuple(jnp.moveaxis(t, 1, 0) for t in (xs, bmat, cmat, dt, cum, log_a))
    h_fin, ys = _scan(body, h0, blks)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, h, p)[:, :s]
    y = y + params["d_skip"][None, None, :, None] * xs.reshape(b, sp, h, p)[:, :s]
    y = y.reshape(b, s, inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], (h_fin, conv_state)


def mamba_step(params, cfg, x, state):
    """Decode step. x: (B, 1, d); state = (h (B,H,P,N), conv (B,K-1,Cd))."""
    d, inner, h, p, n = _dims(cfg)
    b = x.shape[0]
    hstate, conv_state = state
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], conv_state)
    xbc = xbc[:, 0]
    xs = xbc[..., :inner].reshape(b, h, p)
    bmat = xbc[..., inner:inner + n]
    cmat = xbc[..., inner + n:]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(params["a_log"])[None] * dt1)
    h_new = a[..., None, None] * hstate + jnp.einsum(
        "bh,bhp,bn->bhpn", dt1, xs.astype(jnp.float32), bmat.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", cmat.astype(jnp.float32), h_new)
    y = y + params["d_skip"][None, :, None] * xs
    y = y.reshape(b, 1, inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], (h_new, conv_state)
