"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix-memory, chunk-parallel)
and sLSTM (scalar-memory, inherently sequential — recurrent R weights).

mLSTM state: C (B, H, P, P) matrix memory + n (B, H, P) normalizer, with
exponential input gate and sigmoid forget gate (stabilised in log space).
Chunked scan mirrors ssm.mamba_chunked; the 7:1 mLSTM:sLSTM stacking of the
1.3B model comes from configs (block_pattern="xlstm_7_1").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm
from .scan_util import scan as _scan


def _dims(cfg):
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    return d, h, p


def init_mlstm(key, cfg, dtype=jnp.float32):
    d, h, p = _dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d), dtype=dtype),      # [x_in | gate]
        "wq": dense_init(ks[1], (d, d), dtype=dtype),
        "wk": dense_init(ks[2], (d, d), dtype=dtype),
        "wv": dense_init(ks[3], (d, d), dtype=dtype),
        "w_if": dense_init(ks[4], (d, 2 * h), scale=d ** -0.5, dtype=jnp.float32),
        "if_bias": jnp.concatenate([jnp.zeros(h), 3.0 + jnp.arange(h, dtype=jnp.float32) * 0.5 / max(h - 1, 1)]),
        "norm": jnp.ones((d,), dtype),
        "w_down": dense_init(ks[5], (d, d), dtype=dtype),
    }


def mlstm_chunked(params, cfg, x, *, chunk: int = 256):
    """Training pass. x: (B, S, d) -> (B, S, d), final (C, n, m) state."""
    d, h, p = _dims(cfg)
    b, s, _ = x.shape
    up = x @ params["w_up"]
    x_in, gate = jnp.split(up, 2, axis=-1)
    q = (x_in @ params["wq"]).reshape(b, s, h, p) * (p ** -0.5)
    k = (x_in @ params["wk"]).reshape(b, s, h, p) * (p ** -0.5)
    v = (x_in @ params["wv"]).reshape(b, s, h, p)
    if_pre = x.astype(jnp.float32) @ params["w_if"] + params["if_bias"]
    log_i = if_pre[..., :h]                              # (B,S,H) exp input gate
    log_f = -jax.nn.softplus(-if_pre[..., h:])           # log sigmoid forget

    nchunk = -(-s // chunk)
    sp = nchunk * chunk
    if sp != s:
        pad = lambda t: jnp.pad(t, ((0, 0), (0, sp - s)) + ((0, 0),) * (t.ndim - 2))
        q, k, v, log_i, log_f = map(pad, (q, k, v, log_i, log_f))
    rs = lambda t: jnp.moveaxis(t.reshape(b, nchunk, chunk, *t.shape[2:]), 1, 0)
    qc, kc, vc, lic, lfc = map(rs, (q, k, v, log_i, log_f))

    def body(carry, blk):
        c_st, n_st, m_st = carry      # (B,H,P,P), (B,H,P), (B,H)
        q_c, k_c, v_c, li_c, lf_c = blk
        cumf = jnp.cumsum(lf_c, axis=1)                          # (B,L,H)
        # stabiliser: running max of (cumf + m_in) vs intra log weights
        log_in = cumf + m_st[:, None]                            # decay applied to carry-in
        intra = cumf[:, :, None, :] - cumf[:, None, :, :] + li_c[:, None, :, :]
        causal = jnp.tril(jnp.ones((intra.shape[1], intra.shape[1]), bool))
        intra = jnp.where(causal[None, :, :, None], intra, -jnp.inf)
        m_new = jnp.maximum(log_in, jnp.max(intra, axis=2))      # (B,L,H)
        # inter-chunk contribution
        y_inter = jnp.einsum("blhp,bhpr,blh->blhr", q_c, c_st,
                             jnp.exp(log_in - m_new))
        n_inter = jnp.einsum("blhp,bhp,blh->blh", q_c, n_st, jnp.exp(log_in - m_new))
        # intra-chunk
        w = jnp.exp(intra - m_new[:, :, None, :])                # (B,L,L,H)
        scores = jnp.einsum("blhp,bmhp->blmh", q_c, k_c) * w
        y_intra = jnp.einsum("blmh,bmhr->blhr", scores, v_c)
        n_intra = jnp.sum(scores, axis=2)                        # (B,L,H)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_new))
        y = (y_inter + y_intra) / denom[..., None]
        # state to next chunk
        tot = cumf[:, -1]                                        # (B,H)
        m_out = jnp.maximum(tot + m_st, jnp.max(tot[:, None] - cumf + li_c, axis=1))
        decay_out = jnp.exp(tot[:, None] - cumf + li_c - m_out[:, None])  # (B,L,H)
        c_new = jnp.exp(tot + m_st - m_out)[..., None, None] * c_st + jnp.einsum(
            "blh,blhp,blhr->bhpr", decay_out, k_c, v_c
        )
        n_new = jnp.exp(tot + m_st - m_out)[..., None] * n_st + jnp.einsum(
            "blh,blhp->bhp", decay_out, k_c
        )
        return (c_new, n_new, m_out), y

    c0 = jnp.zeros((b, h, p, p), jnp.float32)
    n0 = jnp.zeros((b, h, p), jnp.float32)
    m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    (c_f, n_f, m_f), ys = _scan(body, (c0, n0, m0), (qc, kc, vc, lic, lfc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, d)[:, :s].astype(x.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(gate)
    return y @ params["w_down"], (c_f, n_f, m_f)


def mlstm_step(params, cfg, x, state):
    """Decode step. x: (B, 1, d); state (C, n, m)."""
    d, h, p = _dims(cfg)
    b = x.shape[0]
    c_st, n_st, m_st = state
    up = x @ params["w_up"]
    x_in, gate = jnp.split(up, 2, axis=-1)
    q = (x_in[:, 0] @ params["wq"]).reshape(b, h, p) * (p ** -0.5)
    k = (x_in[:, 0] @ params["wk"]).reshape(b, h, p) * (p ** -0.5)
    v = (x_in[:, 0] @ params["wv"]).reshape(b, h, p)
    if_pre = x[:, 0].astype(jnp.float32) @ params["w_if"] + params["if_bias"]
    log_i, log_f = if_pre[..., :h], -jax.nn.softplus(-if_pre[..., h:])
    m_new = jnp.maximum(log_f + m_st, log_i)
    f_ = jnp.exp(log_f + m_st - m_new)
    i_ = jnp.exp(log_i - m_new)
    c_new = f_[..., None, None] * c_st + i_[..., None, None] * jnp.einsum("bhp,bhr->bhpr", k, v)
    n_new = f_[..., None] * n_st + i_[..., None] * k
    num = jnp.einsum("bhp,bhpr->bhr", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n_new)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, d).astype(x.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(gate)
    return y @ params["w_down"], (c_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM: scalar memory with recurrent weights — sequential by construction.
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype=jnp.float32):
    d, h, p = _dims(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype=dtype),      # z,i,f,o pre-acts
        "r_gates": dense_init(ks[1], (h, p, 4 * p), scale=p ** -0.5, dtype=dtype),
        "bias": jnp.concatenate([jnp.zeros(2 * d), jnp.ones(d), jnp.zeros(d)]).astype(jnp.float32),
        "norm": jnp.ones((d,), dtype),
        "w_down": dense_init(ks[2], (d, d), dtype=dtype),
    }


def _slstm_cell(params, cfg, wx_t, state):
    """One step. wx_t: (B, 4d) input pre-activations; state (h,c,n,m)."""
    d, h, p = _dims(cfg)
    h_prev, c_prev, n_prev, m_prev = state
    rh = jnp.einsum("bhp,hpr->bhr", h_prev, params["r_gates"])       # (B,H,4P)
    pre = wx_t.reshape(-1, h, 4 * p) + rh + params["bias"].reshape(h, 4 * p)
    z, i_raw, f_raw, o = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    log_f = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(log_f + m_prev, i_raw)
    i_ = jnp.exp(i_raw - m_new)
    f_ = jnp.exp(log_f + m_prev - m_new)
    c_new = f_ * c_prev + i_ * z
    n_new = f_ * n_prev + i_
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new.astype(wx_t.dtype), c_new, n_new, m_new)


def slstm_scan(params, cfg, x):
    """Training pass (sequential lax.scan over time). x: (B,S,d)."""
    d, h, p = _dims(cfg)
    b, s, _ = x.shape
    wx = x @ params["w_gates"]                                       # (B,S,4d)
    state = (
        jnp.zeros((b, h, p), x.dtype),
        jnp.zeros((b, h, p), jnp.float32),
        jnp.zeros((b, h, p), jnp.float32),
        jnp.full((b, h, p), -jnp.inf, jnp.float32),
    )

    def body(st, wx_t):
        st = _slstm_cell(params, cfg, wx_t, st)
        return st, st[0]

    state, hs = _scan(body, state, jnp.moveaxis(wx, 1, 0), force_loop=True)
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return y @ params["w_down"], state


def slstm_step(params, cfg, x, state):
    """Decode step. x: (B, 1, d)."""
    d, h, p = _dims(cfg)
    b = x.shape[0]
    wx = (x[:, 0] @ params["w_gates"])
    state = _slstm_cell(params, cfg, wx, state)
    y = state[0].reshape(b, 1, d)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return y @ params["w_down"], state
