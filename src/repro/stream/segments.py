"""Segment primitives for the mutable ProMIPS index (DESIGN.md §8).

A streaming index is (base segment, delta segment, tombstones):

  base   — one immutable `core/index.py` build product. Row-indexed state
           (the tombstone bitmap) addresses the base's padded sorted layout.
  delta  — an append-only buffer of raw rows: preallocated host arrays plus
           a fill watermark (``count``). Delta rows are NOT projected into
           the iDistance layout; they are scored exactly at search time via
           the same `kernels/ops.mips_score` verification kernel the batched
           two-phase runtime uses, so no probability-guarantee bookkeeping
           is needed for them.
  tombstones — boolean "alive" bitmaps over both segments. A deleted (or
           updated-away) row stays physically present until compaction; its
           score is masked to -inf at rescore time.

`Snapshot` freezes one `(base, delta_watermark, tombstone_epoch)` triple as
device arrays with STATIC shapes (full delta capacity + a dynamic validity
mask), so every epoch reuses one compiled search graph and in-flight
searches are immune to concurrent writers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

from ..core.index import IndexArrays, IndexMeta
from ..core.search_device import SearchStats


class StreamStats(NamedTuple):
    """Per-query stats for a segment-merged search."""

    pages: np.ndarray       # logical pages: base two-phase + delta sweep
    candidates: np.ndarray  # verified rows: base candidates + live delta rows
    exhausted: np.ndarray   # base budget exhausted (delta is always exact)
    base: SearchStats       # untouched stats of the base two-phase search

    def to_dict(self) -> dict:
        """Normalized accounting (`core/stats.stats_totals` contract)."""
        from ..core.stats import stats_totals
        return stats_totals(self.pages, self.candidates, self.exhausted)


class DeltaSegment:
    """Append-only row buffer: preallocated arrays + fill watermark.

    Slots [0, count) are filled; `alive` marks which of them still count
    (an updated/deleted delta row is tombstoned in place, not reclaimed —
    reclamation is compaction's job).
    """

    def __init__(self, capacity: int, d: int):
        self.capacity = int(capacity)
        self.d = int(d)
        self.x = np.zeros((self.capacity, d), np.float32)
        self.gids = np.full(self.capacity, -1, np.int64)
        self.alive = np.zeros(self.capacity, bool)
        self.count = 0  # fill watermark

    @property
    def full(self) -> bool:
        return self.count >= self.capacity

    @property
    def n_alive(self) -> int:
        return int(self.alive[: self.count].sum())

    def append(self, gids: np.ndarray, rows: np.ndarray) -> np.ndarray:
        """Bulk append; returns the slots written. Caller checks capacity."""
        n = len(gids)
        if self.count + n > self.capacity:
            raise ValueError(
                f"delta segment full: {self.count}+{n} > {self.capacity} "
                "(compact first or grow delta_capacity)")
        slots = np.arange(self.count, self.count + n)
        self.x[slots] = rows
        self.gids[slots] = gids
        self.alive[slots] = True
        self.count += n
        return slots

    def survivors(self):
        """(gids, rows) of live delta entries, in append order."""
        live = np.nonzero(self.alive[: self.count])[0]
        return self.gids[live], self.x[live]


@dataclass(frozen=True)
class Snapshot:
    """One consistent, device-resident view of the mutable index.

    Searches launched against a snapshot keep returning answers for its
    epoch even while writers append / tombstone / compact — writers never
    mutate a published snapshot's arrays.
    """

    arrays: IndexArrays      # base segment (device), ids already GLOBAL
    meta: IndexMeta
    base_alive: object       # (n_pad,) bool — False = tombstoned/padding
    delta_x: object          # (cap, d) f32 — full capacity, static shape
    delta_gids: object       # (cap,) int32 — -1 for unfilled/invalid
    delta_valid: object      # (cap,) bool — below watermark AND alive
    epoch: int               # tombstone/write epoch this snapshot froze
    delta_count: int         # fill watermark at freeze time
    n_base_dead: int         # base tombstones at freeze time (over-fetch k)
    clean: bool = field(default=False)  # no tombstones, empty delta


__all__ = ["DeltaSegment", "Snapshot", "StreamStats"]
