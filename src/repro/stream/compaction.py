"""Compaction: fold delta + tombstones back into a fresh immutable base.

The rebuild reuses `core/index.build_index` verbatim over the SURVIVING rows
in canonical (ascending global id) order, with the stream's stored build
kwargs — including the explicit seed — so a compacted base is bit-identical
to a cold `build_index` over the same rows (the determinism + parity tests
in tests/test_stream.py assert this).

`Compactor` runs the rebuild on a background thread, off the search path:
the stream is only locked twice — a freeze (copy out survivors + open the
op log) and an install (swap the base, reset the delta, replay the ops that
arrived while the rebuild ran). Searches keep hitting the old snapshot the
whole time; writers never block on the k-means.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.index import ProMIPSIndex, build_index
from ..obs import metrics as _metrics
from ..robust.faultpoints import fault


@dataclass(frozen=True)
class CompactionConfig:
    """Trigger math (DESIGN.md §8): compact once the churn fraction
    (delta watermark + base tombstones, over base size + delta watermark)
    exceeds ``threshold``. The O(n log n) rebuild is then amortized over at
    least ``threshold/(1-threshold) * n`` absorbed writes.

    Failure policy (DESIGN.md §16): a failed background rebuild is retried
    up to ``max_retries`` times with exponential backoff
    (``backoff_s * backoff_mult**attempt``, plus deterministic seeded jitter
    up to ``jitter`` of the delay) before latching the error for `join()`.
    Transient faults (an OOM'd k-means, a blip in the allocator) heal
    without wedging the stream; the freeze is reused across retries, so the
    op log keeps absorbing writes throughout."""

    threshold: float = 0.3
    max_retries: int = 0
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    jitter: float = 0.25


def rebuild_base(gids: np.ndarray, rows: np.ndarray, build_kwargs: dict) -> ProMIPSIndex:
    """Fresh base over the surviving rows, ids stamped GLOBAL.

    Rows are sorted into ascending-gid canonical order first, so any two
    rebuilds over the same surviving set (in any presentation order) are
    bit-identical.
    """
    fault.at("compaction.rebuild")
    order = np.argsort(gids, kind="stable")
    g = np.asarray(gids)[order]
    idx = build_index(np.ascontiguousarray(rows[order], np.float32), **build_kwargs)
    local = idx.arrays.ids
    global_ids = np.where(local >= 0, g[np.maximum(local, 0)], -1).astype(np.int32)
    return ProMIPSIndex(arrays=idx.arrays._replace(ids=global_ids),
                        meta=idx.meta, layout=idx.layout)


class Compactor:
    """Background-compaction driver for one `MutableProMIPS`."""

    def __init__(self, cfg: CompactionConfig = CompactionConfig()):
        self.cfg = cfg
        self._thread: Optional[threading.Thread] = None
        self._join_lock = threading.Lock()   # serializes concurrent joiners
        self.runs = 0
        self.failures = 0                    # rebuild attempts that raised
        self.retries = 0                     # failures that were retried
        self.error: Optional[BaseException] = None
        self.last_error: Optional[str] = None  # survives join() for health()

    @property
    def in_flight(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def maybe_trigger(self, stream) -> bool:
        """Start a background rebuild if churn crossed the threshold. A
        stored failure disables auto-retriggering (one failing O(n log n)
        rebuild per write would be a storm) until `join()` surfaces and
        clears the error."""
        if (self.in_flight or self.error is not None
                or stream.churn_fraction <= self.cfg.threshold):
            return False
        self.start(stream)
        return True

    def start(self, stream) -> None:
        if self.in_flight:
            raise RuntimeError("compaction already in flight")
        gids, rows = stream._freeze_for_compaction()
        if len(gids) == 0:
            # fully-tombstoned stream: no survivors to rebuild a base from.
            # Close the op log and keep the tombstoned base — searches mask
            # every dead row, so skipping the rebuild is invisible.
            stream._abandon_compaction()
            return

        self.error = None

        cfg = self.cfg
        # deterministic jitter: seeded off the rebuild seed + run count so
        # two replicas don't thundering-herd, yet a test run is reproducible
        jit = np.random.RandomState(
            (int(stream.build_kwargs.get("seed", 0)) + self.runs) & 0x7FFFFFFF)

        def run():
            for attempt in range(cfg.max_retries + 1):
                try:
                    new_base = rebuild_base(gids, rows, stream.build_kwargs)
                    stream._install_compacted(new_base)
                    self.runs += 1
                    return
                except BaseException as e:  # noqa: BLE001 — must not wedge the stream
                    self.failures += 1
                    self.last_error = f"{type(e).__name__}: {e}"
                    if _metrics.enabled():
                        _metrics.counter("stream.compaction_errors").inc()
                    if attempt < cfg.max_retries:
                        self.retries += 1
                        if _metrics.enabled():
                            _metrics.counter("stream.compaction_retries").inc()
                        delay = cfg.backoff_s * cfg.backoff_mult ** attempt
                        time.sleep(delay * (1.0 + cfg.jitter * jit.rand()))
                        continue
                    # retries exhausted: the freeze only COPIED state and ops
                    # were applied live, so abandoning = closing the op log;
                    # writes stay intact and the next trigger retries. The
                    # error latches and surfaces on join().
                    self.error = e
                    stream._abandon_compaction()

        self._thread = threading.Thread(target=run, name="promips-compaction",
                                        daemon=True)
        self._thread.start()

    def status(self) -> dict:
        """Snapshot for `engine.health()` / `maintenance_status()` — the
        latched error is surfaced (not cleared; `join()` clears), and
        ``last_error`` persists even after a successful retry so operators
        can see a flapping rebuild."""
        return {"in_flight": self.in_flight, "runs": self.runs,
                "failures": self.failures, "retries": self.retries,
                "error_latched": self.error is not None,
                "last_error": self.last_error}

    def join(self, timeout: Optional[float] = None) -> None:
        """Safe under concurrent callers (e.g. two writers both waiting on a
        full delta): the thread handle is snapshotted under a lock."""
        with self._join_lock:
            t = self._thread
            if t is not None:
                t.join(timeout)
                if t.is_alive():
                    raise TimeoutError("compaction did not finish in time")
                self._thread = None
            if self.error is not None:
                err, self.error = self.error, None
                raise RuntimeError("background compaction failed") from err


__all__ = ["CompactionConfig", "Compactor", "rebuild_base"]
