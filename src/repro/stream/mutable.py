"""MutableProMIPS: a ProMIPS index that absorbs inserts/updates/deletes.

Layout (DESIGN.md §8): one immutable BASE segment (a `build_index` product
whose ids are stamped GLOBAL and whose probability guarantees are untouched)
plus an append-only DELTA segment of raw rows scored exactly at search time,
plus tombstone bitmaps over both. Searches run against an epoch-versioned
`Snapshot`; writers mutate host state under a lock and bump the epoch, so an
in-flight search never observes a half-applied write. Past a configurable
churn fraction, compaction rebuilds the base off the search path (seeded,
deterministic) and atomically swaps it in.

>>> st = MutableProMIPS(x, m=8, seed=0)
>>> st.insert(new_ids, new_rows)        # exact-scored from the next search
>>> st.delete(stale_ids)                # masked to -inf from the next search
>>> ids, scores, stats = st.search(queries, k=10)
>>> st.compact()                        # fold delta+tombstones into the base
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.index import IndexArrays, IndexMeta, ProMIPSIndex
from ..core.runtime import RuntimeConfig, next_pow2, search_segments
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from ..obs.trace import span as _span
from .compaction import CompactionConfig, Compactor, rebuild_base
from .segments import DeltaSegment, Snapshot


class MutableProMIPS:
    """Mutable index = base segment + delta segment + tombstones."""

    def __init__(self, x: np.ndarray, ids: Optional[np.ndarray] = None, *,
                 delta_capacity: Optional[int] = None,
                 compaction: CompactionConfig = CompactionConfig(),
                 auto_compact: bool = False,
                 **build_kwargs):
        """``build_kwargs`` go to `core/index.build_index` verbatim (m, c, p,
        page_bytes, seed, ...) and are REUSED by every compaction rebuild —
        pass an explicit ``seed`` for reproducible rebuilds (default 0)."""
        x = np.ascontiguousarray(x, np.float32)
        n, d = x.shape
        gids = (np.arange(n, dtype=np.int64) if ids is None
                else np.asarray(ids, np.int64))
        self._check_gids(gids)
        build_kwargs.setdefault("seed", 0)
        self.build_kwargs = dict(build_kwargs)
        self.d = d
        self._lock = threading.RLock()
        self._oplog: Optional[list] = None   # open while a rebuild is in flight
        self._defer_trigger = False          # True inside update()'s two halves
        self._init_wal_state()
        self._delta_capacity = (int(delta_capacity) if delta_capacity
                                else max(64, n // 2))
        self._set_base(rebuild_base(gids, x, self.build_kwargs))
        self._reset_delta()
        self._epoch = 0
        self._snap: Optional[Snapshot] = None
        self._next_id = int(gids.max()) + 1 if n else 0
        self.compactor = Compactor(compaction) if auto_compact else None

    # -- state plumbing ------------------------------------------------------
    def _set_base(self, base: ProMIPSIndex) -> None:
        self._base = base
        self._base_dev = None                     # device copy built lazily
        self._base_alive = base.arrays.ids >= 0   # (n_pad,) — padding is dead
        self._n_base_dead = 0
        self._row_of = {int(g): r for r, g in enumerate(base.arrays.ids) if g >= 0}

    def _reset_delta(self) -> None:
        self._delta = DeltaSegment(self._delta_capacity, self.d)
        self._slot_of: dict[int, int] = {}

    @property
    def meta(self) -> IndexMeta:
        return self._base.meta

    @property
    def n_alive(self) -> int:
        return (self.meta.n - self._n_base_dead) + self._delta.n_alive

    @property
    def delta_capacity(self) -> int:
        return self._delta.capacity

    @property
    def delta_fraction(self) -> float:
        """Live delta rows over live rows — what the search path pays extra."""
        return self._delta.n_alive / max(1, self.n_alive)

    @property
    def churn_fraction(self) -> float:
        """Absorbed writes over base size — the compaction trigger metric
        (counts tombstoned delta slots too: they cost buffer space and the
        base tombstones cost over-fetch, and only compaction reclaims them)."""
        return ((self._delta.count + self._n_base_dead)
                / max(1, self.meta.n + self._delta.count))

    def alive_items(self) -> tuple[np.ndarray, np.ndarray]:
        """(gids, rows) of every live row — base survivors, then live delta
        entries in append order. The supported way to enumerate live rows
        (the exact-search oracle in tests, the example's catalog dump, and
        compaction's freeze all use it)."""
        with self._lock:
            live = np.nonzero(self._base_alive)[0]
            bg = self._base.arrays.ids[live].astype(np.int64)
            bx = self._base.arrays.x[live]
            dg, dx = self._delta.survivors()
            return np.concatenate([bg, dg]), np.concatenate([bx, dx])

    def _is_alive(self, gid: int) -> bool:
        slot = self._slot_of.get(gid)
        if slot is not None and self._delta.alive[slot]:
            return True
        row = self._row_of.get(gid)
        return row is not None and bool(self._base_alive[row])

    def _log(self, op) -> None:
        if self._oplog is not None:
            self._oplog.append(op)

    def _dirty(self) -> None:
        self._epoch += 1
        self._snap = None
        if (self.compactor is not None and self._oplog is None
                and not self._defer_trigger and not self._wal_replaying):
            self.compactor.maybe_trigger(self)

    # -- durability (robust/wal.py, DESIGN.md §16) ---------------------------
    def _init_wal_state(self) -> None:
        self._wal = None             # attached WriteAheadLog, if any
        self._wal_seq = 0            # seq of the last record durably logged
        self._wal_floor = 0          # seq baked into the last snapshot
        self._wal_suspended = False  # True while replaying the compaction
        #                              op log (those ops were already logged
        #                              live the first time)
        self._wal_replaying = False  # True during crash-recovery replay:
        #                              nothing is re-logged and auto-compaction
        #                              must not fire (replay drives compaction
        #                              from the recorded markers instead)

    def attach_wal(self, wal) -> None:
        """Bind a `robust.WriteAheadLog`; every subsequent acknowledged
        mutation is logged BEFORE it is applied."""
        with self._lock:
            self._wal = wal

    def wal_lag(self) -> int:
        """Records logged since the snapshot this stream was restored from
        (0 when no WAL is attached) — what replay would have to redo."""
        with self._lock:
            return self._wal_seq - self._wal_floor if self._wal is not None else 0

    def mark_wal_floor(self) -> None:
        """Called by checkpoint after a snapshot lands: replay skips
        everything at or below the current seq."""
        with self._lock:
            self._wal_floor = self._wal_seq

    def _wal_append(self, op: str, gids=None, rows=None) -> None:
        # Log-before-apply at the exact point the mutation begins. The seq
        # is bumped only AFTER the append succeeds, so a failed write (disk
        # error, injected fault) rejects the op cleanly without burning a
        # sequence number.
        if (self._wal is None or self._wal_suspended
                or self._wal_replaying):
            return
        self._wal.append(self._wal_seq + 1, op, gids, rows)
        self._wal_seq += 1

    # -- writes --------------------------------------------------------------
    @staticmethod
    def _check_gids(gids: np.ndarray) -> None:
        if len(np.unique(gids)) != len(gids):
            raise ValueError("duplicate ids within one call")
        if len(gids) and (gids.min() < 0 or gids.max() >= 2 ** 31):
            raise ValueError("ids must fit int32 (device arrays are int32)")

    def insert(self, ids, rows, _wait_ok: bool = True) -> None:
        """Append new rows. ids must not be alive (use `update` to replace).

        If the delta is full while a background rebuild is in flight, the
        rebuild is already reclaiming the space — the writer waits for the
        install (outside the lock) and retries instead of failing.
        ``_wait_ok=False`` (internal, used under update()'s lock where
        waiting would deadlock against the install) falls back to raising.
        """
        gids = np.atleast_1d(np.asarray(ids, np.int64))
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        self._check_gids(gids)
        if rows.shape != (len(gids), self.d):
            raise ValueError(f"rows must be ({len(gids)}, {self.d}), "
                             f"got {rows.shape}")
        if len(gids) > self._delta.capacity:
            raise ValueError(f"batch of {len(gids)} rows exceeds delta "
                             f"capacity {self._delta.capacity}")
        retried = False
        while True:
            with self._lock:
                for g in gids:
                    if self._is_alive(int(g)):
                        raise ValueError(f"id {int(g)} already alive; use update()")
                full = self._delta.count + len(gids) > self._delta.capacity
                if not full or self._oplog is None:
                    if full:
                        self.compact()
                    # logged AFTER any self-compaction (whose begin/commit
                    # markers precede this record) and BEFORE the append, so
                    # replay sees the exact live op order
                    self._wal_append("insert", gids, rows)
                    slots = self._delta.append(gids, rows)
                    for g, s in zip(gids, slots):
                        self._slot_of[int(g)] = int(s)
                    self._next_id = max(self._next_id, int(gids.max()) + 1)
                    self._log(("insert", gids.copy(), rows.copy()))
                    self._dirty()
                    if _metrics.enabled():
                        _metrics.counter("stream.delta_appends").inc(len(gids))
                    return
            if not _wait_ok or self.compactor is None:
                raise RuntimeError("delta full while compaction in flight")
            if self.compactor.in_flight:
                self.compactor.join()   # install/abandon closes the op log
            elif retried:
                # op log open with no rebuild to wait for: wedged (external
                # Compactor misuse) — raising beats spinning. The extra retry
                # covers an install landing between the lock and this check.
                raise RuntimeError("delta full while compaction in flight")
            retried = True

    def add(self, rows) -> np.ndarray:
        """Insert rows under freshly-assigned ids; returns them."""
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if (self.compactor is not None and self.compactor.in_flight
                and self._delta.count + len(rows) > self._delta.capacity):
            self.compactor.join()  # outside the lock, as in update()
        with self._lock:
            gids = np.arange(self._next_id, self._next_id + len(rows), dtype=np.int64)
            self.insert(gids, rows, _wait_ok=False)
        return gids

    def delete(self, ids) -> None:
        """Tombstone rows; physical reclamation happens at compaction.
        Validates every id (and uniqueness) up front, so a bad call
        mutates nothing."""
        gids = np.atleast_1d(np.asarray(ids, np.int64))
        self._check_gids(gids)
        with self._lock:
            for g in gids:
                if not self._is_alive(int(g)):
                    raise KeyError(f"id {int(g)} is not alive")
            self._wal_append("delete", gids)
            for g in gids:
                g = int(g)
                slot = self._slot_of.get(g)
                if slot is not None and self._delta.alive[slot]:
                    self._delta.alive[slot] = False
                    del self._slot_of[g]
                else:
                    self._base_alive[self._row_of[g]] = False
                    self._n_base_dead += 1
            self._log(("delete", gids.copy()))
            self._dirty()
            if _metrics.enabled():
                _metrics.counter("stream.deletes").inc(len(gids))

    def update(self, ids, rows) -> None:
        """Replace the rows of live ids (tombstone old + append new).
        Capacity and shape are checked BEFORE the tombstoning, so a doomed
        insert half cannot leave rows deleted with no replacement appended.
        (If the batch fits the capacity but not the current free space, the
        insert half self-compacts — the just-tombstoned old rows are
        reclaimed and the replacements land in a fresh delta.)"""
        gids = np.atleast_1d(np.asarray(ids, np.int64))
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        self._check_gids(gids)
        if rows.shape != (len(gids), self.d):
            raise ValueError(f"rows must be ({len(gids)}, {self.d}), "
                             f"got {rows.shape}")
        if len(gids) > self._delta.capacity:
            raise ValueError(f"update of {len(gids)} rows exceeds delta "
                             f"capacity {self._delta.capacity}")
        if (self.compactor is not None and self.compactor.in_flight
                and self._delta.count + len(gids) > self._delta.capacity):
            # wait for the in-flight rebuild BEFORE taking the lock (the
            # install needs it); afterwards the delta has room again
            self.compactor.join()
        with self._lock:
            if (self._oplog is not None
                    and self._delta.count + len(gids) > self._delta.capacity):
                raise RuntimeError("delta full while compaction in flight")
            # defer the auto-compaction trigger: the delete half must not
            # open the op log mid-update (it would doom the insert half's
            # capacity re-check and leave the rows tombstoned)
            self._defer_trigger = True
            try:
                self.delete(gids)
                self.insert(gids, rows, _wait_ok=False)
            finally:
                self._defer_trigger = False
            if self.compactor is not None and self._oplog is None:
                self.compactor.maybe_trigger(self)

    # -- snapshot + search ---------------------------------------------------
    def snapshot(self) -> Snapshot:
        """The current `(base, delta_watermark, tombstone_epoch)` triple as
        immutable device arrays; cached until the next write."""
        with self._lock:
            if self._snap is not None:
                return self._snap
            if self._base_dev is None:
                self._base_dev = jax.tree.map(jnp.asarray, self._base.arrays)
            d = self._delta
            # ship/score only a pow2-quantized prefix of the delta buffers:
            # O(log capacity) distinct compiled shapes between compactions,
            # and an empty/small delta doesn't pay for the full preallocation
            cap_q = min(d.capacity, next_pow2(max(d.count, 64)))
            self._snap = Snapshot(
                arrays=self._base_dev,
                meta=self._base.meta,
                base_alive=jnp.asarray(self._base_alive.copy()),
                delta_x=jnp.asarray(d.x[:cap_q].copy()),
                delta_gids=jnp.asarray(d.gids[:cap_q].astype(np.int32)),
                delta_valid=jnp.asarray(d.alive[:cap_q].copy()),
                epoch=self._epoch,
                delta_count=d.count,
                n_base_dead=self._n_base_dead,
                clean=(self._n_base_dead == 0 and d.count == 0),
            )
            return self._snap

    def search(self, queries, k: int = 10,
               runtime: Optional[RuntimeConfig] = None):
        """Segment-merged c-k-AMIP search over the live rows. Returns
        (ids (B, k) GLOBAL, scores (B, k), StreamStats). A user-supplied
        RuntimeConfig is taken as-is (only k is stamped in), matching the
        sharded/serve contract."""
        cfg = runtime if runtime is not None else RuntimeConfig()
        cfg = dataclasses.replace(cfg, k=k)
        return search_segments(self.snapshot(), queries, cfg)

    # -- compaction ----------------------------------------------------------
    def _freeze_for_compaction(self) -> tuple[np.ndarray, np.ndarray]:
        """Copy out the surviving rows and open the op log (writes from here
        to `_install_compacted` are replayed onto the new base)."""
        with self._lock:
            if self._oplog is not None:
                raise RuntimeError("compaction already in flight")
            # the begin marker sits EXACTLY at the freeze point in the op
            # order: replay freezes over the same live set
            self._wal_append("compact_begin")
            gids, rows = self.alive_items()
            self._oplog = []
            return gids, rows

    def _install_compacted(self, new_base: ProMIPSIndex) -> None:
        """Atomically swap in the rebuilt base, reset the delta, and replay
        the writes that landed while the rebuild ran."""
        with self._lock:
            # the commit marker sits at the install point; the op-log replay
            # below is NOT re-logged (each op already has its own record
            # from when it was applied live, between begin and commit)
            self._wal_append("compact_commit")
            ops, self._oplog = self._oplog, None
            self._set_base(new_base)
            self._reset_delta()
            self._epoch += 1
            self._snap = None
            prev, self._wal_suspended = self._wal_suspended, True
            try:
                for op in ops:
                    if op[0] == "insert":
                        self.insert(op[1], op[2])
                    else:
                        self.delete(op[1])
            finally:
                self._wal_suspended = prev
        # counted HERE (not in compact()) so the background Compactor's
        # installs land in the same counter as synchronous compactions
        if _metrics.enabled():
            _metrics.counter("stream.compactions").inc()

    def _abandon_compaction(self) -> None:
        """Close the op log without swapping (failed rebuild). The freeze only
        copied state and logged ops were ALSO applied live, so discarding the
        log loses nothing; the next trigger simply retries."""
        with self._lock:
            self._wal_append("compact_abort")
            self._oplog = None

    def compact(self) -> None:
        """Synchronous compaction (the background path is `self.compactor`).

        With NO surviving rows (every row tombstoned — e.g. one fully
        retired shard of a `MutableShardedProMIPS`) there is nothing to
        rebuild a base FROM: the rebuild is skipped and the op log closed.
        Tombstones then simply persist, which is semantically invisible —
        searches already mask every dead row."""
        with _span("stream_compact",
                   active=_trace.enabled() or _metrics.enabled(),
                   metric="stream.compaction_us"):
            gids, rows = self._freeze_for_compaction()
            if len(gids) == 0:
                self._abandon_compaction()
                return
            try:
                new_base = rebuild_base(gids, rows, self.build_kwargs)
            except BaseException:
                self._abandon_compaction()
                raise
            self._install_compacted(new_base)

    def join_compaction(self, timeout: Optional[float] = None) -> None:
        if self.compactor is not None:
            self.compactor.join(timeout)

    # -- persistence (repro.api save/load, DESIGN.md §9) ---------------------
    def state_dict(self) -> tuple[dict, dict]:
        """(arrays, meta) capturing the full mutable state: base segment
        arrays + tombstone bitmap + the filled delta prefix. Restoring via
        `from_state` yields bit-identical searches — the base arrays are
        persisted verbatim (no rebuild) and the delta is replayed in place.
        """
        with self._lock:
            if self._oplog is not None:
                raise RuntimeError("cannot serialize while a compaction is "
                                   "in flight (join_compaction() first)")
            arrays = {f"base_{f}": np.asarray(getattr(self._base.arrays, f))
                      for f in IndexArrays._fields}
            d = self._delta
            arrays.update(
                base_alive=self._base_alive.copy(),
                delta_x=d.x[: d.count].copy(),
                delta_gids=d.gids[: d.count].copy(),
                delta_alive=d.alive[: d.count].copy(),
            )
            meta = dict(
                meta=dataclasses.asdict(self._base.meta),
                build_kwargs=dict(self.build_kwargs),
                delta_capacity=int(d.capacity),
                next_id=int(self._next_id),
                wal_seq=int(self._wal_seq),
                auto_compact=self.compactor is not None,
                compaction=dataclasses.asdict(
                    self.compactor.cfg if self.compactor is not None
                    else CompactionConfig()),
            )
            return arrays, meta

    @classmethod
    def from_state(cls, arrays: dict, meta: dict, *,
                   auto_compact: Optional[bool] = None,
                   compaction: Optional[CompactionConfig] = None
                   ) -> "MutableProMIPS":
        """Inverse of :meth:`state_dict` (no index rebuild)."""
        base = ProMIPSIndex(
            arrays=IndexArrays(**{f: np.asarray(arrays[f"base_{f}"])
                                  for f in IndexArrays._fields}),
            meta=IndexMeta(**meta["meta"]),
            layout=None,
        )
        obj = cls.__new__(cls)
        obj.build_kwargs = dict(meta["build_kwargs"])
        obj.d = base.meta.d
        obj._lock = threading.RLock()
        obj._oplog = None
        obj._defer_trigger = False
        obj._init_wal_state()
        obj._wal_seq = obj._wal_floor = int(meta.get("wal_seq", 0))
        obj._delta_capacity = int(meta["delta_capacity"])
        obj._set_base(base)
        obj._base_alive = np.asarray(arrays["base_alive"], bool).copy()
        obj._n_base_dead = int(np.sum((base.arrays.ids >= 0)
                                      & ~obj._base_alive))
        obj._reset_delta()
        d = obj._delta
        count = len(arrays["delta_gids"])
        if count:
            d.x[:count] = arrays["delta_x"]
            d.gids[:count] = arrays["delta_gids"]
            d.alive[:count] = arrays["delta_alive"]
            d.count = count
            for slot in range(count):
                if d.alive[slot]:
                    obj._slot_of[int(d.gids[slot])] = slot
        obj._epoch = 0
        obj._snap = None
        obj._next_id = int(meta["next_id"])
        if auto_compact is None:
            auto_compact = bool(meta.get("auto_compact", False))
        if compaction is None:
            # restore the saved trigger config, not the class default
            compaction = CompactionConfig(**meta.get("compaction", {}))
        obj.compactor = Compactor(compaction) if auto_compact else None
        return obj


__all__ = ["MutableProMIPS"]
