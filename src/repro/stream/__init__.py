# Streaming ProMIPS: mutable index = immutable base segment + append-only
# delta segment + tombstones, with snapshot/epoch versioning and background
# compaction (DESIGN.md §8).
from .compaction import CompactionConfig, Compactor, rebuild_base
from .mutable import MutableProMIPS
from .segments import DeltaSegment, Snapshot, StreamStats

__all__ = [
    "CompactionConfig", "Compactor", "rebuild_base",
    "MutableProMIPS",
    "DeltaSegment", "Snapshot", "StreamStats",
]
