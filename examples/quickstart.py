"""Quickstart: build a ProMIPS index and run probability-guaranteed
c-k-AMIP queries, paper-faithful and beyond-paper progressive modes.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.baselines.exact import exact_topk
from repro.core import ProMIPS, overall_ratio, recall_at_k
from repro.data.synthetic import paper_dataset, paper_queries


def main():
    # Netflix-like PureSVD factors (paper Table III shape: 17770 x 300)
    x = paper_dataset("netflix")
    queries = paper_queries("netflix", 16)
    print(f"corpus {x.shape}, queries {queries.shape}")

    # paper defaults: m=6 on Netflix, c=0.9, p=0.5, kp=5, Nkey=40, ksp=10
    pm = ProMIPS.build(x, m=6, c=0.9, p=0.5)
    print(f"index: {pm.meta.n_groups} quick-probe groups, "
          f"{pm.meta.n_subparts} sub-partitions, {pm.meta.n_blocks} pages, "
          f"{pm.meta.index_bytes/1e6:.2f} MB")

    eids, escores = exact_topk(x, queries, 10)
    for label, fn in [
        ("paper-faithful (Alg.2+3)", lambda q: pm.search_host(q, k=10)),
        ("progressive (beyond-paper)", lambda q: pm.search_host_progressive(q, k=10)),
    ]:
        ratios, recalls, pages = [], [], []
        for i in range(len(queries)):
            ids, scores, st = fn(queries[i])
            ratios.append(overall_ratio(scores, escores[i]))
            recalls.append(recall_at_k(ids, eids[i]))
            pages.append(st.pages)
        print(f"{label:28s} ratio={np.mean(ratios):.4f} "
              f"P[ratio>=c]={np.mean([r >= 0.9 for r in ratios]):.2f} "
              f"recall={np.mean(recalls):.3f} pages={np.mean(pages):.0f}"
              f"/{pm.meta.n_blocks}")

    # batched device-mode (jit) search
    ids, scores, stats = pm.search_progressive(queries, k=10)
    ratios = [overall_ratio(np.asarray(scores)[i], escores[i])
              for i in range(len(queries))]
    print(f"{'device-mode (jit, batched)':28s} ratio={np.mean(ratios):.4f} "
          f"pages={np.mean(np.asarray(stats.pages)):.0f}")


if __name__ == "__main__":
    main()
