"""Quickstart: the unified index API (`repro.api`, DESIGN.md §9).

Declare the paper's guarantee — "c-AMIP results with probability >= p0" —
once as a `GuaranteeConfig`; every registered backend builds and searches
behind the same facade, returns the same `SearchResult`, and persists with
`save`/`load` (bit-identical post-load searches).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import api
from repro.baselines.exact import exact_topk
from repro.core import overall_ratio, recall_at_k
from repro.data.synthetic import paper_dataset, paper_queries


def main():
    # Netflix-like PureSVD factors (paper Table III shape: 17770 x 300)
    x = paper_dataset("netflix")
    queries = paper_queries("netflix", 16)
    print(f"corpus {x.shape}, queries {queries.shape}")

    # the declarative contract: c-AMIP with probability >= p0, top-k.
    # m, radii and Quick-Probe budgets are DERIVED (paper §V-B), not picked:
    guarantee = api.GuaranteeConfig(c=0.9, p0=0.5, k=10)
    plan = guarantee.derive(len(x))
    print(f"derived plan: m={plan.m} x_p={plan.x_p:.3f} "
          f"probe_cost={plan.probe_cost:.0f} "
          f"budget={'all blocks' if plan.budget is None else plan.budget}")

    eids, escores = exact_topk(x, queries, 10)

    # one registry loop — every backend behind the same build/search calls
    sweep = [
        ("promips", dict(m=6)),
        ("promips", dict(m=6, mode="progressive")),
        ("h2alsh", {}),
        ("rangelsh", {}),
        ("pq", dict(n_cells=32)),
    ]
    for backend, opts in sweep:
        s = api.build(x, backend=backend, guarantee=guarantee, seed=0, **opts)
        ratios, recalls = [], []
        res = s.search(queries)  # one batched call, any backend
        for i in range(len(queries)):
            ratios.append(overall_ratio(res.scores[i], escores[i]))
            recalls.append(recall_at_k(res.ids[i], eids[i]))
        label = backend + ("+" if opts.get("mode") == "progressive" else "")
        print(f"{label:12s} guaranteed={s.capabilities.guaranteed!s:5s} "
              f"ratio={np.mean(ratios):.4f} "
              f"P[ratio>=c]={np.mean([r >= 0.9 for r in ratios]):.2f} "
              f"recall={np.mean(recalls):.3f} "
              f"pages/q={res.pages / len(queries):.0f} "
              f"index={s.index_bytes/1e6:.2f}MB")

    # persistence: save -> load -> search is bit-identical
    s = api.build(x, backend="promips", guarantee=guarantee, seed=0, m=6)
    before = s.search(queries)
    with tempfile.TemporaryDirectory() as td:
        path = s.save(os.path.join(td, "netflix_idx"))
        disk = api.saved_bytes(path)
        after = api.load(path).search(queries)
    same = (np.array_equal(before.ids, after.ids)
            and np.array_equal(before.scores, after.scores))
    print(f"save/load round trip: {disk/1e6:.2f}MB on disk, "
          f"bit-identical={same}")


if __name__ == "__main__":
    main()
