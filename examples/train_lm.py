"""Train a reduced LM for a few hundred steps with checkpoint/resume —
exercises the trainer, AdamW, microbatching, and the fault-tolerance path.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()
    ckpt = "/tmp/repro_train_lm_ckpt"
    train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps), "--batch", "8", "--seq", "256",
        "--microbatches", "2",
        "--ckpt-dir", ckpt, "--ckpt-every", "50", "--log-every", "20",
    ])
    print(f"checkpoints in {ckpt}; rerun to resume from the latest step")


if __name__ == "__main__":
    main()
