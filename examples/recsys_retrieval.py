"""Matrix-factorization recommendation retrieval (paper §I use case):
user vectors query a sharded item-factor corpus; ProMIPS returns
probability-guaranteed top-10 items. Demonstrates the multi-shard search
(shard_map) when more than one device is available.

  PYTHONPATH=src python examples/recsys_retrieval.py
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/recsys_retrieval.py   # sharded path
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.baselines.exact import exact_topk
from repro.core import ProMIPS, overall_ratio, recall_at_k
from repro.data.synthetic import mf_factors


def main():
    n_items, n_users, rank, d = 50_000, 32, 32, 128
    items = mf_factors(n_items, d, rank, decay=0.15, seed=0, norm_tail=0.3)
    users = mf_factors(n_users, d, rank, decay=0.15, seed=1)
    eids, escores = exact_topk(items, users, 10)

    n_dev = len(jax.devices())
    if n_dev >= 2:
        from repro.core.sharded import (build_sharded, device_put_sharded_index,
                                        sharded_search)
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((1, n_dev), ("data", "model"))
        sh = build_sharded(items, n_dev, m=8, c=0.9, p=0.7, norm_strata=4)
        shd = device_put_sharded_index(sh, mesh)
        ids, scores, pages = sharded_search(shd, users, 10, mesh,
                                            budget=sh.meta.n_blocks)
        label = f"sharded over {n_dev} devices"
    else:
        pm = ProMIPS.build(items, m=8, c=0.9, p=0.7, norm_strata=4)
        ids, scores, stats = pm.search_progressive(users, k=10)
        pages = np.sum(np.asarray(stats.pages))
        label = "single device"

    ids, scores = np.asarray(ids), np.asarray(scores)
    ratios = [overall_ratio(scores[i], escores[i]) for i in range(n_users)]
    recalls = [recall_at_k(ids[i], eids[i]) for i in range(n_users)]
    print(f"recsys retrieval ({label}): {n_items} items, {n_users} users")
    print(f"  ratio={np.mean(ratios):.4f} recall={np.mean(recalls):.3f} "
          f"total_pages={int(pages)}")
    print(f"  sample user 0 recommended items: {ids[0][:5].tolist()}")


if __name__ == "__main__":
    main()
