"""Matrix-factorization recommendation retrieval (paper §I use case):
user vectors query an item-factor corpus; ProMIPS returns probability-
guaranteed top-10 items. Everything goes through the unified `repro.api`
facade — the backend is a registry NAME (the range-routed mutable "sharded"
backend when several devices are available, single-index otherwise); build,
search and the churn loop's mutations are the same calls either way.

The "sharded" backend here is the facade's host-merge fan-out (per-shard
searches overlap under JAX async dispatch; k x shards pairs merged on
host). The mesh/shard_map SPMD search is a lower-level tool —
`core/sharded.py::sharded_search`, exercised by tests/test_distributed.py.

  PYTHONPATH=src python examples/recsys_retrieval.py
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/recsys_retrieval.py   # sharded backend
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import api
from repro.baselines.exact import exact_topk
from repro.core import overall_ratio, recall_at_k
from repro.data.synthetic import mf_factors

GUARANTEE = api.GuaranteeConfig(c=0.9, p0=0.7, k=10)


def main():
    n_items, n_users, rank, d = 50_000, 32, 32, 128
    items = mf_factors(n_items, d, rank, decay=0.15, seed=0, norm_tail=0.3)
    users = mf_factors(n_users, d, rank, decay=0.15, seed=1)
    eids, escores = exact_topk(items, users, 10)

    n_dev = len(jax.devices())
    backend = "sharded" if n_dev >= 2 else "promips"
    opts = dict(n_shards=n_dev) if backend == "sharded" else {}
    s = api.build(items, backend=backend, guarantee=GUARANTEE, seed=0,
                  m=8, mode="progressive", norm_strata=4, **opts)
    res = s.search(users)

    ratios = [overall_ratio(res.scores[i], escores[i]) for i in range(n_users)]
    recalls = [recall_at_k(res.ids[i], eids[i]) for i in range(n_users)]
    print(f"recsys retrieval (backend={backend}, {n_dev} device(s)): "
          f"{n_items} items, {n_users} users")
    print(f"  ratio={np.mean(ratios):.4f} recall={np.mean(recalls):.3f} "
          f"total_pages={res.pages}")
    print(f"  sample user 0 recommended items: {res.ids[0][:5].tolist()}")

    churn_loop(items, users)


def churn_loop(items, users, rounds: int = 4):
    """Streaming catalog churn (DESIGN.md §8) through the facade's uniform
    mutation surface: every round retires a slice of items, ships a batch of
    new ones, refreshes a few embeddings — then searches and reports recall
    against an exact scan of the CURRENT catalog (`alive_items`). Recall
    stays flat through inserts, deletes and the background compaction."""
    n, d = items.shape
    rng = np.random.RandomState(7)
    s = api.build(items[: n // 2], backend="promips-stream",
                  guarantee=GUARANTEE, seed=0, m=8, norm_strata=4,
                  auto_compact=True)
    assert s.capabilities.supports_mutation
    alive = set(range(n // 2))
    next_id, k = n // 2, 10

    print(f"churn loop: {len(alive)} items live (backend=promips-stream)")
    for r in range(rounds):
        dead = rng.choice(sorted(alive), size=1000, replace=False)
        s.delete(dead)
        alive.difference_update(dead.tolist())
        fresh = items[n // 2 + (r * 2000) % (n // 2):][:2000]
        gids = np.arange(next_id, next_id + len(fresh))
        next_id += len(fresh)
        s.insert(gids, fresh)
        alive.update(gids.tolist())
        refresh = rng.choice(sorted(alive), size=200, replace=False)
        s.update(refresh, rng.randn(len(refresh), d).astype(np.float32))

        res = s.search(users, k=k)
        # exact oracle over the live catalog (refreshed rows via the stream)
        cat_ids, cat_rows = s.alive_items()
        eids, _ = exact_topk(cat_rows, users, k)
        rec = np.mean([len(set(res.ids[i]) & set(cat_ids[eids[i]])) / k
                       for i in range(len(users))])
        print(f"  round {r}: live={s.n} recall={rec:.3f} "
              f"pages={res.pages} wall={res.wall_time_s*1e3:.0f}ms")
    s.flush()
    print(f"  post-churn live={s.n}")


if __name__ == "__main__":
    main()
