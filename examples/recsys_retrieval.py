"""Matrix-factorization recommendation retrieval (paper §I use case):
user vectors query a sharded item-factor corpus; ProMIPS returns
probability-guaranteed top-10 items. Demonstrates the multi-shard search
(shard_map) when more than one device is available.

  PYTHONPATH=src python examples/recsys_retrieval.py
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/recsys_retrieval.py   # sharded path
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.baselines.exact import exact_topk
from repro.core import ProMIPS, overall_ratio, recall_at_k
from repro.data.synthetic import mf_factors


def main():
    n_items, n_users, rank, d = 50_000, 32, 32, 128
    items = mf_factors(n_items, d, rank, decay=0.15, seed=0, norm_tail=0.3)
    users = mf_factors(n_users, d, rank, decay=0.15, seed=1)
    eids, escores = exact_topk(items, users, 10)

    n_dev = len(jax.devices())
    if n_dev >= 2:
        from repro.core.sharded import (build_sharded, device_put_sharded_index,
                                        sharded_search)
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((1, n_dev), ("data", "model"))
        sh = build_sharded(items, n_dev, m=8, c=0.9, p=0.7, norm_strata=4)
        shd = device_put_sharded_index(sh, mesh)
        ids, scores, pages = sharded_search(shd, users, 10, mesh,
                                            budget=sh.meta.n_blocks)
        label = f"sharded over {n_dev} devices"
    else:
        pm = ProMIPS.build(items, m=8, c=0.9, p=0.7, norm_strata=4)
        ids, scores, stats = pm.search_progressive(users, k=10)
        pages = np.sum(np.asarray(stats.pages))
        label = "single device"

    ids, scores = np.asarray(ids), np.asarray(scores)
    ratios = [overall_ratio(scores[i], escores[i]) for i in range(n_users)]
    recalls = [recall_at_k(ids[i], eids[i]) for i in range(n_users)]
    print(f"recsys retrieval ({label}): {n_items} items, {n_users} users")
    print(f"  ratio={np.mean(ratios):.4f} recall={np.mean(recalls):.3f} "
          f"total_pages={int(pages)}")
    print(f"  sample user 0 recommended items: {ids[0][:5].tolist()}")

    churn_loop(items, users)


def churn_loop(items, users, rounds: int = 4):
    """Streaming catalog churn (DESIGN.md §8): every round retires a slice of
    items, ships a batch of new ones into the delta segment, and refreshes a
    few embeddings — then searches and reports recall against an exact scan
    of the CURRENT catalog. Recall stays flat through inserts, deletes and
    the compaction that folds the churn back into the base."""
    from repro.stream import MutableProMIPS

    n, d = items.shape
    rng = np.random.RandomState(7)
    st = MutableProMIPS(items[: n // 2], m=8, c=0.9, p=0.7, norm_strata=4,
                        seed=0, auto_compact=True)
    alive = set(range(n // 2))
    next_id, k = n // 2, 10

    print(f"churn loop: {len(alive)} items live, "
          f"compaction threshold {st.compactor.cfg.threshold}")
    for r in range(rounds):
        dead = rng.choice(sorted(alive), size=1000, replace=False)
        st.delete(dead)
        alive.difference_update(dead.tolist())
        fresh = items[n // 2 + (r * 2000) % (n // 2):][:2000]
        gids = np.arange(next_id, next_id + len(fresh))
        next_id += len(fresh)
        st.insert(gids, fresh)
        alive.update(gids.tolist())
        refresh = rng.choice(sorted(alive), size=200, replace=False)
        st.update(refresh, rng.randn(len(refresh), d).astype(np.float32))

        ids, _, stats = st.search(users, k=k)
        # exact oracle over the live catalog (refreshed rows via the stream)
        cat_ids, cat_rows = st.alive_items()
        eids, _ = exact_topk(cat_rows, users, k)
        rec = np.mean([len(set(np.asarray(ids)[i]) & set(cat_ids[eids[i]])) / k
                       for i in range(len(users))])
        print(f"  round {r}: live={st.n_alive} churn={st.churn_fraction:.2f} "
              f"delta={st.delta_fraction:.2f} recall={rec:.3f} "
              f"pages={int(np.sum(np.asarray(stats.pages)))}"
              + ("  [compacting]" if st.compactor.in_flight else ""))
    st.join_compaction()
    print(f"  compactions run: {st.compactor.runs}; "
          f"post-compaction churn={st.churn_fraction:.2f}")


if __name__ == "__main__":
    main()
