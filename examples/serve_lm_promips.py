"""END-TO-END DRIVER (paper kind = search/serving): serve a small LM with
batched requests through the continuous-batching engine, comparing exact
greedy decoding against ProMIPS approximate-logit decoding — the paper's
c-AMIP search applied to the decode-time vocabulary MIPS problem.

  PYTHONPATH=src python examples/serve_lm_promips.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine


def main():
    cfg = get_config("phi4-mini-3.8b").reduced()  # family-faithful small model
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, size=24) for _ in range(12)]

    results = {}
    for mode in ("exact", "promips"):
        eng = DecodeEngine(params, cfg, batch_slots=4, max_len=128,
                           logits_mode=mode,
                           promips_kwargs=dict(m=8, c=0.95, p=0.95))
        reqs = [eng.submit(p, max_new_tokens=12) for p in prompts]
        t0 = time.time()
        eng.run()
        dt = time.time() - t0
        toks = sum(len(r.out_tokens) for r in reqs)
        results[mode] = [r.out_tokens for r in reqs]
        print(f"{mode:8s}: {len(reqs)} reqs, {toks} tokens, {dt:.1f}s "
              f"({toks/dt:.1f} tok/s), engine steps {eng.steps}, "
              f"logit pages touched {eng.pages}")

    agree = np.mean([a == b for a, b in zip(results["exact"], results["promips"])])
    per_tok = np.mean([np.mean([x == y for x, y in zip(a, b)])
                       for a, b in zip(results["exact"], results["promips"])])
    print(f"greedy agreement: {agree:.2f} of sequences identical, "
          f"{per_tok:.3f} of tokens identical")


if __name__ == "__main__":
    main()
